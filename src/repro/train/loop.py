"""Training-step builders: pjit path (+microbatch grad accumulation) and
the explicit-DP shard_map path with the paper's PIM schedule
(+ int8 compressed all-reduce with error feedback).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..compat import shard_map

from repro.optim.adam import AdamW
from repro.optim.grad_compression import ef_compress_psum


def make_train_step(model, optimizer: AdamW, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch leaves have leading dim B; with microbatches > 1 the
    step scans over k slices of B/k, accumulating f32 gradients — the
    activation-memory knob that makes the big train_4k cells fit
    (configs/shapes.py TRAIN_MICROBATCHES), and the natural place where
    per-microbatch reduce-scatter overlaps the next microbatch's compute
    on real hardware.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc_g, acc_l = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_g = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches

        params, opt_state, gnorm = optimizer.update(grads, opt_state,
                                                    params)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        return model.loss(params, batch).astype(jnp.float32)
    return eval_step


# ---------------------------------------------------------------------------
# Explicit-DP trainer (the paper's PIM schedule applied to LM training):
# replicated params, batch sharded over a "data" axis via shard_map, ONE
# gradient reduction per step — optionally int8-compressed with error
# feedback (optim/grad_compression.py).
# ---------------------------------------------------------------------------

def make_dp_train_step(model, optimizer: AdamW, mesh, *,
                       compress: bool = False):
    axis = "data"
    world = mesh.shape[axis] * mesh.shape.get("pod", 1)

    def step(params, opt_state, err, batch):
        (loss, grads), new_err = _dp_call(mesh, axis, model, params, err,
                                          batch, compress, world)
        params, opt_state, gnorm = optimizer.update(grads, opt_state,
                                                    params)
        return params, opt_state, new_err, {
            "loss": loss.astype(jnp.float32), "grad_norm": gnorm}

    return step


def _dp_call(mesh, axis, model, params, err, batch, compress, world):
    """Build + call the shard_map'd gradient step (specs mirror args).

    On a multi-pod mesh the exact (uncompressed) reduction uses the
    two-level hierarchical schedule (distributed/collectives.py) so the
    slow cross-pod links carry 1/pod_size of the gradient bytes.
    """
    from jax.sharding import PartitionSpec as P
    hierarchical = "pod" in mesh.axis_names
    dp_axes = ("pod", axis) if hierarchical else (axis,)
    batch_specs = jax.tree_util.tree_map(
        lambda x: P(dp_axes) if getattr(x, "ndim", 0) > 0 else P(), batch)
    rep = jax.tree_util.tree_map(lambda _: P(), params)
    err_specs = jax.tree_util.tree_map(lambda _: P(), err)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(rep, err_specs, batch_specs),
        out_specs=((P(), rep), err_specs), check_vma=False)
    def run(params_, err_, batch_):
        loss, g = jax.value_and_grad(
            lambda p: model.loss(p, batch_))(params_)
        if compress:
            flat_g, td = jax.tree_util.tree_flatten(g)
            flat_e, _ = jax.tree_util.tree_flatten(err_)
            outs = [ef_compress_psum(gg, ee, dp_axes, world)
                    for gg, ee in zip(flat_g, flat_e)]
            g = jax.tree_util.tree_unflatten(td, [o[0] for o in outs])
            new_err = jax.tree_util.tree_unflatten(td,
                                                   [o[1] for o in outs])
        elif hierarchical:
            from repro.distributed.collectives import hierarchical_psum
            g = jax.tree_util.tree_map(
                lambda gg: hierarchical_psum(
                    gg, intra_axis=axis, inter_axis="pod") / world, g)
            new_err = err_
        else:
            g = jax.lax.pmean(g, axis)
            new_err = err_
        loss = jax.lax.pmean(loss, dp_axes)
        return (loss, g), new_err

    return run(params, err, batch)
