"""Fault-tolerance runtime: straggler detection + elastic rescale.

At 1000+ node scale the two dominant failure modes are (a) slow nodes
(thermal throttling, flaky ICI links) and (b) dead nodes.  The trainer
handles them with:

  - ``StragglerMonitor``: per-step wall-time EWMA with z-score flagging;
    on a real pod each host reports its step time through the same
    all-host channel the data loader uses, and persistent stragglers
    trigger a checkpoint + rescale.  (On CPU the monitor is fed the
    local step times; the detection logic is identical and unit-tested.)
  - ``plan_rescale``: given surviving device count, pick the largest mesh
    (dp x tp) that (1) divides the survivors and (2) keeps tp equal (so
    weight shards stay valid) — restoring the latest checkpoint onto the
    new mesh re-shards everything (train/checkpoint.py).
  - ``run_with_recovery``: the supervision loop — catch step failures,
    restore from the last checkpoint, continue; injected-fault tested.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA + z-score step-time outlier detection."""
    alpha: float = 0.1
    z_threshold: float = 3.0
    warmup_steps: int = 5

    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.n += 1
        if self.n <= self.warmup_steps:
            # prime the statistics
            delta = step_seconds - self.mean
            self.mean += delta / self.n
            self.var += delta * (step_seconds - self.mean)
            return False
        std = math.sqrt(max(self.var / max(self.n - 1, 1), 1e-12))
        z = (step_seconds - self.mean) / max(std, 1e-9)
        is_outlier = z > self.z_threshold
        if is_outlier:
            self.flagged += 1
        else:
            # EWMA update only on healthy steps (outliers would poison it)
            self.mean = (1 - self.alpha) * self.mean \
                + self.alpha * step_seconds
            self.var = (1 - self.alpha) * self.var \
                + self.alpha * (step_seconds - self.mean) ** 2
        return is_outlier


def plan_rescale(n_surviving: int, tp: int,
                 pod_axis: bool = False) -> Optional[tuple]:
    """Largest usable mesh shape from surviving chips, keeping tp fixed.

    Returns ("pod","data","model") or ("data","model") dims, or None if
    fewer than one tp group survives.  Keeping tp constant means weight
    shards from the checkpoint remain bitwise-valid; only the data axis
    shrinks (gradient all-reduce groups re-form automatically).
    """
    if n_surviving < tp:
        return None
    dp = n_surviving // tp
    if pod_axis and dp % 2 == 0:
        return (2, dp // 2, tp)
    return (dp, tp)


@dataclasses.dataclass
class RecoveryStats:
    failures: int = 0
    restores: int = 0
    steps_lost: int = 0


def run_with_recovery(step_fn: Callable, save_fn: Callable,
                      restore_fn: Callable, *, n_steps: int,
                      ckpt_every: int, state,
                      monitor: Optional[StragglerMonitor] = None,
                      max_failures: int = 10):
    """Supervised training loop with checkpoint/restart semantics.

    ``step_fn(state, step) -> state`` may raise (injected faults in tests;
    XlaRuntimeError / RPC errors on a real pod).  On failure: restore the
    latest checkpoint and continue from there.
    """
    stats = RecoveryStats()
    last_saved = -1
    step = 0
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(state, step)
            dt = time.perf_counter() - t0
            if monitor is not None:
                monitor.observe(dt)
            if (step + 1) % ckpt_every == 0:
                save_fn(state, step + 1)
                last_saved = step + 1
            step += 1
        except Exception:
            stats.failures += 1
            if stats.failures > max_failures:
                raise
            if last_saved >= 0:
                state = restore_fn(last_saved)
                stats.steps_lost += step - last_saved
                step = last_saved
            else:
                stats.steps_lost += step
                step = 0
            stats.restores += 1
    return state, stats
