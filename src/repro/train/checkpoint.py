"""Atomic, versioned checkpointing (fault tolerance substrate).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json   (tmp dir + rename for
atomicity; a crashed save never shadows a good checkpoint).  keep_last_k
pruning; restore validates tree structure and shapes and re-places leaves
onto the target mesh shardings (this is also the elastic-rescale path:
restore onto a *different* mesh re-shards transparently).

Multi-host note: on a real pod each process writes its address-split shard
via the same API with process-indexed filenames (the container is single-
process; the sharding round-trip is exercised in tests via host devices).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                       for p in path)
        named[key] = leaf
    return named, treedef


def save(ckpt_dir: str, step: int, state: Any, *, keep_last: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    """Atomic save of a pytree state.  Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # taken BEFORE this save publishes: the newest checkpoint a
    # concurrent reader could have selected via latest_step() — pruning
    # must never delete it (see _prune)
    durable_before = latest_step(ckpt_dir)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named, _ = _flatten_with_names(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in named.items()}
    # numpy's npz cannot store ml_dtypes (bfloat16 etc.); save a bit-view
    # and record the true dtype in the manifest
    exotic = {}
    storable = {}
    for k, a in arrays.items():
        if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
            exotic[k] = a.dtype.name
            storable[k] = a.view(np.uint16 if a.dtype.itemsize == 2
                                 else np.uint8)
        else:
            storable[k] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **storable)
    arrays = storable
    manifest = {
        "exotic_dtypes": exotic,
        "step": step,
        "time": time.time(),
        "n_arrays": len(arrays),
        "total_bytes": int(sum(a.nbytes for a in arrays.values())),
        "keys_checksum": _keys_checksum(arrays),
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    _prune(ckpt_dir, keep_last, durable_before)
    return final


def _keys_checksum(arrays: dict) -> str:
    import hashlib
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(str(arrays[k].shape).encode())
        h.update(str(arrays[k].dtype).encode())
    return h.hexdigest()[:16]


def _prune(ckpt_dir: str, keep_last: int,
           durable_before: Optional[int] = None) -> None:
    """Remove old checkpoints, keeping the newest ``keep_last``.

    ``durable_before`` is the latest step that was durable BEFORE the
    save that triggered this prune.  A concurrent restore picks its
    checkpoint via ``latest_step()`` — which can only have returned
    ``durable_before`` or older-but-still-newest at that moment — so
    deleting it here would race the reader (keep_last=1 used to delete
    the previous latest the instant a new save published, mid-read).
    Only checkpoints *strictly older* than that latest durable save are
    eligible for pruning; the previously-newest survives one extra save
    cycle and is reclaimed by the next prune, when readers have had a
    newer checkpoint to select the whole time.
    """
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        if durable_before is not None \
                and int(d.split("_")[1]) >= durable_before:
            continue
        shutil.rmtree(os.path.join(ckpt_dir, d))


class AsyncCheckpointer:
    """Background-thread checkpoint writer: ``save`` snapshots the state
    to host memory synchronously (cheap) and writes to disk off the
    training thread — the step never stalls on I/O.  ``wait()`` joins the
    in-flight write (call before shutdown / restore)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        import threading
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[object] = None
        self._threading = threading

    def save(self, step: int, state: Any) -> None:
        self.wait()
        # snapshot on the caller's thread (device->host copy must not race
        # with the next step's donation)
        host_state = jax.tree_util.tree_map(
            lambda v: np.asarray(jax.device_get(v)), state)
        t = self._threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_state),
            kwargs={"keep_last": self.keep_last}, daemon=True)
        t.start()
        self._thread = t

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_raw(ckpt_dir: str, step: int) -> tuple:
    """Load a checkpoint WITHOUT a target tree: returns
    ``(arrays, manifest)`` where ``arrays`` is a flat ``{key: ndarray}``
    dict (exotic dtypes re-viewed per the manifest) and ``manifest`` the
    saved metadata (including any ``extra_meta``).

    This is the restore path for state whose shape is not known until
    the checkpoint is read — the elastic job runtime's carry snapshots
    (repro/elastic), where the checkpoint itself says which workload
    carry it holds."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    exotic = manifest.get("exotic_dtypes", {})
    if exotic:
        import ml_dtypes  # noqa: F401 — registers the dtype names
    arrays = {}
    with np.load(os.path.join(path, "arrays.npz")) as data:
        for key in data.files:
            arr = data[key]
            if key in exotic:
                arr = arr.view(np.dtype(exotic[key]))
            arrays[key] = arr
    return arrays, manifest


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree`` (shape-validated).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put onto them, which is how elastic rescale re-shards state
    saved from a different mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    named_target, treedef = _flatten_with_names(target_tree)
    assert manifest["n_arrays"] == len(named_target), \
        (manifest["n_arrays"], len(named_target))
    leaves = []
    named_shardings = None
    if shardings is not None:
        named_shardings, _ = _flatten_with_names(shardings)
    exotic = manifest.get("exotic_dtypes", {})
    for key, tgt in named_target.items():
        arr = data[key]
        if key in exotic:
            import ml_dtypes
            arr = arr.view(np.dtype(exotic[key]))
        assert tuple(arr.shape) == tuple(tgt.shape), (key, arr.shape,
                                                      tgt.shape)
        if named_shardings is not None:
            leaves.append(jax.device_put(arr, named_shardings[key]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
