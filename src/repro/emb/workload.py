"""EMB registered behind the Workload protocol (DESIGN.md §15.2).

``make_estimator("emb", version="int32", flush_every=8)`` trains the
bank-sharded embedding tables through the same registry surface the
paper's four workloads use — so the scheduler, the elastic job runtime,
compare.py and the pim_ml CLI all pick EMB up without special cases.
"""
from __future__ import annotations

import numpy as np

from ..api.registry import FitResult, TrainerSpec, Workload, \
    register_workload
from . import trainer


class EmbWorkload(Workload):
    """EMB: deferred-update embedding regression (LazyDP-style)."""

    name = "emb"
    aliases = ("EMB", "embedding")
    versions = trainer.VERSIONS
    resumable = True
    defaults = {"n_iters": 200, "batch": 64, "dim": 8, "lr": 0.05,
                "frac_bits": 10, "flush_every": 1, "deferred": None,
                "compress_flush": False, "placement": "mod",
                "n_users": None, "n_items": None, "record_every": 0,
                "seed": 0, "kernel_backend": None, "fuse_steps": 1,
                "pipeline_depth": 2}

    def _config(self, spec: TrainerSpec) -> trainer.EmbConfig:
        return trainer.EmbConfig(version=spec.version, **spec.params)

    def _result(self, spec: TrainerSpec, r: trainer.EmbResult) -> FitResult:
        return FitResult(spec, r, {"user_emb_": r.user_emb,
                                   "item_emb_": r.item_emb,
                                   "n_flushes_": r.n_flushes})

    def fit(self, dataset, spec: TrainerSpec) -> FitResult:
        return self._result(spec, trainer.fit(dataset, self._config(spec)))

    def fit_steps(self, dataset, spec: TrainerSpec, *, state=None):
        r = yield from trainer.fit_steps(dataset, self._config(spec),
                                         state=state)
        return self._result(spec, r)

    def predict(self, result: FitResult, X):
        return result.model.predict(np.asarray(X))

    def score(self, result: FitResult, X, y=None) -> float:
        """R^2 of the predicted ratings (regression convention)."""
        y = np.asarray(y, np.float64)
        pred = self.predict(result, X)
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum())
        return 1.0 - ss_res / max(ss_tot, 1e-12)


register_workload(EmbWorkload())
