"""EMB trainer: deferred-update embedding regression (DESIGN.md §15).

Model: rating(u, i) = <U[u], I[i]> — two bank-sharded embedding tables
(:class:`~repro.api.table.ShardedTable`) trained by minibatch SGD over
(user, item, rating) triples.  Two precisions, the paper's ladder:

  EMB-FP32   float32 tables and arithmetic (the processor-centric
             baseline precision).
  EMB-INT32  Q(frac_bits) fixed-point tables + arithmetic — the PIM
             version; every reduction is exact in int32, so serial,
             fused, deferred-D=1 and resumed runs are bit-identical.

Execution per step (the LazyDP flow on the System protocol):

  1. the minibatch's (user, item) ids + targets broadcast to the banks;
  2. every core answers a shard-local ``emb_gather`` against its
     placement map (zeros for rows it does not own) — ONE map_reduce
     whose fabric sum reconstructs the full gathered rows;
  3. the update math (predict, error, per-row deltas) runs in the
     shared ``update`` closure — the same jnp ops serve the serial
     loop and the fused :class:`StepProgram` scan;
  4. the sparse delta rows either apply immediately (eager,
     ``flush_every=1``) via ``emb_scatter_add``, or accumulate in the
     table's host-side staging ledger and flush every D batches as one
     deduplicated batched scatter-add (deferred — LazyDP).

Deferred semantics: within a window the gathers read the table as of
the last flush (updates are invisible until they apply — the relaxed
schedule PIM-Opt studies).  A window of D=1 therefore degenerates to
eager exactly: the ledger holds one batch, drains without dedup, and
ships through the SAME scatter kernel the eager path uses — asserted
bit-identical (tests/test_emb.py).  Fusion composes with windows, not
across them: a flush is a host-visible table write the next window
depends on, so chunks are clipped to flush boundaries (and eager mode,
a read-after-write per step, always runs the serial loop).

``TransferStats.flush_bytes`` counts the sparse update payload (ids +
delta rows) every apply ships — the counter the deferred-vs-eager
traffic claim (benchmarks/emb_bench.py) is made on; the payload is
also charged as cross-rank traffic on PIM targets.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fixed_point import _shift_round, from_fixed, to_fixed
from ..elastic.state import pack_rng, unpack_rng
from ..kernels import dispatch
from ..kernels.sparse_gather import IDX_PAD
from ..systems import ChunkTick, System, run_steps
from ..systems.compress import quantize_rows

VERSIONS = ("fp32", "int32")


@dataclasses.dataclass
class EmbConfig:
    version: str = "fp32"
    n_iters: int = 200       # minibatch SGD steps
    batch: int = 64
    dim: int = 8             # embedding width
    lr: float = 0.05
    frac_bits: int = 10      # Q format of the int32 tables/arithmetic
    #: D — deferred-update window in batches (LazyDP).  1 = eager
    #: (apply every step); D > 1 stages D batches in the table ledger
    #: and flushes once, deduplicated, per window.
    flush_every: int = 1
    #: force the staging-ledger path even at flush_every=1 (None = auto:
    #: deferred iff flush_every > 1).  The D=1 identity is asserted
    #: against this: staged-and-flushed D=1 == eager, bit for bit.
    deferred: Optional[bool] = None
    #: int8 + error-feedback compression of the flush payload
    #: (systems.compress.quantize_rows; residual rows re-stage into the
    #: next window — exact on the int32 version, see DESIGN.md §15.4)
    compress_flush: bool = False
    placement: str = "mod"   # ShardedTable placement map ("mod"|"hash")
    n_users: Optional[int] = None   # None = infer from the index pairs
    n_items: Optional[int] = None
    record_every: int = 0    # record batch MSE every this many steps
    seed: int = 0
    kernel_backend: Optional[str] = None
    #: step fusion within a deferred window (DESIGN.md §9/§15.3):
    #: chunks clip to flush boundaries; ignored in eager mode.
    fuse_steps: int = 1
    #: accepted for interface parity with the other trainers; deferred
    #: windows serialize on their flush, so chunks dispatch depth-1.
    pipeline_depth: int = 2


@dataclasses.dataclass
class EmbResult:
    user_emb: np.ndarray     # (n_users, dim) float32
    item_emb: np.ndarray     # (n_items, dim) float32
    user_raw: np.ndarray     # storage dtype (int32 Q(f) | float32)
    item_raw: np.ndarray
    history: list            # [(iter, batch MSE)] if record_every
    n_iters: int = 0
    n_flushes: int = 0

    def predict(self, pairs: np.ndarray) -> np.ndarray:
        p = np.asarray(pairs, np.int64)
        return np.sum(self.user_emb[p[:, 0]] * self.item_emb[p[:, 1]],
                      axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Per-core kernels (dispatch-routed through the sparse_gather family).
# ---------------------------------------------------------------------------

def build_emb_fwd(backend=None) -> Callable:
    """Forward leg: both tables' shard-local gathers + the target relay.

    ``lead`` is a sharded (1,)-per-core indicator (1 on shard 0) that
    lets the replicated targets ride the reduce tree exactly once —
    the fused scan has no other lane for per-step host values."""
    be = dispatch.resolve_backend(backend)

    def _fwd(Utab, Uids, Itab, Iids, lead, iu, ii, yb):
        u = dispatch.launch("emb_gather", Utab, Uids, iu, backend=be)
        i = dispatch.launch("emb_gather", Itab, Iids, ii, backend=be)
        return {"u": u, "i": i, "y": yb * lead[0]}
    return _fwd


def build_emb_apply(backend=None) -> Callable:
    """Update leg: duplicate-safe scatter-add of sparse delta rows into
    both tables; output stays bank-resident (map_elementwise)."""
    be = dispatch.resolve_backend(backend)

    def _apply(Utab, Uids, Itab, Iids, iu, du, ii, di):
        return {"u": dispatch.launch("emb_scatter_add", Utab, Uids,
                                     iu, du, backend=be),
                "i": dispatch.launch("emb_scatter_add", Itab, Iids,
                                     ii, di, backend=be)}
    return _apply


def fwd_kernel_name(cfg: EmbConfig) -> str:
    return (f"emb.fwd/{cfg.version}/f{cfg.frac_bits}"
            f"/{dispatch.backend_tag(cfg.kernel_backend)}")


def apply_kernel_name(cfg: EmbConfig) -> str:
    return (f"emb.apply/{cfg.version}"
            f"/{dispatch.backend_tag(cfg.kernel_backend)}")


def make_emb_step_fns(cfg: EmbConfig):
    """(prepare, update) closures of one EMB step — shared by the
    serial loop and the fused scan (they cannot drift numerically).

    ``update`` consumes the reduced {"u","i","y"} rows and emits the
    *signed* per-sample delta rows (lr folded in, rounding applied) plus
    the batch squared error: ``carry`` is just the step counter, since
    the model state lives in the sharded tables, not the carry."""
    f = cfg.frac_bits

    def prepare(carry):
        del carry  # the minibatch arrives as replicated args / scan xs
        return ()

    if cfg.version == "int32":
        lr_q = to_fixed(cfg.lr / cfg.batch, f)          # Q(f) scalar

        def update(carry, red):
            # host-strategy reduces arrive as promoted numpy int64;
            # jnp.asarray demotes to int32 (same convention as linreg)
            u = jnp.asarray(red["u"])
            i = jnp.asarray(red["i"])
            y = jnp.asarray(red["y"])
            pred = jnp.sum(_shift_round(u * i, f), axis=1)  # Q(f)
            err = pred - y                                  # Q(f)
            du = -_shift_round(lr_q * _shift_round(err[:, None] * i, f), f)
            di = -_shift_round(lr_q * _shift_round(err[:, None] * u, f), f)
            errf = err.astype(jnp.float32) * np.float32(2.0 ** -f)
            return carry + 1, (du, di, jnp.sum(errf * errf))
    else:
        s = jnp.float32(cfg.lr / cfg.batch)

        def update(carry, red):
            u = jnp.asarray(red["u"], jnp.float32)
            i = jnp.asarray(red["i"], jnp.float32)
            y = jnp.asarray(red["y"], jnp.float32)
            err = jnp.sum(u * i, axis=1) - y
            du = -(s * err[:, None] * i)
            di = -(s * err[:, None] * u)
            return carry + 1, (du, di, jnp.sum(err * err))
    return prepare, update


# ---------------------------------------------------------------------------
# Host-orchestrated training loop.
# ---------------------------------------------------------------------------

def fit_steps(dataset, cfg: Optional[EmbConfig] = None, *,
              state: Optional[dict] = None):
    """Generator form of EMB training; the EmbResult travels on
    StopIteration.  Yields one :class:`ChunkTick` per step (serial) or
    per fused chunk, each carrying a lazy chunk-boundary snapshot —
    tables serialize as size-independent (V, D) host rows plus the
    staging ledger, so a preempted fit resumes bit-identically on any
    slice width (DESIGN.md §11.2/§15.5)."""
    cfg = cfg or EmbConfig()
    assert cfg.version in VERSIONS, cfg.version
    pim = dataset.system
    pairs, y_f = dataset.emb_view()
    n = pairs.shape[0]
    n_users = int(cfg.n_users or pairs[:, 0].max() + 1)
    n_items = int(cfg.n_items or pairs[:, 1].max() + 1)
    f = cfg.frac_bits
    int_ver = cfg.version == "int32"
    D = max(1, int(cfg.flush_every))
    deferred = D > 1 if cfg.deferred is None else bool(cfg.deferred)
    y_host = np.asarray(to_fixed(y_f, f)) if int_ver else y_f

    history: list = []
    rng = np.random.RandomState(cfg.seed)
    it_done = 0
    # table init draws come FIRST on the rng stream; a resumed fit
    # restores the packed rng (already past them) and overrides the
    # init values with the checkpointed rows below.
    scale = np.float32(1.0 / np.sqrt(cfg.dim))
    Wu = (rng.rand(n_users, cfg.dim).astype(np.float32) - 0.5) * scale
    Wi = (rng.rand(n_items, cfg.dim).astype(np.float32) - 0.5) * scale
    utable = pim.put_table(Wu, placement=cfg.placement, seed=cfg.seed)
    itable = pim.put_table(Wi, placement=cfg.placement, seed=cfg.seed + 1)

    if state is not None:
        arrays, meta = state["arrays"], state["meta"]
        it_done = int(meta["iters"])
        history = [tuple(h) for h in meta.get("history", [])]
        rng = unpack_rng(arrays, meta) or rng
        Ut = utable.place_rows(arrays["u_tab"])
        It = itable.place_rows(arrays["i_tab"])
        Uids = utable.ids_device()
        Iids = itable.ids_device()
        utable.restore_pending(arrays["pend_u_idx"], arrays["pend_u_upd"],
                               int(meta.get("pend_u_batches", 0)))
        itable.restore_pending(arrays["pend_i_idx"], arrays["pend_i_upd"],
                               int(meta.get("pend_i_batches", 0)))
    else:
        Ut, Uids = utable.view(cfg.version, f)
        It, Iids = itable.view(cfg.version, f)

    lead_host = np.zeros(pim.n_shards, np.int32 if int_ver else np.float32)
    lead_host[0] = 1
    lead = pim.shard_rows(lead_host)

    prepare, update = make_emb_step_fns(cfg)
    update_j = jax.jit(update)
    fwd_k = pim.named_kernel(fwd_kernel_name(cfg),
                             lambda: build_emb_fwd(cfg.kernel_backend))
    apply_k = pim.named_kernel(apply_kernel_name(cfg),
                               lambda: build_emb_apply(cfg.kernel_backend))
    n_flushes = 0

    def draw():
        rows = rng.randint(0, n, size=cfg.batch)
        return (pairs[rows, 0].copy(), pairs[rows, 1].copy(),
                y_host[rows].copy())

    def record(it, sq):
        if cfg.record_every and (it % cfg.record_every == 0
                                 or it == cfg.n_iters):
            history.append((it, float(sq) / cfg.batch))

    def _pad_flush(idx, upd):
        """Pad a flush batch up to a multiple of cfg.batch (sentinel
        ids, zero rows — exact no-ops in the scatter) so the apply
        kernel sees at most a few distinct shapes per fit."""
        m = int(idx.shape[0])
        bucket = max(cfg.batch, -(-m // cfg.batch) * cfg.batch)
        if bucket == m:
            return idx, upd
        pad_i = np.full(bucket - m, IDX_PAD, np.int32)
        pad_u = np.zeros((bucket - m, upd.shape[1]), upd.dtype)
        return (np.concatenate([np.asarray(idx), pad_i]),
                np.concatenate([np.asarray(upd), pad_u]))

    def _apply_rows(iu, du, ii, di):
        """One batched scatter-add of sparse delta rows into both
        tables (eager apply AND deferred flush — one code path)."""
        nonlocal Ut, It, n_flushes
        payload = int(iu.nbytes + du.nbytes + ii.nbytes + di.nbytes)
        pim.stats.flush_bytes += payload
        # the sparse update leg crosses rank boundaries on its way to
        # the owning banks (no-op charge on host targets)
        pim._charge_topology(0, payload)
        iu, du = _pad_flush(iu, du)
        ii, di = _pad_flush(ii, di)
        out = pim.map_elementwise(
            apply_k, (Ut, Uids, It, Iids),
            (jnp.asarray(iu), jnp.asarray(du),
             jnp.asarray(ii), jnp.asarray(di)))
        Ut, It = out["u"], out["i"]
        n_flushes += 1

    def _compressed(table, idx, upd):
        """int8 the flush rows; the residual re-stages as sparse error
        feedback for the next window (exact integer EF on int32)."""
        q, scales, deq, residual = quantize_rows(np.asarray(upd))
        pim.stats.compressed_bytes += (q.nbytes + scales.nbytes
                                       + np.asarray(idx).nbytes)
        if residual.any():
            table.stage(idx, residual)
        return deq

    def _flush_window():
        """Drain both ledgers into one batched scatter-add.  A single
        staged batch (the D=1 identity) skips dedup entirely: it ships
        verbatim through the same kernel call eager would make."""
        dedup = max(utable.pending_batches, itable.pending_batches) > 1
        iu, du = utable.drain(dedup=dedup)
        ii, di = itable.drain(dedup=dedup)
        if iu.size == 0 and ii.size == 0:
            return
        if cfg.compress_flush:
            du = _compressed(utable, iu, du)
            di = _compressed(itable, ii, di)
        _apply_rows(iu, du, ii, di)

    def _snapshot():
        ra, rm = pack_rng(rng)
        pu_idx, pu_upd = utable.pending_arrays()
        pi_idx, pi_upd = itable.pending_arrays()
        arrays = {"u_tab": utable.unshard(np.asarray(Ut)),
                  "i_tab": itable.unshard(np.asarray(It)),
                  "pend_u_idx": pu_idx, "pend_u_upd": pu_upd,
                  "pend_i_idx": pi_idx, "pend_i_upd": pi_upd}
        arrays.update(ra)
        meta = {"iters": int(it_done),
                "history": [[int(i), None if m is None else float(m)]
                            for i, m in history],
                "pend_u_batches": int(utable.pending_batches),
                "pend_i_batches": int(itable.pending_batches)}
        meta.update(rm)
        return {"arrays": arrays, "meta": meta}

    sharded = lambda: (Ut, Uids, It, Iids, lead)  # noqa: E731

    if deferred and cfg.fuse_steps > 1:
        # fused deferred windows: D steps of gather+update compile into
        # lax.scan chunks (tables frozen within the window — exactly
        # the deferred semantics), delta rows ride out as scan emits
        C = pim.n_shards

        def select(shards, x):
            iu, ii, yb = x
            bc = lambda v: jnp.broadcast_to(  # noqa: E731
                v, (C,) + v.shape)
            return (*shards, bc(iu), bc(ii), bc(yb))

        program = pim.step_program(
            fwd_k, prepare, update,
            name=(f"emb.step/{fwd_kernel_name(cfg)}/lr{cfg.lr}"
                  f"/b{cfg.batch}/D{D}"),
            select=select)
        it = it_done
        carry = jnp.int32(it_done)
        while it < cfg.n_iters:
            window_end = min(cfg.n_iters, it + (D - it % D))
            k = min(cfg.fuse_steps, window_end - it)
            if cfg.record_every:
                nxt = (it // cfg.record_every + 1) * cfg.record_every
                k = min(k, nxt - it)
            batches = [draw() for _ in range(k)]
            xs = tuple(jnp.asarray(np.stack([b[j] for b in batches]))
                       for j in range(3))
            if getattr(pim, "kind", None) == "pim":
                # the per-step minibatch legs cross host->bank exactly
                # as the serial loop's broadcast does
                pim.stats.cpu_to_pim += (
                    sum(int(v.nbytes) for v in xs) * pim.config.n_cores)
            carry, outs = program.run(carry, sharded(), k, xs=xs)
            du_k, di_k, sq_k = (np.asarray(o) for o in outs)
            for j in range(k):
                utable.stage(batches[j][0], du_k[j])
                itable.stage(batches[j][1], di_k[j])
                record(it + j + 1, sq_k[j])
            it += k
            it_done = it
            if it % D == 0 or it == cfg.n_iters:
                _flush_window()
            yield ChunkTick(k, _snapshot)
    else:
        for it in range(it_done, cfg.n_iters):
            iu, ii, yb = draw()
            rep = pim.broadcast((jnp.asarray(iu), jnp.asarray(ii),
                                 jnp.asarray(yb)))
            red = pim.map_reduce(fwd_k, sharded(), tuple(rep))
            _, (du, di, sq) = update_j(jnp.int32(it), red)
            if deferred:
                utable.stage(iu, np.asarray(du))
                itable.stage(ii, np.asarray(di))
                if (it + 1) % D == 0 or it + 1 == cfg.n_iters:
                    _flush_window()
            else:
                _apply_rows(iu, du, ii, di)
            it_done = it + 1
            record(it_done, sq)
            yield ChunkTick(1, _snapshot)

    u_raw = utable.unshard(np.asarray(Ut))
    i_raw = itable.unshard(np.asarray(It))
    if int_ver:
        u_emb = np.asarray(from_fixed(u_raw, f), np.float32)
        i_emb = np.asarray(from_fixed(i_raw, f), np.float32)
    else:
        u_emb, i_emb = u_raw, i_raw
    return EmbResult(user_emb=u_emb, item_emb=i_emb, user_raw=u_raw,
                     item_raw=i_raw, history=history,
                     n_iters=cfg.n_iters, n_flushes=n_flushes)


def fit(dataset, cfg: Optional[EmbConfig] = None) -> EmbResult:
    """Train EMB over a bank-resident dataset + sharded tables; the
    table placements are paid once and the per-step traffic is sparse
    ids/rows only — the LazyDP execution model end to end."""
    return run_steps(fit_steps(dataset, cfg))
