"""EMB: bank-sharded embedding training with deferred sparse updates.

The repo's first sparse, irregular-access workload family (DESIGN.md
§15): dot-product embedding regression over (user, item) index pairs —
the memory-bound recsys pattern LazyDP (ASPLOS'24) accelerates with
lazily deferred gradient updates, reproduced here on the System
protocol with the ``sparse_gather`` Pallas kernel family.
"""
from .trainer import (EmbConfig, EmbResult, VERSIONS, fit,  # noqa: F401
                      fit_steps)
