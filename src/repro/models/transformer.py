"""Generic decoder LM assembling all block families (DESIGN.md §3).

Layer stacking: the per-layer pattern (configs.base.layer_pattern) is
factored into its repeating *unit* (e.g. 4x attn + 1x cross for the VLM;
7x mLSTM + 1x sLSTM for xLSTM) and the trainer ``lax.scan``s over unit
repetitions with stacked parameters — HLO stays unit-sized regardless of
depth, which keeps the 40-cell dry-run compile tractable.

Heterogeneous per-layer attention windows (hymba's 3 global layers) ride
through the scan as a traced per-layer int array (0 == full attention).

Modes: train forward (+aux losses), prefill (writes KV caches), decode
(single token, O(1) state for SSM blocks).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.act_sharding import constrain
from . import ssm as ssm_mod
from .attention import (AttnSpec, KVCache, attention, attention_decode,
                        cross_attention, init_attention, init_kv_cache,
                        plan_heads)
from .layers import dense_init, embed_init, init_mlp, mlp, rms_norm
from .moe import MoeSpec, init_moe, moe_apply, pad_experts

FULL_WINDOW = jnp.int32(1 << 30)


# ---------------------------------------------------------------------------
# Specs derived from the config.
# ---------------------------------------------------------------------------

def attn_spec(cfg: ArchConfig, tp: int = 16) -> AttnSpec:
    return AttnSpec(
        d_model=cfg.d_model,
        plan=plan_heads(cfg.n_heads, cfg.n_kv_heads, tp),
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        rope_fraction=cfg.rope_fraction, rope_theta=cfg.rope_theta,
        norm_eps=cfg.norm_eps,
        kv_dim=cfg.vision_dim or 0)


def moe_spec(cfg: ArchConfig, ep: int = 16) -> MoeSpec:
    return MoeSpec(
        d_model=cfg.d_model,
        n_experts=pad_experts(cfg.n_experts, ep),
        n_experts_real=cfg.n_experts,
        top_k=cfg.n_experts_per_tok, d_ff=cfg.moe_d_ff,
        capacity_factor=cfg.moe_capacity_factor,
        activation=cfg.activation, dispatch=cfg.moe_dispatch,
        groups=cfg.moe_groups)


def mlstm_spec(cfg: ArchConfig) -> ssm_mod.MlstmSpec:
    return ssm_mod.MlstmSpec(d_model=cfg.d_model, n_heads=cfg.n_heads,
                             proj_factor=cfg.ssm_proj_factor)


def slstm_spec(cfg: ArchConfig) -> ssm_mod.SlstmSpec:
    return ssm_mod.SlstmSpec(d_model=cfg.d_model, n_heads=cfg.n_heads)


def ssm_spec(cfg: ArchConfig) -> ssm_mod.SsmSpec:
    return ssm_mod.SsmSpec(
        d_model=cfg.d_model,
        d_inner=int(cfg.d_model * cfg.ssm_proj_factor),
        d_state=cfg.ssm_state or 16)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Block init / apply dispatch.
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, bt: str):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    norm = lambda: jnp.ones((d,), dt)
    if bt == "attn":
        return {"norm1": norm(), "attn": init_attention(ks[0],
                                                        attn_spec(cfg), dt),
                "norm2": norm(),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, dt)}
    if bt == "moe":
        p = {"norm1": norm(), "attn": init_attention(ks[0],
                                                     attn_spec(cfg), dt),
             "norm2": norm(), "moe": init_moe(ks[1], moe_spec(cfg), dt)}
        if cfg.shared_expert_d_ff:
            p["shared"] = init_mlp(ks[2], d, cfg.shared_expert_d_ff, dt)
        return p
    if bt == "mlstm":
        return {"norm1": norm(),
                "mlstm": ssm_mod.init_mlstm(ks[0], mlstm_spec(cfg), dt)}
    if bt == "slstm":
        return {"norm1": norm(),
                "slstm": ssm_mod.init_slstm(ks[0], slstm_spec(cfg), dt)}
    if bt == "hymba":
        return {"norm1": norm(),
                "attn": init_attention(ks[0], attn_spec(cfg), dt),
                "ssm": ssm_mod.init_ssm(ks[1], ssm_spec(cfg), dt),
                "attn_norm": norm(), "ssm_norm": norm(),
                "norm2": norm(),
                "mlp": init_mlp(ks[2], d, cfg.d_ff, dt)}
    if bt == "cross":
        return {"norm1": norm(),
                "cross": init_attention(ks[0], attn_spec(cfg), dt,
                                        cross=True),
                "norm2": norm(),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, dt),
                "gate_attn": jnp.zeros((), jnp.float32),
                "gate_mlp": jnp.zeros((), jnp.float32)}
    raise ValueError(bt)


def apply_block_train(p, cfg: ArchConfig, bt: str, x, positions, window,
                      extras) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (x, aux_loss)."""
    q = cfg.quantize_dense
    lut = cfg.lut_activations
    zero = jnp.float32(0.0)
    win = window  # traced int32; FULL_WINDOW means unbounded
    win_opt = None if bt == "cross" else win
    if bt in ("attn", "moe"):
        h = attention(p["attn"], attn_spec(cfg), rms_norm(x, p["norm1"]),
                      positions, window=win_opt)
        x = x + h
        if bt == "attn":
            x = x + mlp(p["mlp"], rms_norm(x, p["norm2"]),
                        cfg.activation, lut, q)
            return x, zero
        y = rms_norm(x, p["norm2"])
        mo, aux = moe_apply(p["moe"], moe_spec(cfg), y, lut)
        if "shared" in p:
            mo = mo + mlp(p["shared"], y, cfg.activation, lut, q)
        return x + mo, aux
    if bt == "mlstm":
        return x + ssm_mod.mlstm_chunkwise(
            p["mlstm"], mlstm_spec(cfg), rms_norm(x, p["norm1"])), zero
    if bt == "slstm":
        return x + ssm_mod.slstm_apply(
            p["slstm"], slstm_spec(cfg), rms_norm(x, p["norm1"])), zero
    if bt == "hymba":
        y = rms_norm(x, p["norm1"])
        ha = attention(p["attn"], attn_spec(cfg), y, positions,
                       window=win_opt)
        hs = ssm_mod.ssm_apply(p["ssm"], ssm_spec(cfg), y)
        h = 0.5 * (rms_norm(ha, p["attn_norm"])
                   + rms_norm(hs, p["ssm_norm"]))
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(x, p["norm2"]),
                    cfg.activation, lut, q)
        return x, zero
    if bt == "cross":
        kv = extras["cross_states"]
        h = cross_attention(p["cross"], attn_spec(cfg),
                            rms_norm(x, p["norm1"]), kv)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h2 = mlp(p["mlp"], rms_norm(x, p["norm2"]), cfg.activation, lut, q)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h2
        return x, zero
    raise ValueError(bt)


# -- caches -------------------------------------------------------------------

def init_block_cache(cfg: ArchConfig, bt: str, batch: int, max_seq: int):
    dt = _dtype(cfg)
    spec = attn_spec(cfg)
    if bt in ("attn", "moe", "hymba"):
        c = {"kv": init_kv_cache(batch, spec.plan, spec.head_dim,
                                 max_seq, dt, bits=cfg.kv_cache_bits)}
        if bt == "hymba":
            c["ssm"] = ssm_mod.ssm_state_init(batch, ssm_spec(cfg), dt)
        return c
    if bt == "mlstm":
        return {"mlstm": ssm_mod.mlstm_state_init(batch, mlstm_spec(cfg),
                                                  dt)}
    if bt == "slstm":
        return {"slstm": ssm_mod.slstm_state_init(batch, slstm_spec(cfg))}
    if bt == "cross":
        # cross K/V computed once at prefill from the vision/encoder states
        sk = cfg.vision_tokens or cfg.encoder_seq
        shape = (batch, attn_spec(cfg).plan.n_kv, sk, spec.head_dim)
        return {"ck": jnp.zeros(shape, dt), "cv": jnp.zeros(shape, dt)}
    raise ValueError(bt)


def _cross_kv(p, spec: AttnSpec, kv_states, dtype):
    b, sk, _ = kv_states.shape
    k = (kv_states.astype(dtype) @ p["wk"].astype(dtype))
    v = (kv_states.astype(dtype) @ p["wv"].astype(dtype))
    if spec.qkv_bias:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    k = k.reshape(b, sk, spec.plan.n_kv, spec.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, sk, spec.plan.n_kv, spec.head_dim).transpose(0, 2, 1, 3)
    return k, v


def apply_block_decode(p, cfg: ArchConfig, bt: str, x, cache, window,
                       extras):
    """Single-token step.  -> (x, new_cache)."""
    q = cfg.quantize_dense
    lut = cfg.lut_activations
    win_opt = window
    if bt in ("attn", "moe"):
        h, kv = attention_decode(p["attn"], attn_spec(cfg),
                                 rms_norm(x, p["norm1"]), cache["kv"],
                                 window=win_opt)
        x = x + h
        if bt == "attn":
            x = x + mlp(p["mlp"], rms_norm(x, p["norm2"]),
                        cfg.activation, lut, q)
            return x, {"kv": kv}
        y = rms_norm(x, p["norm2"])
        mo, _ = moe_apply(p["moe"], moe_spec(cfg), y, lut)
        if "shared" in p:
            mo = mo + mlp(p["shared"], y, cfg.activation, lut, q)
        return x + mo, {"kv": kv}
    if bt == "mlstm":
        h, st = ssm_mod.mlstm_decode_step(
            p["mlstm"], mlstm_spec(cfg), rms_norm(x, p["norm1"]),
            cache["mlstm"])
        return x + h, {"mlstm": st}
    if bt == "slstm":
        h, st = ssm_mod.slstm_decode_step(
            p["slstm"], slstm_spec(cfg), rms_norm(x, p["norm1"]),
            cache["slstm"])
        return x + h, {"slstm": st}
    if bt == "hymba":
        y = rms_norm(x, p["norm1"])
        ha, kv = attention_decode(p["attn"], attn_spec(cfg), y,
                                  cache["kv"], window=win_opt)
        hs, st = ssm_mod.ssm_decode_step(p["ssm"], ssm_spec(cfg), y,
                                         cache["ssm"])
        h = 0.5 * (rms_norm(ha, p["attn_norm"])
                   + rms_norm(hs, p["ssm_norm"]))
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(x, p["norm2"]),
                    cfg.activation, lut, q)
        return x, {"kv": kv, "ssm": st}
    if bt == "cross":
        spec = attn_spec(cfg)
        from .attention import _sdpa
        y = rms_norm(x, p["norm1"])
        b, s, _ = y.shape
        qh = (y @ p["cross"]["wq"].astype(y.dtype))
        if spec.qkv_bias:
            qh = qh + p["cross"]["bq"].astype(y.dtype)
        qh = qh.reshape(b, s, spec.plan.n_q,
                        spec.head_dim).transpose(0, 2, 1, 3)
        if spec.qk_norm:
            qh = rms_norm(qh, p["cross"]["q_norm"], spec.norm_eps)
        out = _sdpa(qh, cache["ck"], cache["cv"], causal=False)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
        h = out @ p["cross"]["wo"].astype(y.dtype)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h2 = mlp(p["mlp"], rms_norm(x, p["norm2"]), cfg.activation, lut, q)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h2
        return x, dict(cache)
    raise ValueError(bt)


# ---------------------------------------------------------------------------
# Whole-model init / apply with unit scan.
# ---------------------------------------------------------------------------

def unit_pattern(cfg: ArchConfig) -> tuple[tuple[str, ...], int]:
    """(repeating unit, reps)."""
    pattern = cfg.layer_pattern()
    n = len(pattern)
    for p in range(1, n + 1):
        if n % p == 0 and pattern == pattern[:p] * (n // p):
            return pattern[:p], n // p
    return pattern, 1


def init_lm(cfg: ArchConfig, key) -> dict:
    dt = _dtype(cfg)
    unit, reps = unit_pattern(cfg)
    keys = jax.random.split(key, 4 + len(unit))
    params: dict[str, Any] = {
        "tok_emb": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dt),
    }
    if cfg.meta_tokens:
        params["meta"] = (jax.random.normal(
            keys[2], (cfg.meta_tokens, cfg.d_model), jnp.float32)
            * 0.02).astype(dt)
    unit_params = []
    for i, bt in enumerate(unit):
        rep_keys = jax.random.split(keys[4 + i], reps)
        unit_params.append(jax.vmap(
            lambda k, bt=bt: init_block(k, cfg, bt))(rep_keys))
    params["unit"] = tuple(unit_params)
    return params


def _windows_stacked(cfg: ArchConfig, unit_len: int, reps: int):
    wins = [w if w else int(FULL_WINDOW) for w in cfg.layer_windows()]
    return jnp.asarray(np.array(wins, np.int32).reshape(reps, unit_len))


def _embed(cfg: ArchConfig, params, tokens):
    x = params["tok_emb"][tokens]
    if cfg.meta_tokens:
        b = tokens.shape[0]
        meta = jnp.broadcast_to(params["meta"][None],
                                (b,) + params["meta"].shape).astype(x.dtype)
        x = jnp.concatenate([meta, x], axis=1)
    return x


def lm_forward(cfg: ArchConfig, params, tokens: jnp.ndarray,
               extras: Optional[dict] = None):
    """Training forward: tokens [B, S] -> (logits [B, S, Vpad], aux)."""
    extras = extras or {}
    unit, reps = unit_pattern(cfg)
    x = constrain(_embed(cfg, params, tokens), "btd")
    s_total = x.shape[1]
    positions = jnp.arange(s_total, dtype=jnp.int32)[None]
    windows = _windows_stacked(cfg, len(unit), reps)

    windowed = cfg.sliding_window > 0

    def unit_body(carry, xs):
        h, aux = carry
        unit_p, wins = xs
        for i, bt in enumerate(unit):
            win = wins[i] if windowed else None  # static fast path
            h = constrain(h, "btd")
            h, a = apply_block_train(unit_p[i], cfg, bt, h, positions,
                                     win, extras)
            aux = aux + a
        return (h, aux), None

    body = unit_body
    if cfg.remat == "full":
        body = jax.checkpoint(unit_body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               (params["unit"], windows))
    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain(x @ params["lm_head"].astype(x.dtype), "btv")
    return logits, aux


def lm_loss(cfg: ArchConfig, params, tokens: jnp.ndarray,
            targets: jnp.ndarray, extras: Optional[dict] = None,
            aux_weight: float = 0.01):
    logits, aux = lm_forward(cfg, params, tokens, extras)
    logits = logits.astype(jnp.float32)
    # mask padded vocab columns
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    unit, reps = unit_pattern(cfg)
    if cfg.meta_tokens:
        max_seq = max_seq + cfg.meta_tokens

    def stack_cache(bt):
        one = init_block_cache(cfg, bt, batch, max_seq)
        return jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v[None], (reps,) + v.shape), one)

    return tuple(stack_cache(bt) for bt in unit)


def lm_prefill(cfg: ArchConfig, params, tokens: jnp.ndarray, max_seq: int,
               extras: Optional[dict] = None):
    """Run the full prompt, returning (last-token logits, filled cache).

    Implemented as chained decode over the training forward's k/v:
    for simplicity and HLO size we run the parallel forward per block and
    materialize its k/v into the cache (standard prefill)."""
    extras = extras or {}
    unit, reps = unit_pattern(cfg)
    x = _embed(cfg, params, tokens)
    b, s_total, _ = x.shape
    positions = jnp.arange(s_total, dtype=jnp.int32)[None]
    windows = _windows_stacked(cfg, len(unit), reps)
    cache_max = max_seq + (cfg.meta_tokens or 0)

    windowed = cfg.sliding_window > 0

    def unit_body(x, xs):
        unit_p, wins = xs
        new_caches = []
        for i, bt in enumerate(unit):
            win = wins[i] if windowed else None
            x = constrain(x, "btd")
            x, c = _prefill_block(unit_p[i], cfg, bt, x, positions,
                                  win, extras, cache_max)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, caches = jax.lax.scan(unit_body, x, (params["unit"], windows))
    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1:] @ params["lm_head"].astype(x.dtype)
    return logits, caches


def _prefill_block(p, cfg, bt, x, positions, window, extras, cache_max):
    """Forward one block while materializing its decode cache."""
    b = x.shape[0]
    spec = attn_spec(cfg)
    dt = _dtype(cfg)
    s_total = x.shape[1]
    if bt in ("attn", "moe", "hymba"):
        from .attention import _project_qkv, quantize_kv
        y = rms_norm(x, p["norm1"])
        qh, kh, vh = _project_qkv(p["attn"], spec, y, positions)
        kv_shape = (b, spec.plan.n_kv, cache_max, spec.head_dim)
        if cfg.kv_cache_bits == 8:
            kq, ks = quantize_kv(kh)
            vq, vs = quantize_kv(vh)
            kpad = jax.lax.dynamic_update_slice(
                jnp.zeros(kv_shape, jnp.int8), kq, (0, 0, 0, 0))
            vpad = jax.lax.dynamic_update_slice(
                jnp.zeros(kv_shape, jnp.int8), vq, (0, 0, 0, 0))
            kspad = jax.lax.dynamic_update_slice(
                jnp.ones(kv_shape[:-1], jnp.float32), ks, (0, 0, 0))
            vspad = jax.lax.dynamic_update_slice(
                jnp.ones(kv_shape[:-1], jnp.float32), vs, (0, 0, 0))
            kv = KVCache(kpad, vpad, jnp.int32(s_total), kspad, vspad)
        else:
            kpad = jax.lax.dynamic_update_slice(
                jnp.zeros(kv_shape, dt), kh.astype(dt), (0, 0, 0, 0))
            vpad = jax.lax.dynamic_update_slice(
                jnp.zeros(kv_shape, dt), vh.astype(dt), (0, 0, 0, 0))
            kv = KVCache(kpad, vpad, jnp.int32(s_total))
        cache = {"kv": kv}
        win = None if bt == "cross" else window
        from .attention import _sdpa
        att = _sdpa(qh, kh, vh, causal=True, window=win)
        att = att.transpose(0, 2, 1, 3).reshape(b, s_total, -1)
        h = att @ p["attn"]["wo"].astype(x.dtype)
        if bt == "hymba":
            # SSM path: parallel scan for outputs + final state for cache
            hs, st = _ssm_prefill(p["ssm"], cfg, y)
            h = 0.5 * (rms_norm(h, p["attn_norm"])
                       + rms_norm(hs, p["ssm_norm"]))
            cache["ssm"] = st
        x = x + h
        lut, q = cfg.lut_activations, cfg.quantize_dense
        if bt == "attn" or bt == "hymba":
            x = x + mlp(p["mlp"], rms_norm(x, p["norm2"]),
                        cfg.activation, lut, q)
        else:
            y2 = rms_norm(x, p["norm2"])
            mo, _ = moe_apply(p["moe"], moe_spec(cfg), y2, lut)
            if "shared" in p:
                mo = mo + mlp(p["shared"], y2, cfg.activation, lut, q)
            x = x + mo
        return x, cache
    if bt == "mlstm":
        h, st = _mlstm_prefill(p["mlstm"], cfg, rms_norm(x, p["norm1"]))
        return x + h, {"mlstm": st}
    if bt == "slstm":
        h, st = _slstm_prefill(p["slstm"], cfg, rms_norm(x, p["norm1"]))
        return x + h, {"slstm": st}
    if bt == "cross":
        kv_states = extras["cross_states"]
        ck, cv = _cross_kv(p["cross"], spec, kv_states, dt)
        x, _ = apply_block_train(p, cfg, bt, x, positions, window, extras)
        return x, {"ck": ck, "cv": cv}
    raise ValueError(bt)


def _ssm_prefill(params, cfg, y):
    """Parallel SSM over the prompt + final recurrent state."""
    spec = ssm_spec(cfg)
    out = ssm_mod.ssm_apply(params, spec, y)
    # final state: run the recurrence on the last conv window only is NOT
    # sufficient (state accumulates); recompute via associative scan
    u0 = y @ params["w_in"].astype(y.dtype)
    u = jax.nn.silu(ssm_mod.causal_conv1d(u0, params["conv_w"]))
    dA, dBu, _ = ssm_mod._ssm_inputs(params, spec, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hh = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    st = ssm_mod.SsmState(h=hh[:, -1],
                          conv=u0[:, -(spec.conv_width - 1):].astype(
                              u0.dtype))
    return out, st


def _mlstm_prefill(params, cfg, y):
    spec = mlstm_spec(cfg)
    out = ssm_mod.mlstm_chunkwise(params, spec, y)
    # final recurrent state by replaying decode on the last position only
    # would be wrong; recompute states by chunk scan (same code path with
    # state output).  Cheap approximation: run decode steps over the last
    # chunk after bulk-scanning prior chunks is an optimization; here we
    # scan all steps recurrently for state only (compiled once; serving
    # prefill for ssm archs is linear anyway).
    b, s, _ = y.shape
    st = ssm_mod.mlstm_state_init(b, spec, y.dtype)

    def step(st, yt):
        _, st2 = ssm_mod.mlstm_decode_step(params, spec, yt[:, None], st)
        return st2, 0

    st, _ = jax.lax.scan(step, st, y.swapaxes(0, 1))
    return out, st


def _slstm_prefill(params, cfg, y):
    spec = slstm_spec(cfg)
    b, s, _ = y.shape
    xp = y.astype(jnp.float32) @ params["w_x"]
    st0 = ssm_mod.slstm_state_init(b, spec)

    def step(st, xt):
        h, st2 = ssm_mod._slstm_cell(params, spec, xt, st)
        return st2, h

    st, hs = jax.lax.scan(step, st0, xp.swapaxes(0, 1))
    hs = rms_norm(hs.swapaxes(0, 1), params["norm"])
    out = hs.astype(y.dtype) @ params["w_out"].astype(y.dtype)
    return out, st


def lm_decode_step(cfg: ArchConfig, params, tokens: jnp.ndarray, caches,
                   extras: Optional[dict] = None):
    """tokens [B, 1] -> (logits [B, 1, Vpad], new caches)."""
    extras = extras or {}
    unit, reps = unit_pattern(cfg)
    x = params["tok_emb"][tokens]
    windows = _windows_stacked(cfg, len(unit), reps)

    windowed = cfg.sliding_window > 0

    def unit_body(x, xs):
        unit_p, unit_c, wins = xs
        new_caches = []
        for i, bt in enumerate(unit):
            win = wins[i] if windowed else None
            x = constrain(x, "btd")
            x, c = apply_block_decode(unit_p[i], cfg, bt, x, unit_c[i],
                                      win, extras)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(unit_body, x,
                                 (params["unit"], caches, windows))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, new_caches


def param_shapes(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs without allocation (for the dry-run)."""
    return jax.eval_shape(lambda k: init_lm(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(cfg: ArchConfig) -> int:
    tree = param_shapes(cfg)
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(tree))
