"""Unified model facade over all architecture families + input_specs.

``Model(cfg)`` exposes init/loss/forward/prefill/decode_step uniformly;
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the lowered step (weak-type-correct, shardable, no allocation) —
the dry-run contract (deliverable (e)).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from . import encdec, transformer


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.is_encdec = cfg.family == "audio"

    # -- init -----------------------------------------------------------------
    def init(self, key):
        if self.is_encdec:
            return encdec.init_encdec(self.cfg, key)
        return transformer.init_lm(self.cfg, key)

    def param_shapes(self):
        return jax.eval_shape(
            lambda k: self.init(k), jax.ShapeDtypeStruct((2,), jnp.uint32))

    # -- training -------------------------------------------------------------
    def loss(self, params, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        if self.is_encdec:
            return encdec.encdec_loss(cfg, params, batch["frames"],
                                      batch["tokens"], batch["targets"])
        extras = {}
        if cfg.family == "vlm":
            extras["cross_states"] = batch["vision"]
        return transformer.lm_loss(cfg, params, batch["tokens"],
                                   batch["targets"], extras)

    def forward(self, params, batch: dict):
        cfg = self.cfg
        if self.is_encdec:
            enc = encdec.encode(cfg, params, batch["frames"])
            return encdec.decoder_forward(cfg, params, batch["tokens"], enc)
        extras = {}
        if cfg.family == "vlm":
            extras["cross_states"] = batch["vision"]
        logits, _ = transformer.lm_forward(cfg, params, batch["tokens"],
                                           extras)
        return logits

    # -- serving ----------------------------------------------------------------
    def prefill(self, params, batch: dict, max_seq: int):
        cfg = self.cfg
        if self.is_encdec:
            return encdec.encdec_prefill(cfg, params, batch["frames"],
                                         batch["tokens"], max_seq)
        extras = {}
        if cfg.family == "vlm":
            extras["cross_states"] = batch["vision"]
        return transformer.lm_prefill(cfg, params, batch["tokens"],
                                      max_seq, extras)

    def decode_step(self, params, tokens, cache, extras=None):
        cfg = self.cfg
        if self.is_encdec:
            return encdec.encdec_decode_step(cfg, params, tokens, cache)
        logits, cache = transformer.lm_decode_step(cfg, params, tokens,
                                                   cache, extras or {})
        return logits, cache

    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        if self.is_encdec:
            return encdec.init_dec_cache(cfg, batch, max_seq)
        return transformer.init_cache(cfg, batch, max_seq)

    def cache_shapes(self, batch: int, max_seq: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for the step's data inputs.

    train   : {tokens, targets (+vision/frames)}
    prefill : {tokens (+vision/frames)}
    decode  : {tokens [B,1], cache}
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = jnp.int32
    model = Model(cfg)
    if shape.kind == "train":
        spec = {"tokens": _sds((b, s), tok), "targets": _sds((b, s), tok)}
        if cfg.family == "vlm":
            spec["vision"] = _sds((b, cfg.vision_tokens, cfg.vision_dim), dt)
        if cfg.family == "audio":
            spec["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), dt)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": _sds((b, s), tok)}
        if cfg.family == "vlm":
            spec["vision"] = _sds((b, cfg.vision_tokens, cfg.vision_dim), dt)
        if cfg.family == "audio":
            spec["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), dt)
        return spec
    # decode: one new token against a seq_len KV cache
    cache = model.cache_shapes(b, s)
    return {"tokens": _sds((b, 1), tok), "cache": cache}
