"""Whisper-style encoder-decoder backbone (whisper-tiny).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, encoder_seq, d_model].
Sinusoidal positions (computed on the fly) extend to the assigned decoder
lengths.  Encoder blocks: bidirectional self-attn + MLP; decoder blocks:
causal self-attn + cross-attn + MLP (pre-LayerNorm).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .attention import (AttnSpec, KVCache, _project_qkv, _sdpa,
                        attention_decode, init_attention, init_kv_cache)
from .layers import (dense_init, embed_init, layer_norm, mlp, init_mlp,
                     sinusoidal_positions)
from .transformer import _cross_kv, attn_spec, _dtype


def _ln_params(d, dt):
    return {"w": jnp.ones((d,), dt), "b": jnp.zeros((d,), dt)}


def _ln(x, p, eps):
    return layer_norm(x, p["w"], p["b"], eps)


def init_enc_block(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    return {"ln1": _ln_params(cfg.d_model, dt),
            "attn": init_attention(ks[0], attn_spec(cfg), dt),
            "ln2": _ln_params(cfg.d_model, dt),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt, gated=False)}


def init_dec_block(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    return {"ln1": _ln_params(cfg.d_model, dt),
            "self": init_attention(ks[0], attn_spec(cfg), dt),
            "ln2": _ln_params(cfg.d_model, dt),
            "cross": init_attention(ks[1], attn_spec(cfg), dt, cross=True),
            "ln3": _ln_params(cfg.d_model, dt),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dt, gated=False)}


def init_encdec(cfg: ArchConfig, key) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "tok_emb": embed_init(ks[2], cfg.padded_vocab, cfg.d_model, dt),
        "enc": jax.vmap(lambda k: init_enc_block(k, cfg))(enc_keys),
        "dec": jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys),
        "enc_ln": _ln_params(cfg.d_model, dt),
        "dec_ln": _ln_params(cfg.d_model, dt),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt),
    }


def encode(cfg: ArchConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: [B, S_enc, d] precomputed embeddings (conv frontend stub)."""
    s = frames.shape[1]
    pos = jnp.asarray(sinusoidal_positions(s, cfg.d_model))
    x = frames + pos[None].astype(frames.dtype)
    spec = attn_spec(cfg)
    eps = cfg.norm_eps

    def body(x, p):
        y = _ln(x, p["ln1"], eps)
        q, k, v = _project_qkv(p["attn"], spec, y, None, rope=False)
        att = _sdpa(q, k, v, causal=False)
        b, h, sq, hd = att.shape
        att = att.transpose(0, 2, 1, 3).reshape(b, sq, h * hd)
        x = x + att @ p["attn"]["wo"].astype(x.dtype)
        x = x + mlp(p["mlp"], _ln(x, p["ln2"], eps), cfg.activation,
                    cfg.lut_activations, cfg.quantize_dense)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return _ln(x, params["enc_ln"], eps)


def _dec_embed(cfg, params, tokens, offset=0):
    x = params["tok_emb"][tokens]
    s = tokens.shape[1]
    pos = jnp.asarray(sinusoidal_positions(
        offset + s, cfg.d_model))[offset:]
    return x + pos[None].astype(x.dtype)


def decoder_forward(cfg: ArchConfig, params, tokens: jnp.ndarray,
                    enc_states: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced decoder: tokens [B, S] -> logits [B, S, Vpad]."""
    x = _dec_embed(cfg, params, tokens)
    spec = attn_spec(cfg)
    eps = cfg.norm_eps
    s = tokens.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)[None]

    def body(x, p):
        y = _ln(x, p["ln1"], eps)
        q, k, v = _project_qkv(p["self"], spec, y, positions, rope=False)
        att = _sdpa(q, k, v, causal=True)
        b, h, sq, hd = att.shape
        att = att.transpose(0, 2, 1, 3).reshape(b, sq, h * hd)
        x = x + att @ p["self"]["wo"].astype(x.dtype)
        from .attention import cross_attention
        x = x + cross_attention(p["cross"], spec, _ln(x, p["ln2"], eps),
                                enc_states)
        x = x + mlp(p["mlp"], _ln(x, p["ln3"], eps), cfg.activation,
                    cfg.lut_activations, cfg.quantize_dense)
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) \
        if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    x = _ln(x, params["dec_ln"], eps)
    return x @ params["lm_head"].astype(x.dtype)


def encdec_loss(cfg: ArchConfig, params, frames, tokens, targets):
    enc = encode(cfg, params, frames)
    logits = decoder_forward(cfg, params, tokens, enc).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad[None, None], -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# -- serving -------------------------------------------------------------------

def init_dec_cache(cfg: ArchConfig, batch: int, max_seq: int):
    spec = attn_spec(cfg)
    dt = _dtype(cfg)
    L = cfg.n_layers

    def per_layer(shape):
        return jnp.zeros((L,) + shape, dt)

    kv_shape = (batch, spec.plan.n_kv, max_seq, spec.head_dim)
    cross_shape = (batch, spec.plan.n_kv, cfg.encoder_seq, spec.head_dim)
    return {"k": per_layer(kv_shape), "v": per_layer(kv_shape),
            "ck": per_layer(cross_shape), "cv": per_layer(cross_shape),
            "length": jnp.zeros((), jnp.int32)}


def encdec_prefill(cfg: ArchConfig, params, frames, tokens, max_seq: int):
    """Encode + teacher-forced decoder pass that fills the decode cache."""
    enc = encode(cfg, params, frames)
    spec = attn_spec(cfg)
    eps = cfg.norm_eps
    b, s = tokens.shape
    x = _dec_embed(cfg, params, tokens)
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    dt = _dtype(cfg)

    def body(x, p):
        y = _ln(x, p["ln1"], eps)
        q, k, v = _project_qkv(p["self"], spec, y, positions, rope=False)
        kpad = jnp.zeros((b, spec.plan.n_kv, max_seq, spec.head_dim), dt)
        kpad = jax.lax.dynamic_update_slice(kpad, k.astype(dt),
                                            (0, 0, 0, 0))
        vpad = jnp.zeros_like(kpad)
        vpad = jax.lax.dynamic_update_slice(vpad, v.astype(dt),
                                            (0, 0, 0, 0))
        att = _sdpa(q, k, v, causal=True)
        att = att.transpose(0, 2, 1, 3).reshape(b, s, -1)
        x = x + att @ p["self"]["wo"].astype(x.dtype)
        ck, cv = _cross_kv(p["cross"], spec, enc, dt)
        xq = _ln(x, p["ln2"], eps)
        qc = (xq @ p["cross"]["wq"].astype(x.dtype)).reshape(
            b, s, spec.plan.n_q, spec.head_dim).transpose(0, 2, 1, 3)
        catt = _sdpa(qc, ck, cv, causal=False)
        catt = catt.transpose(0, 2, 1, 3).reshape(b, s, -1)
        x = x + catt @ p["cross"]["wo"].astype(x.dtype)
        x = x + mlp(p["mlp"], _ln(x, p["ln3"], eps), cfg.activation,
                    cfg.lut_activations, cfg.quantize_dense)
        return x, {"k": kpad, "v": vpad, "ck": ck, "cv": cv}

    x, layer_caches = jax.lax.scan(body, x, params["dec"])
    x = _ln(x, params["dec_ln"], eps)
    logits = x[:, -1:] @ params["lm_head"].astype(x.dtype)
    cache = {**layer_caches, "length": jnp.int32(s)}
    return logits, cache


def encdec_decode_step(cfg: ArchConfig, params, tokens, cache):
    """tokens [B, 1] -> (logits, cache) single decoder step."""
    spec = attn_spec(cfg)
    eps = cfg.norm_eps
    b = tokens.shape[0]
    length = cache["length"]
    x = params["tok_emb"][tokens]
    # position embedding at the current offset (dynamic gather)
    max_pos = cache["k"].shape[3]
    pos_tab = jnp.asarray(sinusoidal_positions(max_pos, cfg.d_model))
    x = x + jax.lax.dynamic_slice_in_dim(
        pos_tab, length, 1, 0)[None].astype(x.dtype)

    def body(x, xs):
        p, k_l, v_l, ck_l, cv_l = xs
        y = _ln(x, p["ln1"], eps)
        pos = (length + jnp.arange(1))[None].astype(jnp.int32)
        q, k, v = _project_qkv(p["self"], spec, y, pos, rope=False)
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype),
                                           (0, 0, length, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype),
                                           (0, 0, length, 0))
        att = _sdpa(q, k_l, v_l, causal=True, q_offset=length,
                    kv_len=length + 1)
        att = att.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        x = x + att @ p["self"]["wo"].astype(x.dtype)
        xq = _ln(x, p["ln2"], eps)
        qc = (xq @ p["cross"]["wq"].astype(x.dtype)).reshape(
            b, 1, spec.plan.n_q, spec.head_dim).transpose(0, 2, 1, 3)
        catt = _sdpa(qc, ck_l, cv_l, causal=False)
        catt = catt.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        x = x + catt @ p["cross"]["wo"].astype(x.dtype)
        x = x + mlp(p["mlp"], _ln(x, p["ln3"], eps), cfg.activation,
                    cfg.lut_activations, cfg.quantize_dense)
        return x, (k_l, v_l)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    x = _ln(x, params["dec_ln"], eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    new_cache = {"k": new_k, "v": new_v, "ck": cache["ck"],
                 "cv": cache["cv"], "length": length + 1}
    return logits, new_cache
