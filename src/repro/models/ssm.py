"""Recurrent sequence-mixing layers: xLSTM (mLSTM + sLSTM) and a
Mamba-style selective SSM (used by the Hymba hybrid blocks).

Training uses parallel forms (chunkwise for mLSTM, associative scan for the
selective SSM); decoding uses O(1)-per-token recurrent updates — which is
what makes the ``long_500k`` shape runnable for xlstm/hymba (DESIGN.md §4).

mLSTM stabilization follows the xLSTM paper (arXiv:2405.04517): all
exponential gates are tracked in log space with a running max ``m`` so the
chunkwise and recurrent forms are numerically identical (tested).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain
from .layers import dense_init


# ---------------------------------------------------------------------------
# Causal depthwise conv (shared by mLSTM and mamba paths).
# ---------------------------------------------------------------------------

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x [B, S, C]; w [K, C] depthwise causal conv."""
    k, c = w.shape
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :].astype(x.dtype),  # [K, 1, C] HIO-ish
        window_strides=(1,), padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c)
    return out


def conv_state_init(batch: int, width: int, channels: int, dtype):
    return jnp.zeros((batch, width - 1, channels), dtype)


def causal_conv1d_step(x_t: jnp.ndarray, state: jnp.ndarray,
                       w: jnp.ndarray):
    """Single-token conv: x_t [B, 1, C], state [B, K-1, C]."""
    window = jnp.concatenate([state, x_t], axis=1)        # [B, K, C]
    out = jnp.sum(window * w[None].astype(x_t.dtype), axis=1, keepdims=True)
    return out, window[:, 1:]


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM) — xLSTM's parallelizable block.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MlstmSpec:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


class MlstmState(NamedTuple):
    c: jnp.ndarray     # [B, H, Dh, Dh] stabilized matrix memory
    n: jnp.ndarray     # [B, H, Dh]
    m: jnp.ndarray     # [B, H] log-space stabilizer
    conv: jnp.ndarray  # [B, K-1, Di]


def init_mlstm(key, spec: MlstmSpec, dtype):
    ks = jax.random.split(key, 8)
    d, di, h = spec.d_model, spec.d_inner, spec.n_heads
    return {
        "w_up": dense_init(ks[0], d, di, dtype),
        "w_gate": dense_init(ks[1], d, di, dtype),
        "conv_w": (jax.random.normal(ks[2], (spec.conv_width, di),
                                     jnp.float32) * 0.1).astype(dtype),
        "wq": dense_init(ks[3], di, di, dtype),
        "wk": dense_init(ks[4], di, di, dtype),
        "wv": dense_init(ks[5], di, di, dtype),
        "w_if": dense_init(ks[6], di, 2 * h, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((h,), jnp.float32),
                                 3.0 * jnp.ones((h,), jnp.float32)]),
        "w_down": dense_init(ks[7], di, d, dtype),
    }


def _mlstm_qkv_gates(params, spec: MlstmSpec, u: jnp.ndarray):
    """u: [B, S, Di] post-conv branch -> per-head q,k,v and log gates."""
    b, s, di = u.shape
    h, dh = spec.n_heads, spec.head_dim
    q = (u @ params["wq"].astype(u.dtype)).reshape(b, s, h, dh)
    k = (u @ params["wk"].astype(u.dtype)).reshape(b, s, h, dh)
    v = (u @ params["wv"].astype(u.dtype)).reshape(b, s, h, dh)
    k = k / jnp.sqrt(jnp.float32(dh)).astype(k.dtype)
    gates = u.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    logi = gates[..., :h]                                  # exp input gate
    logf = jax.nn.log_sigmoid(gates[..., h:])              # sigmoid forget
    return q, k, v, logi, logf


def mlstm_chunkwise(params, spec: MlstmSpec, x: jnp.ndarray,
                    chunk: int = 64) -> jnp.ndarray:
    """Parallel training form: scan over chunks, quadratic within chunk."""
    b, s, d = x.shape
    h, dh = spec.n_heads, spec.head_dim
    u0 = x @ params["w_up"].astype(x.dtype)
    g = x @ params["w_gate"].astype(x.dtype)
    u = jax.nn.silu(causal_conv1d(u0, params["conv_w"]))
    q, k, v, logi, logf = _mlstm_qkv_gates(params, spec, u)

    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def reshape_c(t):  # [B, S, ...] -> [nc, B, chunk, ...]
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = map(reshape_c, (q, k, v))
    lic, lfc = map(reshape_c, (logi, logf))

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)

    def chunk_step(carry, inp):
        c_st, n_st, m_st = carry
        qb, kb, vb, li, lf = inp          # [B, L, H, dh], gates [B, L, H]
        qb = qb.astype(jnp.float32).transpose(0, 2, 1, 3)  # [B,H,L,dh]
        kb = kb.astype(jnp.float32).transpose(0, 2, 1, 3)
        vb = vb.astype(jnp.float32).transpose(0, 2, 1, 3)
        li = li.transpose(0, 2, 1)        # [B, H, L]
        lf = lf.transpose(0, 2, 1)
        bcum = jnp.cumsum(lf, axis=-1)    # [B,H,L] decay from chunk start
        a = li - bcum                     # log i_j - b_j
        A = jnp.maximum(m_st[..., None], jax.lax.cummax(a, axis=2))
        # intra-chunk scores: (q_i k_j) exp(a_j - A_i), j <= i
        sc = jnp.einsum("bhid,bhjd->bhij", qb, kb)
        w = jnp.exp(a[:, :, None, :] - A[:, :, :, None])
        L = a.shape[-1]
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal[None, None], w, 0.0)
        num = jnp.einsum("bhij,bhjd->bhid", sc * w, vb)
        ninc = jnp.einsum("bhij,bhjd->bhid", w, kb)        # sum_j w k_j
        inter = jnp.exp(m_st[..., None] - A)               # [B,H,L]
        # (C q)_d = sum_e C[d,e] q_e  with C[d,e] = sum w * v_d k_e
        num = num + inter[..., None] * jnp.einsum(
            "bhie,bhde->bhid", qb, c_st)
        nvec = ninc + inter[..., None] * n_st[:, :, None, :]
        qn = jnp.abs(jnp.einsum("bhid,bhid->bhi", qb, nvec))
        m_abs = bcum + A
        denom = jnp.maximum(qn, jnp.exp(-jnp.clip(m_abs, -30.0, 30.0)))
        hid = num / denom[..., None]                       # [B,H,L,dh]
        # end-of-chunk state
        A_L = A[..., -1]
        wl = jnp.exp(a - A_L[..., None])                   # [B,H,L]
        decay_state = jnp.exp(m_st - A_L)
        c_new = decay_state[..., None, None] * c_st + jnp.einsum(
            "bhj,bhjd,bhje->bhde", wl, vb, kb)
        n_new = decay_state[..., None] * n_st + jnp.einsum(
            "bhj,bhjd->bhd", wl, kb)
        m_new = bcum[..., -1] + A_L
        out = hid.transpose(0, 2, 1, 3).reshape(b, L, h * dh)
        return (c_new, n_new, m_new), out

    (_, _, _), outs = jax.lax.scan(
        chunk_step, (c0, n0, m0), (qc, kc, vc, lic, lfc))
    hseq = outs.swapaxes(0, 1).reshape(b, s, h * dh).astype(x.dtype)
    return (hseq * jax.nn.silu(g)) @ params["w_down"].astype(x.dtype)


def mlstm_state_init(batch: int, spec: MlstmSpec, dtype) -> MlstmState:
    h, dh = spec.n_heads, spec.head_dim
    return MlstmState(
        c=jnp.zeros((batch, h, dh, dh), jnp.float32),
        n=jnp.zeros((batch, h, dh), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        conv=conv_state_init(batch, spec.conv_width, spec.d_inner, dtype))


def mlstm_decode_step(params, spec: MlstmSpec, x: jnp.ndarray,
                      state: MlstmState) -> tuple[jnp.ndarray, MlstmState]:
    """x: [B, 1, d] -> ([B, 1, d], new state).  Recurrent O(1) update."""
    b = x.shape[0]
    h, dh = spec.n_heads, spec.head_dim
    u0 = x @ params["w_up"].astype(x.dtype)
    g = x @ params["w_gate"].astype(x.dtype)
    conv_out, conv_new = causal_conv1d_step(u0, state.conv, params["conv_w"])
    u = jax.nn.silu(conv_out)
    q, k, v, logi, logf = _mlstm_qkv_gates(params, spec, u)
    q = q[:, 0].astype(jnp.float32)        # [B, H, dh]
    k = k[:, 0].astype(jnp.float32)
    v = v[:, 0].astype(jnp.float32)
    li = logi[:, 0]                        # [B, H]
    lf = logf[:, 0]
    m_new = jnp.maximum(lf + state.m, li)
    fp = jnp.exp(lf + state.m - m_new)
    ip = jnp.exp(li - m_new)
    c_new = fp[..., None, None] * state.c + \
        ip[..., None, None] * jnp.einsum("bhd,bhe->bhde", v, k)
    n_new = fp[..., None] * state.n + ip[..., None] * k
    num = jnp.einsum("bhde,bhe->bhd", c_new, q)
    qn = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new))
    denom = jnp.maximum(qn, jnp.exp(-jnp.clip(m_new, -30.0, 30.0)))
    hid = (num / denom[..., None]).reshape(b, 1, h * dh).astype(x.dtype)
    out = (hid * jax.nn.silu(g)) @ params["w_down"].astype(x.dtype)
    return out, MlstmState(c_new, n_new, m_new, conv_new)


# ---------------------------------------------------------------------------
# sLSTM — xLSTM's scalar-memory recurrent block (sequential over time).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SlstmSpec:
    d_model: int
    n_heads: int
    conv_width: int = 4

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


class SlstmState(NamedTuple):
    c: jnp.ndarray   # [B, D]
    n: jnp.ndarray   # [B, D]
    h: jnp.ndarray   # [B, D]
    m: jnp.ndarray   # [B, D]


def init_slstm(key, spec: SlstmSpec, dtype):
    ks = jax.random.split(key, 4)
    d, hds = spec.d_model, spec.n_heads
    dh = spec.head_dim
    return {
        "w_x": dense_init(ks[0], d, 4 * d, jnp.float32),
        # block-diagonal recurrent weights, one block per head
        "r": (jax.random.normal(ks[1], (hds, dh, 4 * dh), jnp.float32)
              / jnp.sqrt(jnp.float32(dh))),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "w_out": dense_init(ks[2], d, d, dtype),
        "norm": jnp.ones((d,), jnp.float32),
    }


def slstm_state_init(batch: int, spec: SlstmSpec) -> SlstmState:
    d = spec.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SlstmState(z, z, z, jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_cell(params, spec: SlstmSpec, xt: jnp.ndarray,
                st: SlstmState) -> tuple[jnp.ndarray, SlstmState]:
    """xt: [B, D] (pre-activations from x side already included)."""
    b, d = st.h.shape
    hds, dh = spec.n_heads, spec.head_dim
    hprev = st.h.reshape(b, hds, dh)
    rec = jnp.einsum("bhd,hde->bhe", hprev, params["r"]).reshape(b, 4 * d)
    pre = xt + rec + params["b"]
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + st.m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(logf + st.m - m_new)
    c_new = fp * st.c + ip * jnp.tanh(zt)
    n_new = fp * st.n + ip
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return h_new, SlstmState(c_new, n_new, h_new, m_new)


def slstm_apply(params, spec: SlstmSpec, x: jnp.ndarray) -> jnp.ndarray:
    """Training form: lax.scan over time (inherently sequential block)."""
    b, s, d = x.shape
    xp = x.astype(jnp.float32) @ params["w_x"]
    st0 = slstm_state_init(b, spec)

    def step(st, xt):
        h, st2 = _slstm_cell(params, spec, xt, st)
        return st2, h

    _, hs = jax.lax.scan(step, st0, xp.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1)                                 # [B, S, D]
    from .layers import rms_norm
    hs = rms_norm(hs, params["norm"])
    return hs.astype(x.dtype) @ params["w_out"].astype(x.dtype)


def slstm_decode_step(params, spec: SlstmSpec, x: jnp.ndarray,
                      state: SlstmState):
    xt = x[:, 0].astype(jnp.float32) @ params["w_x"]
    h, st = _slstm_cell(params, spec, xt, state)
    from .layers import rms_norm
    h = rms_norm(h[:, None, :], params["norm"])
    return h.astype(x.dtype) @ params["w_out"].astype(x.dtype), st


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (hymba's SSM heads).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SsmSpec:
    d_model: int
    d_inner: int
    d_state: int = 16
    conv_width: int = 4


class SsmState(NamedTuple):
    h: jnp.ndarray      # [B, Di, N]
    conv: jnp.ndarray   # [B, K-1, Di]


def init_ssm(key, spec: SsmSpec, dtype):
    ks = jax.random.split(key, 6)
    d, di, n = spec.d_model, spec.d_inner, spec.d_state
    return {
        "w_in": dense_init(ks[0], d, di, dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.conv_width, di),
                                     jnp.float32) * 0.1).astype(dtype),
        "w_bc": dense_init(ks[2], di, 2 * n, jnp.float32),
        "w_dt": dense_init(ks[3], di, di, jnp.float32),
        "dt_bias": jnp.full((di,), -2.0, jnp.float32),
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], di, d, dtype),
    }


def _ssm_inputs(params, spec: SsmSpec, u: jnp.ndarray):
    """u: [B, S, Di] post-conv -> (dA [B,S,Di,N], dBu [B,S,Di,N], C)."""
    uf = u.astype(jnp.float32)
    bc = uf @ params["w_bc"]
    B, C = jnp.split(bc, 2, axis=-1)                      # [B,S,N]
    dt = jax.nn.softplus(uf @ params["w_dt"] + params["dt_bias"])  # [B,S,Di]
    A = -jnp.exp(params["a_log"])                          # [Di, N]
    dA = jnp.exp(dt[..., None] * A[None, None])            # [B,S,Di,N]
    dBu = dt[..., None] * B[:, :, None, :] * uf[..., None]
    return dA, dBu, C


def ssm_apply(params, spec: SsmSpec, x: jnp.ndarray) -> jnp.ndarray:
    """Training form: associative scan over time."""
    b, s, d = x.shape
    u0 = x @ params["w_in"].astype(x.dtype)
    u = jax.nn.silu(causal_conv1d(u0, params["conv_w"]))
    dA, dBu, C = _ssm_inputs(params, spec, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hh, C)                # [B,S,Di]
    y = y + params["d_skip"] * u.astype(jnp.float32)
    return y.astype(x.dtype) @ params["w_out"].astype(x.dtype)


def ssm_state_init(batch: int, spec: SsmSpec, dtype) -> SsmState:
    return SsmState(
        h=jnp.zeros((batch, spec.d_inner, spec.d_state), jnp.float32),
        conv=conv_state_init(batch, spec.conv_width, spec.d_inner, dtype))


def ssm_decode_step(params, spec: SsmSpec, x: jnp.ndarray,
                    state: SsmState) -> tuple[jnp.ndarray, SsmState]:
    b = x.shape[0]
    u0 = x @ params["w_in"].astype(x.dtype)
    conv_out, conv_new = causal_conv1d_step(u0, state.conv, params["conv_w"])
    u = jax.nn.silu(conv_out)                              # [B,1,Di]
    dA, dBu, C = _ssm_inputs(params, spec, u)
    h_new = dA[:, 0] * state.h + dBu[:, 0]                 # [B,Di,N]
    y = jnp.einsum("bdn,bn->bd", h_new, C[:, 0])
    y = y + params["d_skip"] * u[:, 0].astype(jnp.float32)
    out = y[:, None].astype(x.dtype) @ params["w_out"].astype(x.dtype)
    return out, SsmState(h_new, conv_new)
