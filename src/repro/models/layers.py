"""Shared neural-net layers (pure-functional JAX).

Conventions:
  - params are nested dicts of jnp arrays; init fns take a PRNG key.
  - all matmuls run in the config dtype (bf16 by default) with f32
    normalization statistics and f32 loss.
  - the paper's techniques surface here as two switches used by every
    linear layer / activation: ``quantize_dense`` (int8 weight path, the
    LIN-HYB analogue — see models/quantized.py) and ``lut_activations``
    (LOG-LUT analogue).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import ActivationLut, gelu_lut, silu_lut

# Module-level LUTs (built once; 16 KB each — the VMEM budget argument from
# the paper's Fig. 4 carries over).
_ACT_LUTS: dict[str, ActivationLut] = {}


def _get_act_lut(name: str) -> ActivationLut:
    if name not in _ACT_LUTS:
        _ACT_LUTS[name] = {"silu": silu_lut, "gelu": gelu_lut}[name]()
    return _ACT_LUTS[name]


def activation(x: jnp.ndarray, name: str, lut: bool = False) -> jnp.ndarray:
    if lut:
        return _get_act_lut(name)(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


# -- initializers -----------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# -- norms -------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# -- rotary embeddings --------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float
                     ) -> np.ndarray:
    """Inverse frequencies for the rotary fraction of the head dim."""
    rot = int(head_dim * fraction) // 2 * 2
    return 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, fraction: float,
               theta: float) -> jnp.ndarray:
    """x: [B, H, S, D]; positions: [B, S] or [S].  Partial rotary supported
    (stablelm-style): only the first ``fraction`` of D is rotated."""
    d = x.shape[-1]
    rot = int(d * fraction) // 2 * 2
    if rot == 0:
        return x
    inv = jnp.asarray(rope_frequencies(d, fraction, theta), jnp.float32)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv  # [B?, S, rot/2]
    if ang.ndim == 2:           # [S, rot/2] -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, None, :, :]  # [B, 1, S, rot/2]
    sin = jnp.sin(ang)[:, None, :, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    rx1 = x1 * cos - x2 * sin
    rx2 = x1 * sin + x2 * cos
    rotated = jnp.stack([rx1, rx2], axis=-1).reshape(x[..., :rot].shape)
    return jnp.concatenate(
        [rotated.astype(x.dtype), x[..., rot:]], axis=-1)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal position embeddings, computed on the fly."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    return np.concatenate([np.sin(angle), np.cos(angle)],
                          axis=1).astype(np.float32)


# -- dense layer with the paper's quantized path -------------------------------

def linear(x: jnp.ndarray, w, bias: Optional[jnp.ndarray] = None,
           quantized: bool = False) -> jnp.ndarray:
    """w is either a raw array or a QuantizedWeight dict (models/quantized).

    The quantized path is the paper's hybrid-precision technique applied to
    LM linears: int8 weights, on-the-fly int8 activations, int32 MXU
    accumulation (kernels/quant_matmul).
    """
    if quantized:
        from repro.models.quantized import pim_dense
        out = pim_dense(x, w)
    else:
        out = x @ w.astype(x.dtype)
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return out


# -- MLP blocks ----------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params, x: jnp.ndarray, act: str = "silu", lut: bool = False,
        quantized: bool = False) -> jnp.ndarray:
    from repro.distributed.act_sharding import constrain
    up = constrain(linear(x, params["up"], quantized=quantized), "btf")
    if "gate" in params:
        g = activation(
            constrain(linear(x, params["gate"], quantized=quantized),
                      "btf"), act, lut)
        h = g * up
    else:
        h = activation(up, act, lut)
    return linear(h, params["down"], quantized=quantized)
