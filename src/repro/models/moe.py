"""Mixture-of-Experts layer with expert parallelism (dbrx / qwen2-moe).

Dispatch uses the GShard-style dense formulation (one-hot matmuls with a
per-expert capacity), which (a) lowers on every backend, (b) under pjit
with experts sharded over the "model" axis becomes the dispatch/combine
all-to-all pair on TPU, and (c) keeps shapes static for the dry-run.

Expert-count padding (DESIGN.md §4): qwen2-moe's 60 routed experts pad to
64 so EP=16 divides; the router logits of padding experts are masked to
-inf, so they are never selected and their (zero-init) weights never get
tokens routed to them.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import activation, dense_init, init_mlp, mlp


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    d_model: int
    n_experts: int          # padded count (divisible by EP)
    n_experts_real: int
    top_k: int
    d_ff: int               # per-expert hidden
    capacity_factor: float = 1.25
    activation: str = "silu"
    dispatch: str = "gather"  # "gather" (scatter/gather, ~0 dispatch
    #                           flops) | "dense" (one-hot matmuls —
    #                           §Perf baseline, kept for comparison)
    groups: int = 1           # group-local routing: tokens route within
    #                           their group; set == data-parallel degree so
    #                           dispatch scatters/gathers never cross data
    #                           shards (§Perf iteration A2)


def pad_experts(n_experts: int, ep: int = 16) -> int:
    return -(-n_experts // ep) * ep


def init_moe(key, spec: MoeSpec, dtype):
    ks = jax.random.split(key, 4)
    e, d, f = spec.n_experts, spec.d_model, spec.d_ff
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        # stacked expert weights: E is the EP sharding axis
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   * (1.0 / jnp.sqrt(f))).astype(dtype),
    }
    return p


def _route(params, spec: MoeSpec, xt: jnp.ndarray):
    """Router: top-k gates + load-balancing aux + capacity positions."""
    t = xt.shape[0]
    e, k = spec.n_experts, spec.top_k
    logits = xt.astype(jnp.float32) @ params["router"]
    if spec.n_experts_real < e:  # mask padding experts
        pad_mask = jnp.arange(e) >= spec.n_experts_real
        logits = jnp.where(pad_mask[None], -1e30, logits)
    gval, gidx = jax.lax.top_k(logits, k)                 # (t, k)
    gates = jax.nn.softmax(gval, axis=-1)                 # (t, k)
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(gidx, e, dtype=jnp.float32).sum(axis=1), axis=0)
    aux = jnp.sum(me * ce) * (e / k)
    # position of each (token, slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gidx, e, dtype=jnp.int32)     # (t, k, e)
    pos = jnp.cumsum(onehot.reshape(t * k, e), axis=0) * \
        onehot.reshape(t * k, e) - 1                      # (t*k, e)
    pos = (pos.reshape(t, k, e) * onehot).sum(-1)         # (t, k)
    return gates, gidx, pos, aux


def moe_apply(params, spec: MoeSpec, x: jnp.ndarray,
              lut: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    dispatch="gather" (default): scatter token ids into per-expert
    capacity buffers and gather activations — ~zero dispatch FLOPs; under
    EP sharding GSPMD turns the cross-shard gathers into all-to-all-class
    collectives.  §Perf measured 19x HLO-FLOPs reduction vs the one-hot
    "dense" baseline on qwen2-moe train_4k (EXPERIMENTS.md).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = spec.n_experts, spec.top_k
    G = spec.groups if t % max(spec.groups, 1) == 0 else 1
    tg = t // G
    cap = int(max(k * tg / e * spec.capacity_factor, 1))
    cap = min(cap, k * tg)  # never exceed the total assignment count

    from repro.distributed.act_sharding import constrain
    if spec.dispatch == "dense":
        gates, gidx, pos, aux = _route(params, spec, xt)
        keep = (pos >= 0) & (pos < cap)
        pos_c = jnp.clip(pos, 0, cap - 1)
        pos_oh = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32) * \
            keep[..., None].astype(jnp.float32)           # (t, k, cap)
        eh = jax.nn.one_hot(gidx, e, dtype=jnp.float32)   # (t, k, e)
        dispatch = jnp.einsum("tke,tkc->tec", eh, pos_oh)
        combine = jnp.einsum("tk,tke,tkc->tec", gates, eh, pos_oh)
        xe = constrain(
            jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), xt), "ecd")
        g = activation(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]
                                  .astype(x.dtype)), spec.activation, lut)
        u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(x.dtype))
        ye = constrain(jnp.einsum("ecf,efd->ecd", g * u,
                                  params["w_down"].astype(x.dtype)), "ecd")
        out = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), ye)
        return out.reshape(b, s, d), aux

    # -- gather dispatch, group-local routing -------------------------------
    xg = xt.reshape(G, tg, d)
    gates, gidx, pos, aux = jax.vmap(
        lambda xx: _route(params, spec, xx))(xg)          # (G, tg, k) ...
    aux = jnp.mean(aux)
    keep = (pos >= 0) & (pos < cap)
    pos_c = jnp.clip(pos, 0, cap - 1)
    slot = gidx * cap + pos_c                             # (G, tg, k)
    slot = jnp.where(keep, slot, e * cap)                 # dropped -> spill

    def scatter_group(slot_g):
        tok = jnp.broadcast_to(
            jnp.arange(tg, dtype=jnp.int32)[:, None], (tg, k)).reshape(-1)
        st = jnp.zeros((e * cap + 1,), jnp.int32).at[
            slot_g.reshape(-1)].set(tok, mode="drop")
        filled = jnp.zeros((e * cap + 1,), jnp.bool_).at[
            slot_g.reshape(-1)].set(True, mode="drop")
        return st[: e * cap], filled[: e * cap]

    token_src, slot_filled = jax.vmap(scatter_group)(slot)  # (G, e*cap)
    xe = jax.vmap(lambda xx, idx: jnp.take(xx, idx, axis=0))(
        xg, token_src)                                    # (G, e*cap, d)
    xe = xe * slot_filled[..., None].astype(xe.dtype)
    xe = constrain(xe.reshape(G, e, cap, d), "gecd")

    gact = activation(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]
                                 .astype(x.dtype)), spec.activation, lut)
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype))
    ye = constrain(jnp.einsum("gecf,efd->gecd", gact * u,
                              params["w_down"].astype(x.dtype)), "gecd")

    flat_ye = ye.reshape(G, e * cap, d)
    yk = jax.vmap(lambda yy, idx: jnp.take(yy, idx, axis=0))(
        flat_ye, (gidx * cap + pos_c).reshape(G, tg * k))
    yk = yk.reshape(G, tg, k, d) * keep[..., None].astype(x.dtype)
    out = jnp.einsum("gtk,gtkd->gtd", gates.astype(x.dtype), yk)
    return out.reshape(b, s, d), aux
