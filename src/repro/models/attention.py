"""Attention layers: GQA, KV cache, sliding-window, cross-attention.

TP divisibility (DESIGN.md §4): `plan_heads` pads query heads up to a
multiple of the model-parallel degree and replicates KV heads so the
(heads -> "model") sharding always divides.  Padded heads are zero-init
and receive zero gradient signal only through their (dead) output slice;
the padding waste is visible in the roofline MODEL_FLOPS/HLO_FLOPs ratio.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain
from repro.kernels.flash_attention.ops import mha
from .layers import apply_rope, dense_init, rms_norm


class HeadPlan(NamedTuple):
    n_q: int          # padded query heads
    n_kv: int         # padded kv heads
    group: int        # q heads per kv head (after padding)
    n_q_real: int
    n_kv_real: int


def plan_heads(n_q: int, n_kv: int, tp: int = 16) -> HeadPlan:
    """Pad (n_q, n_kv) to multiples of ``tp`` with integral GQA groups.

    kv < tp (e.g. GQA kv=8 on a 16-way model axis) is realized by kv-head
    replication at init; odd counts (hymba 25H/kv5, whisper 6H) pad with
    dead heads.  Waste is intentional + measured (DESIGN.md §4).
    """
    n_kv_p = _next_multiple(n_kv, tp)
    n_q_p = _next_multiple(n_q, tp)
    while n_q_p % n_kv_p != 0:
        n_q_p += tp
    return HeadPlan(n_q_p, n_kv_p, n_q_p // n_kv_p, n_q, n_kv)


def _next_multiple(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    plan: HeadPlan
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_fraction: float = 1.0
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    kv_dim: int = 0    # cross-attn source dim (0 -> d_model)


def init_attention(key, spec: AttnSpec, dtype, cross: bool = False):
    ks = jax.random.split(key, 6)
    kv_in = (spec.kv_dim or spec.d_model) if cross else spec.d_model
    p = {
        "wq": dense_init(ks[0], spec.d_model,
                         spec.plan.n_q * spec.head_dim, dtype),
        "wk": dense_init(ks[1], kv_in,
                         spec.plan.n_kv * spec.head_dim, dtype),
        "wv": dense_init(ks[2], kv_in,
                         spec.plan.n_kv * spec.head_dim, dtype),
        "wo": dense_init(ks[3], spec.plan.n_q * spec.head_dim,
                         spec.d_model, dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((spec.plan.n_q * spec.head_dim,), dtype)
        p["bk"] = jnp.zeros((spec.plan.n_kv * spec.head_dim,), dtype)
        p["bv"] = jnp.zeros((spec.plan.n_kv * spec.head_dim,), dtype)
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((spec.head_dim,), dtype)
        p["k_norm"] = jnp.ones((spec.head_dim,), dtype)
    return p


class KVCache(NamedTuple):
    """Static-shape cache; ``length`` is the filled prefix.

    int8 mode (the paper's quantization technique applied to the
    decode-cell memory bound): k/v stored int8 with per-(batch, head,
    position) f32 scales — the KV read, which dominates decode HBM
    traffic, halves.  ``k_scale is None`` <=> unquantized storage.
    """
    k: jnp.ndarray          # [B, Hkv, S_max, D] (dtype or int8)
    v: jnp.ndarray
    length: jnp.ndarray     # int32 scalar
    k_scale: Optional[jnp.ndarray] = None   # [B, Hkv, S_max] f32
    v_scale: Optional[jnp.ndarray] = None


def init_kv_cache(batch: int, plan: HeadPlan, head_dim: int, max_seq: int,
                  dtype, bits: int = 16) -> KVCache:
    shape = (batch, plan.n_kv, max_seq, head_dim)
    if bits == 8:
        sshape = shape[:-1]
        return KVCache(jnp.zeros(shape, jnp.int8),
                       jnp.zeros(shape, jnp.int8),
                       jnp.zeros((), jnp.int32),
                       jnp.ones(sshape, jnp.float32),
                       jnp.ones(sshape, jnp.float32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[..., D] -> (int8 [..., D], f32 scale [...]) per-vector symmetric."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _project_qkv(params, spec: AttnSpec, x: jnp.ndarray,
                 positions: Optional[jnp.ndarray], rope: bool = True):
    b, s, _ = x.shape
    hd = spec.head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if spec.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, spec.plan.n_q, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, spec.plan.n_kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, spec.plan.n_kv, hd).transpose(0, 2, 1, 3)
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"], spec.norm_eps)
        k = rms_norm(k, params["k_norm"], spec.norm_eps)
    if rope and positions is not None and spec.rope_fraction > 0:
        q = apply_rope(q, positions, spec.rope_fraction, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_fraction, spec.rope_theta)
    return (constrain(q, "bhsd"), constrain(k, "bhsd"),
            constrain(v, "bhsd"))


def _sdpa(q, k, v, *, causal: bool, q_offset: int = 0,
          window: Optional[int] = None, kv_len: Optional[jnp.ndarray] = None,
          use_pallas: bool = False) -> jnp.ndarray:
    """Scaled dot-product attention with GQA + optional sliding window and
    valid-kv-length masking (for static-shape caches).

    Only the fully-causal unwindowed path routes to the Pallas kernel; the
    masked variants use the XLA path (windowing inside the kernel is a
    §Perf hillclimb item, not needed for correctness).
    """
    if window is None and kv_len is None and use_pallas:
        return mha(q, k, v, causal=causal, q_offset=q_offset,
                   use_pallas=True)
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    # bf16 operands + f32 accumulation: full MXU rate, f32-stable softmax
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / (d ** 0.5)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attention(params, spec: AttnSpec, x: jnp.ndarray,
              positions: jnp.ndarray, *, window: Optional[int] = None,
              meta_kv: Optional[tuple] = None,
              use_pallas: bool = False) -> jnp.ndarray:
    """Training / prefill path (full sequence, causal)."""
    q, k, v = _project_qkv(params, spec, x, positions)
    if meta_kv is not None:       # hymba meta tokens: extra unmasked kv
        mk, mv = meta_kv
        b = x.shape[0]
        mk = jnp.broadcast_to(mk[None], (b,) + mk.shape).astype(k.dtype)
        mv = jnp.broadcast_to(mv[None], (b,) + mv.shape).astype(v.dtype)
        n_meta = mk.shape[2]
        k = jnp.concatenate([mk, k], axis=2)
        v = jnp.concatenate([mv, v], axis=2)
        out = _sdpa(q, k, v, causal=True, q_offset=n_meta, window=window)
    else:
        out = _sdpa(q, k, v, causal=True, window=window,
                    use_pallas=use_pallas)
    b, h, s, hd = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return out @ params["wo"].astype(x.dtype)


def attention_decode(params, spec: AttnSpec, x: jnp.ndarray,
                     cache: KVCache, *, window: Optional[int] = None
                     ) -> tuple[jnp.ndarray, KVCache]:
    """Single-token decode: append to the cache, attend to the prefix."""
    b, s, _ = x.shape  # s == 1
    pos = cache.length + jnp.arange(s)
    q, k, v = _project_qkv(params, spec, x, pos[None].astype(jnp.int32))
    if cache.k_scale is not None:           # int8 cache (see KVCache doc)
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_k = jax.lax.dynamic_update_slice(
            cache.k, kq, (0, 0, cache.length, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache.v, vq, (0, 0, cache.length, 0))
        new_ks = jax.lax.dynamic_update_slice(
            cache.k_scale, ks, (0, 0, cache.length))
        new_vs = jax.lax.dynamic_update_slice(
            cache.v_scale, vs, (0, 0, cache.length))
        k_full = dequantize_kv(new_k, new_ks, x.dtype)
        v_full = dequantize_kv(new_v, new_vs, x.dtype)
        new_cache = KVCache(new_k, new_v, cache.length + s,
                            new_ks, new_vs)
    else:
        new_k = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, 0, cache.length, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, 0, cache.length, 0))
        k_full, v_full = new_k, new_v
        new_cache = KVCache(new_k, new_v, cache.length + s)
    out = _sdpa(q, k_full, v_full, causal=True, q_offset=cache.length,
                window=window, kv_len=cache.length + s)
    b_, h, s_, hd = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b_, s_, h * hd)
    out = out @ params["wo"].astype(x.dtype)
    return out, new_cache


def cross_attention(params, spec: AttnSpec, x: jnp.ndarray,
                    kv_states: jnp.ndarray) -> jnp.ndarray:
    """Encoder-decoder / vision cross-attention (no causal mask, no rope)."""
    b, s, _ = x.shape
    hd = spec.head_dim
    q = (x @ params["wq"].astype(x.dtype))
    if spec.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
    q = q.reshape(b, s, spec.plan.n_q, hd).transpose(0, 2, 1, 3)
    kv = kv_states.astype(x.dtype)
    k = (kv @ params["wk"].astype(x.dtype))
    v = (kv @ params["wv"].astype(x.dtype))
    if spec.qkv_bias:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    sk = kv.shape[1]
    k = k.reshape(b, sk, spec.plan.n_kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, sk, spec.plan.n_kv, hd).transpose(0, 2, 1, 3)
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"], spec.norm_eps)
        k = rms_norm(k, params["k_norm"], spec.norm_eps)
    out = _sdpa(q, k, v, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"].astype(x.dtype)
