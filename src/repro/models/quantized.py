"""PIM-quantized linear layers for the LM stack (beyond-paper application).

The paper's LIN-HYB/LIN-BUI insight — replace wide multiplies with the
hardware's native narrow ones — maps to the TPU MXU's int8 x int8 -> int32
path.  ``QuantizedWeight`` stores int8 weights + per-output-channel scales
(symmetric, like the paper's dataset quantization);

  - serve path    : true int8 matmul via kernels/quant_matmul
  - train path    : fake-quant with a straight-through estimator, so the
                    quantization noise is *felt* by the optimizer while
                    gradients flow (standard QAT; the paper trains directly
                    on quantized data, which is the same forward numerics)
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from repro.core.quantization import QuantParams, symmetric_quantize
from repro.kernels.quant_matmul.ops import quant_dense


def quantize_weight(w: jnp.ndarray) -> dict:
    """float [K, N] -> {"q": int8 [K, N], "scale": f32 [1, N]}."""
    q, p = symmetric_quantize(w.astype(jnp.float32), bits=8, axis=w.ndim - 1)
    return {"q": q, "scale": p.scale}


def is_quantized(w) -> bool:
    return isinstance(w, dict) and "q" in w


def pim_dense(x: jnp.ndarray, w: Union[dict, jnp.ndarray],
              use_pallas: bool = False) -> jnp.ndarray:
    """Serve-path int8 dense (use_pallas=False lowers on any backend and
    becomes a single MXU int8 matmul on TPU; =True uses the Pallas kernel)."""
    if not is_quantized(w):
        w = quantize_weight(w)
    return quant_dense(x, w["q"], w["scale"], use_pallas=use_pallas)


def fake_quant_dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Train-path QAT: forward sees int8-quantized weights, backward flows
    to the float master weights (straight-through estimator)."""
    q, p = symmetric_quantize(w.astype(jnp.float32), bits=8, axis=w.ndim - 1)
    w_dq = q.astype(jnp.float32) * p.scale
    w_ste = w + jax.lax.stop_gradient(w_dq.astype(w.dtype) - w)
    return x @ w_ste.astype(x.dtype)
