"""Production mesh construction (deliverable (e), MULTI-POD DRY-RUN §1).

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for tests / elastic rescale."""
    return jax.make_mesh(shape, axes)


def describe(mesh) -> str:
    return " x ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
