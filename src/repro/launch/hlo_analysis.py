"""Loop-corrected HLO cost extraction for the roofline (deliverable (g)).

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
experimentally: a scan of 8 matmuls reports the flops of 1 — see
EXPERIMENTS.md §Roofline "methodology").  Since every model here scans
over layer units / microbatches / chunks, raw numbers undercount by
10-1000x.  This module parses the compiled HLO text:

  - splits it into named computations,
  - walks the call graph from ENTRY, multiplying by each while op's
    ``known_trip_count`` backend_config,
  - counts per-computation dot FLOPs (2*M*N*K from operand shapes),
    collective payload bytes by kind, and materialized buffer bytes,

yielding trip-corrected totals.  Elementwise FLOPs are ignored (dots
dominate at these shapes); buffer bytes approximate HBM traffic as
(bytes written + bytes read) at fusion boundaries.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

def normalize_cost_analysis(ca) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns a **list** of per-partition property dicts
    (usually length 1); newer jax returns the dict directly.  Every
    consumer in this repo goes through this helper and indexes the
    result as a plain dict (multi-partition lists fall back to the
    first entry — the repo compiles single-partition executables).
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return ca
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*{\s*$")
_SHAPE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred|"
                    r"f8e4m3fn|f8e5m2|c64|c128|s4|u4)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLS = re.compile(r"(?:calls|body|condition|branch_computations)=\{?%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose output buffers we do not count as HBM traffic
_NO_TRAFFIC = ("parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy-done", "after-all", "iota")


def _shape_bytes(txt: str) -> int:
    total = 0
    for m in _SHAPE.finditer(txt):
        n = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _shape_dims(txt: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(txt)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        # parsed:
        self.dot_flops = 0.0
        self.conv_flops = 0.0
        self.coll_bytes: Dict[str, float] = {}
        self.coll_counts: Dict[str, int] = {}
        self.traffic_bytes = 0.0
        self.subcalls: List[Tuple[str, str, int]] = []  # (kind, name, trips)


def parse_hlo(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = _COMP_HEADER.match(stripped.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.strip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if stripped.strip() == "}":
            cur = None
            continue
        if cur is not None:
            cur.lines.append(stripped)
    for c in comps.values():
        _analyze_computation(c)
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _operand_names(rhs: str) -> List[str]:
    # operands are inside the first (...) after the op name
    i = rhs.find("(")
    if i < 0:
        return []
    depth = 0
    for j in range(i, len(rhs)):
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
            if depth == 0:
                inner = rhs[i + 1: j]
                return re.findall(r"%([\w.\-]+)", inner)
    return []


def _analyze_computation(c: Computation) -> None:
    shapes: Dict[str, str] = {}          # instr name -> shape text
    for line in c.lines:
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        shapes[name] = rhs.split("=")[0] if "=" in rhs else rhs
        shapes[name] = rhs  # full rhs keeps the shape prefix
        opm = re.match(r"(\([^)]*\)|[\w\[\],{}\s]+?)\s*([a-z][\w\-]*)\(",
                       rhs)
        op = opm.group(2) if opm else ""

        # sub-computation calls (while bodies, fusions, conditionals)
        if op == "while":
            trips = 1
            tm = _TRIP.search(line)
            if tm:
                trips = int(tm.group(1))
            for cm in _CALLS.finditer(line):
                c.subcalls.append(("while", cm.group(1), trips))
        elif "calls=" in line and op in ("fusion", "call", "custom-call"):
            for cm in _CALLS.finditer(line):
                c.subcalls.append(("call", cm.group(1), 1))
        elif op == "conditional":
            for cm in _CALLS.finditer(line):
                c.subcalls.append(("call", cm.group(1), 1))

        # collectives (sync or -start async forms)
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            nbytes = _shape_bytes(rhs.split(op)[0])
            c.coll_bytes[base] = c.coll_bytes.get(base, 0) + nbytes
            c.coll_counts[base] = c.coll_counts.get(base, 0) + 1

        # dot flops: 2 * prod(out) * prod(contracting dims of lhs)
        if op in ("dot", "dot-general"):
            out = _shape_dims(rhs.split(op)[0])
            lhs_ops = _operand_names(rhs)
            k = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            if out and cm and lhs_ops:
                lhs_shape = _find_shape_of(c, lhs_ops[0])
                if lhs_shape:
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(lhs_shape[1]):
                            k *= lhs_shape[1][int(d)]
                    n_out = 1
                    for d in out[1]:
                        n_out *= d
                    c.dot_flops += 2.0 * n_out * k
        if op == "convolution":
            out = _shape_dims(rhs.split(op)[0])
            if out:
                n_out = 1
                for d in out[1]:
                    n_out *= d
                # depthwise convs here: K taps per output element
                c.conv_flops += 2.0 * n_out * 4

        # HBM traffic proxy: materialized outputs (write) + read once
        if op and op not in _NO_TRAFFIC and not op.endswith("-done"):
            c.traffic_bytes += 2.0 * _shape_bytes(rhs.split("(")[0])


def _find_shape_of(c: Computation, name: str) -> Optional[Tuple[str, list]]:
    for line in c.lines:
        m = _INSTR.match(line)
        if m and m.group(1) == name:
            return _shape_dims(m.group(2))
    return None


def corrected_totals(hlo: str) -> dict:
    comps = parse_hlo(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0, "collective_bytes": 0, "traffic_bytes": 0,
                "collectives": {}, "note": "no ENTRY computation found"}

    mult: Dict[str, float] = {}

    def walk(name: str, m: float):
        c = comps.get(name)
        if c is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for kind, sub, trips in c.subcalls:
            walk(sub, m * trips)

    walk(entry.name, 1.0)

    flops = 0.0
    traffic = 0.0
    coll: Dict[str, float] = {}
    counts: Dict[str, float] = {}
    for name, m in mult.items():
        c = comps[name]
        flops += m * (c.dot_flops + c.conv_flops)
        traffic += m * c.traffic_bytes
        for k, v in c.coll_bytes.items():
            coll[k] = coll.get(k, 0.0) + m * v
            counts[k] = counts.get(k, 0.0) + m * c.coll_counts[k]
    return {"flops": flops,
            "traffic_bytes": traffic,
            "collective_bytes": sum(coll.values()),
            "collectives": {k: v for k, v in sorted(coll.items())},
            "collective_counts": {k: int(v) for k, v in counts.items()}}
