"""Analytic MODEL_FLOPS per (arch x shape) — the "useful compute" term.

Standard accounting: 6*N_active*T for training (fwd 2 + bwd 4), 2*N_active*T
forward-only, plus explicit attention terms (causal-halved, window-capped)
that the 6N rule does not cover.  The MODEL_FLOPS / HLO_FLOPs ratio in
§Roofline measures padding + remat + dispatch waste.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape


def _param_counts(cfg: ArchConfig) -> dict:
    """Analytic parameter counts (cross-checked against eval_shape in
    tests): total, embedding, active (MoE top-k)."""
    d = cfg.d_model
    v = cfg.padded_vocab
    emb = 2 * v * d                                  # tok_emb + lm_head
    total = emb
    active = emb
    from repro.models.transformer import count_params
    total = count_params(cfg)
    if cfg.n_experts:
        # replace total expert weights with the top-k active slice
        from repro.models.moe import pad_experts
        e_pad = pad_experts(cfg.n_experts, 16)
        per_expert = 3 * d * cfg.moe_d_ff
        all_experts = e_pad * per_expert * cfg.n_layers
        active_experts = cfg.n_experts_per_tok * per_expert * cfg.n_layers
        active = total - all_experts + active_experts
    else:
        active = total
    return {"total": total, "embedding": emb, "active": active}


def _attn_flops_fwd(cfg: ArchConfig, batch: int, seq: int,
                    kv_len: int | None = None) -> float:
    """Score+value matmul flops across layers (padded heads = real cost)."""
    from repro.models.attention import plan_heads
    plan = plan_heads(cfg.n_heads, cfg.n_kv_heads, 16)
    hd = cfg.resolved_head_dim
    total = 0.0
    wins = cfg.layer_windows()
    pattern = cfg.layer_pattern()
    for bt, w in zip(pattern, wins):
        if bt in ("mlstm", "slstm"):
            # mLSTM state math: ~6*B*S*H*dh^2 (intra-chunk + state update)
            if bt == "mlstm":
                di = int(cfg.d_model * cfg.ssm_proj_factor)
                dh = di // cfg.n_heads
                total += 6.0 * batch * seq * cfg.n_heads * dh * dh
            continue
        kv = kv_len if kv_len is not None else seq
        if w:
            kv = min(kv, w)
        elif kv_len is None:
            kv = seq / 2.0  # causal triangle
        total += 4.0 * batch * plan.n_q * seq * kv * hd
    if cfg.family == "vlm":
        # cross-attn layers attend vision tokens
        n_cross = cfg.n_layers // cfg.cross_attn_every
        total += 4.0 * batch * plan.n_q * seq * cfg.vision_tokens * hd \
            * n_cross / max(cfg.n_layers, 1)
    return total


def memory_bytes(cfg: ArchConfig, shape: InputShape, n_chips: int) -> dict:
    """Analytic per-chip HBM traffic per step (the roofline memory term).

    The HLO-text traffic proxy over-counts (CPU fusion != TPU fusion), and
    cost_analysis counts loop bodies once — so the memory term is modeled
    from first principles (documented in EXPERIMENTS.md §Roofline):
      weights   read per pass (fwd / bwd / remat-fwd)
      optimizer m/v read+write + f32 param update   (ZeRO -> /n_chips)
      activations layer-boundary stores + reads (+remat rewrite)
      attention scores materialized by the XLA path (flash removes this
                term on TPU — tracked as a §Perf lever)
      KV cache  full read per decoded token
    """
    from repro.models.attention import plan_heads
    from repro.models.transformer import count_params
    tp = 16
    dp = max(n_chips // tp, 1)
    bytes_w = 2  # bf16
    N = count_params(cfg)
    w_chip = N * bytes_w / tp / (dp if cfg.fsdp else 1)
    b, s = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    d = cfg.d_model
    plan = plan_heads(cfg.n_heads, cfg.n_kv_heads, tp)
    hd = cfg.resolved_head_dim
    toks_chip = (b * s) / min(dp, b) if shape.kind != "decode" else \
        b / min(dp, b)
    out = {}
    if shape.kind == "train":
        k = max(shape.microbatches, 1)
        # weights: fwd+bwd+remat reads per microbatch + grad write/read
        out["weights"] = w_chip * (3 * k + 2)
        out["optimizer"] = 20.0 * N / n_chips
        out["activations"] = L * toks_chip * d * bytes_w * 8
        scores = 0.0
        for w in cfg.layer_windows():
            if cfg.family in ("ssm",):
                continue
            kv = min(s, w) if w else s / 2
            scores += (plan.n_q / tp) * (toks_chip) * kv * 4 * 3  # f32 fwd+bwd
        out["scores"] = scores
    elif shape.kind == "prefill":
        out["weights"] = w_chip
        out["activations"] = L * toks_chip * d * bytes_w * 3
        scores = 0.0
        for w in cfg.layer_windows():
            if cfg.family in ("ssm",):
                continue
            kv = min(s, w) if w else s / 2
            scores += (plan.n_q / tp) * toks_chip * kv * 4
        out["scores"] = scores
        out["kv_write"] = L * toks_chip * (plan.n_kv / tp) * hd * bytes_w * 2
    else:  # decode
        out["weights"] = w_chip
        batch_chip = max(b / min(dp, b), 1)
        kv_layers = sum(1 for bt in cfg.layer_pattern()
                        if bt in ("attn", "moe", "hymba", "cross"))
        wins = cfg.layer_windows()
        # int8 KV cache (paper technique): 1 byte + f32 scale per vector
        kv_elem = (1.0 + 4.0 / hd) if cfg.kv_cache_bits == 8 else bytes_w
        kv_read = 0.0
        for bt, w in zip(cfg.layer_pattern(), wins):
            if bt not in ("attn", "moe", "hymba"):
                continue
            kv = min(s, w) if w else s
            kv_read += batch_chip * (plan.n_kv / tp) * kv * hd * kv_elem * 2
        out["kv_read"] = kv_read
        out["activations"] = kv_layers * batch_chip * d * bytes_w * 4
    out["total"] = float(sum(out.values()))
    return out


def model_flops(cfg: ArchConfig, shape: InputShape) -> dict:
    counts = _param_counts(cfg)
    n_active = counts["active"] - counts["embedding"] \
        + counts["embedding"] // 2     # lm_head matmul counts, tok_emb not
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * n_active * tokens + 3.0 * _attn_flops_fwd(cfg, b, s)
    elif shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_active * tokens + _attn_flops_fwd(cfg, b, s)
    else:  # decode: one token against a seq_len cache
        tokens = b
        flops = 2.0 * n_active * b + _attn_flops_fwd(cfg, b, 1, kv_len=s)
    return {"model_flops": flops, "tokens": tokens, **counts}
