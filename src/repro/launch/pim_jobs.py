"""Training-service launcher: drive the PIM job scheduler from a manifest.

The multi-tenant face of the reproduction (DESIGN.md §7): a YAML/JSON
manifest declares the PIM system, datasets, and a mix of jobs and
(optionally fused) hyperparameter sweeps; the scheduler carves the cores
axis into rank-aligned slices and gang-steps everything concurrently.

  PYTHONPATH=src python -m repro.launch.pim_jobs examples/jobs.yaml
  PYTHONPATH=src python -m repro.launch.pim_jobs jobs.json --json out.json

Without a manifest, ``--demo`` runs a built-in mixed workload queue.

Crash survivability (DESIGN.md §11.5): ``--checkpoint-dir DIR`` writes
chunk-boundary job checkpoints plus an atomic queue record as the drain
progresses; after a kill, re-running the same manifest with
``--checkpoint-dir DIR --resume`` completes it — finished jobs are
restored without re-running, unfinished ones continue from their last
durable snapshot.  ``--retry-budget N`` survives injected or real
per-step faults via supervised retry.

Serve mode (DESIGN.md §14.4): ``--serve --spool DIR`` turns the one-shot
drain into a long-running service — the initial manifest's jobs drain on
a background thread while DIR is watched for further manifest files,
each admitted mid-flight (answered with a ``<name>.status.json``
sidecar: accepted, or rejected with the reason).  The service exits
after ``--idle-timeout`` seconds with no new work.
``--max-modeled-seconds X`` is cost-model admission control (§14.3):
manifests whose modeled makespan bound exceeds X are rejected whole —
reported, never queued, never a crash.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.obs import (TRACER, Column, format_ratio, render_table,
                       write_chrome_trace)
from repro.sched import (SloViolation, job_report, load_manifest,
                         run_manifest, serve_manifests)

#: the per-job report columns every metric row renders through
#: (repro.obs.format — shared with pim_ml/compare so new metrics appear
#: in every CLI by adding one spec here)
JOB_COLUMNS = (
    Column("name", "job", width=28, align="<"),
    Column("state", width=10, align="<"),
    Column("cores", width=5, spec="d"),
    Column("steps", width=6, spec="d"),
    Column("kernel_launches", "launches", width=8, spec="d", default="0"),
    Column("modeled_dpu_seconds", "dpu_s", width=10, spec=".3e"),
    Column("drift_ratio", "drift", width=9, spec=".3g"),
)

#: the built-in demo manifest (also documents the schema)
DEMO_MANIFEST = {
    "system": {"cores": 32, "rank_size": 4, "reduce": "fabric"},
    "datasets": {
        "lin": {"kind": "linear", "samples": 2048, "features": 16,
                "seed": 0},
        "blobs": {"kind": "blobs", "samples": 4096, "features": 8,
                  "centers": 8, "seed": 1},
    },
    "jobs": [
        {"workload": "kmeans", "dataset": "blobs", "cores": 8,
         "priority": 1, "params": {"n_clusters": 8, "max_iter": 40}},
        {"workload": "logreg", "dataset": "lin", "cores": 4,
         "version": "int32_lut_wram", "params": {"n_iters": 150}},
    ],
    "sweeps": [
        {"workload": "linreg", "dataset": "lin", "cores": 8,
         "version": "hyb", "fused": True,
         "grid": {"lr": [0.05, 0.1, 0.2, 0.4]},
         "params": {"n_iters": 150}},
    ],
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("manifest", nargs="?", default=None,
                    help="YAML/JSON manifest path (see repro.sched."
                         "manifest for the schema)")
    ap.add_argument("--demo", action="store_true",
                    help="run the built-in demo manifest")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the per-job report as JSON")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="write crash-survivable elastic checkpoints "
                         "(per-job snapshots + queue record) here")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    metavar="N",
                    help="checkpoint cadence in scheduling steps "
                         "(default 1 = every chunk boundary)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed run from --checkpoint-dir: "
                         "finished jobs are not re-run, unfinished ones "
                         "continue from their last snapshot")
    ap.add_argument("--retry-budget", type=int, default=0, metavar="N",
                    help="per-job supervised retries from the last "
                         "snapshot before FAILED (default 0)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a Chrome trace-event timeline of the "
                         "drain (load in Perfetto / chrome://tracing); "
                         "one track per target System, memory channel, "
                         "and job")
    ap.add_argument("--serve", action="store_true",
                    help="serve mode: drain on a background thread and "
                         "watch --spool for more manifests (DESIGN.md "
                         "§14.4)")
    ap.add_argument("--spool", default=None, metavar="DIR",
                    help="directory watched for additional manifest "
                         "files in --serve mode")
    ap.add_argument("--idle-timeout", type=float, default=10.0,
                    metavar="S",
                    help="serve mode exits after this many seconds "
                         "with no new manifests and an idle scheduler "
                         "(default 10)")
    ap.add_argument("--poll-interval", type=float, default=0.2,
                    metavar="S",
                    help="spool scan cadence in serve mode "
                         "(default 0.2)")
    ap.add_argument("--max-modeled-seconds", type=float, default=None,
                    metavar="X",
                    help="admission SLO: reject manifests whose "
                         "modeled makespan lower bound exceeds X "
                         "(the manifest's own slo section wins)")
    args = ap.parse_args(argv)

    if args.manifest is None and not args.demo:
        ap.error("pass a manifest path or --demo")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume needs --checkpoint-dir")
    if args.serve and not args.spool:
        ap.error("--serve needs --spool")
    doc = DEMO_MANIFEST if args.manifest is None \
        else load_manifest(args.manifest)

    if args.trace:
        TRACER.enable()
    t0 = time.perf_counter()
    try:
        scheduler, handles = run_manifest(
            doc,
            drain=not args.serve,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
            resume=args.resume,
            retry_budget=args.retry_budget,
            max_modeled_seconds=args.max_modeled_seconds)
    except SloViolation as err:
        print(f"manifest rejected: {err}", file=sys.stderr)
        if args.json:
            with open(args.json, "w") as fh:
                json.dump({"rejected": True, "reason": str(err)}, fh,
                          indent=2)
        return 1
    manifest_records = []
    if args.serve:
        manifest_records = serve_manifests(
            scheduler, args.spool,
            poll_interval=args.poll_interval,
            idle_timeout=args.idle_timeout,
            max_modeled_seconds=args.max_modeled_seconds,
            handles=handles)
        scheduler.shutdown(wait=True)
    makespan = time.perf_counter() - t0
    if args.trace:
        write_chrome_trace(TRACER.events(), args.trace)
        print(f"trace written to {args.trace} "
              f"({len(TRACER)} events)")

    rows = job_report(handles)
    print(render_table(rows, JOB_COLUMNS,
                       extra=lambda row: row.get("error", "")))
    stats = scheduler.stats()
    if args.serve:
        accepted = sum(1 for r in manifest_records
                       if r["state"] == "accepted")
        print(f"\nserve: {len(manifest_records)} spooled manifest(s), "
              f"{accepted} accepted, "
              f"{len(manifest_records) - accepted} rejected")
        for rec in manifest_records:
            detail = (f"{rec['jobs']} job(s)"
                      if rec["state"] == "accepted"
                      else rec["reason"])
            print(f"  {rec['path']}: {rec['state']} ({detail})")
        lat = stats["latency"]
        if lat["completion"]["count"]:
            print(f"latency: queue p50 {lat['queue']['p50']:.3f}s "
                  f"p99 {lat['queue']['p99']:.3f}s; completion p50 "
                  f"{lat['completion']['p50']:.3f}s p99 "
                  f"{lat['completion']['p99']:.3f}s")
    n_done = stats["jobs"]["done"]
    print(f"\n{len(handles)} jobs, {n_done} done in {makespan:.2f}s "
          f"({n_done / max(makespan, 1e-9):.2f} jobs/s); "
          f"failed {stats['jobs']['failed']}, "
          f"cancelled {stats['jobs']['cancelled']}")
    s = scheduler.system.stats
    print(f"system transfers: cpu->pim {s.cpu_to_pim:,} B, "
          f"pim->cpu {s.pim_to_cpu:,} B, "
          f"kernel launches {s.kernel_launches}")
    ratios = [d["ratio"] for d in stats.get("drift", {}).values()
              if d.get("ratio")]
    if ratios:
        print(f"model drift (wall/modeled): mean "
              f"{format_ratio(sum(ratios) / len(ratios))} over "
              f"{len(ratios)} priced job(s)")
    n_restored = sum(1 for r in rows if r.get("restored"))
    n_recoveries = sum(r.get("recoveries", 0) for r in rows)
    if n_restored or n_recoveries:
        print(f"elastic: {n_restored} job(s) restored without re-running,"
              f" {n_recoveries} supervised retrie(s)")

    if args.json:
        report = {"makespan_seconds": makespan, "jobs": rows,
                  "scheduler": stats}
        if args.serve:
            report["manifests"] = manifest_records
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"report written to {args.json}")
    return 0 if stats["jobs"]["failed"] == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
