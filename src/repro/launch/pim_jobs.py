"""Training-service launcher: drive the PIM job scheduler from a manifest.

The multi-tenant face of the reproduction (DESIGN.md §7): a YAML/JSON
manifest declares the PIM system, datasets, and a mix of jobs and
(optionally fused) hyperparameter sweeps; the scheduler carves the cores
axis into rank-aligned slices and gang-steps everything concurrently.

  PYTHONPATH=src python -m repro.launch.pim_jobs examples/jobs.yaml
  PYTHONPATH=src python -m repro.launch.pim_jobs jobs.json --json out.json

Without a manifest, ``--demo`` runs a built-in mixed workload queue.

Crash survivability (DESIGN.md §11.5): ``--checkpoint-dir DIR`` writes
chunk-boundary job checkpoints plus an atomic queue record as the drain
progresses; after a kill, re-running the same manifest with
``--checkpoint-dir DIR --resume`` completes it — finished jobs are
restored without re-running, unfinished ones continue from their last
durable snapshot.  ``--retry-budget N`` survives injected or real
per-step faults via supervised retry.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.sched import job_report, load_manifest, run_manifest

#: the built-in demo manifest (also documents the schema)
DEMO_MANIFEST = {
    "system": {"cores": 32, "rank_size": 4, "reduce": "fabric"},
    "datasets": {
        "lin": {"kind": "linear", "samples": 2048, "features": 16,
                "seed": 0},
        "blobs": {"kind": "blobs", "samples": 4096, "features": 8,
                  "centers": 8, "seed": 1},
    },
    "jobs": [
        {"workload": "kmeans", "dataset": "blobs", "cores": 8,
         "priority": 1, "params": {"n_clusters": 8, "max_iter": 40}},
        {"workload": "logreg", "dataset": "lin", "cores": 4,
         "version": "int32_lut_wram", "params": {"n_iters": 150}},
    ],
    "sweeps": [
        {"workload": "linreg", "dataset": "lin", "cores": 8,
         "version": "hyb", "fused": True,
         "grid": {"lr": [0.05, 0.1, 0.2, 0.4]},
         "params": {"n_iters": 150}},
    ],
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("manifest", nargs="?", default=None,
                    help="YAML/JSON manifest path (see repro.sched."
                         "manifest for the schema)")
    ap.add_argument("--demo", action="store_true",
                    help="run the built-in demo manifest")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the per-job report as JSON")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="write crash-survivable elastic checkpoints "
                         "(per-job snapshots + queue record) here")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    metavar="N",
                    help="checkpoint cadence in scheduling steps "
                         "(default 1 = every chunk boundary)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed run from --checkpoint-dir: "
                         "finished jobs are not re-run, unfinished ones "
                         "continue from their last snapshot")
    ap.add_argument("--retry-budget", type=int, default=0, metavar="N",
                    help="per-job supervised retries from the last "
                         "snapshot before FAILED (default 0)")
    args = ap.parse_args(argv)

    if args.manifest is None and not args.demo:
        ap.error("pass a manifest path or --demo")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume needs --checkpoint-dir")
    doc = DEMO_MANIFEST if args.manifest is None \
        else load_manifest(args.manifest)

    t0 = time.perf_counter()
    scheduler, handles = run_manifest(
        doc,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        resume=args.resume,
        retry_budget=args.retry_budget)
    makespan = time.perf_counter() - t0

    rows = job_report(handles)
    print(f"{'job':28s} {'state':10s} {'cores':>5s} {'steps':>6s} "
          f"{'launches':>8s} {'dpu_s':>10s}")
    for row in rows:
        print(f"{row['name'][:28]:28s} {row['state']:10s} "
              f"{row['cores']:5d} {row['steps']:6d} "
              f"{row.get('kernel_launches', 0):8d} "
              f"{row['modeled_dpu_seconds']:10.3e}"
              + (f"  {row['error']}" if "error" in row else ""))
    stats = scheduler.stats()
    n_done = stats["jobs"]["done"]
    print(f"\n{len(handles)} jobs, {n_done} done in {makespan:.2f}s "
          f"({n_done / max(makespan, 1e-9):.2f} jobs/s); "
          f"failed {stats['jobs']['failed']}, "
          f"cancelled {stats['jobs']['cancelled']}")
    s = scheduler.system.stats
    print(f"system transfers: cpu->pim {s.cpu_to_pim:,} B, "
          f"pim->cpu {s.pim_to_cpu:,} B, "
          f"kernel launches {s.kernel_launches}")
    n_restored = sum(1 for r in rows if r.get("restored"))
    n_recoveries = sum(r.get("recoveries", 0) for r in rows)
    if n_restored or n_recoveries:
        print(f"elastic: {n_restored} job(s) restored without re-running,"
              f" {n_recoveries} supervised retrie(s)")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"makespan_seconds": makespan, "jobs": rows,
                       "scheduler": stats}, fh, indent=2)
        print(f"report written to {args.json}")
    return 0 if stats["jobs"]["failed"] == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
