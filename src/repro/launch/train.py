"""End-to-end LM training launcher.

CPU-runnable for reduced configs (examples/train_lm.py drives a ~100M
model for a few hundred steps); on a real pod the same code path uses the
production mesh and full configs.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

The paper's PIM-ML workloads (LIN/LOG/DTR/KME) launch through the
workload-session CLI instead: ``python -m repro.launch.pim_ml`` (built on
the unified repro.api surface — registry, PimDataset, ReduceStrategy).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.data.tokens import MarkovCorpus
from repro.models.api import Model
from repro.optim.adam import AdamW
from repro.train import checkpoint as ckpt_lib
from repro.train.fault_tolerance import StragglerMonitor
from repro.train.loop import make_train_step


def build(arch: str, *, reduced: bool, lr: float = 3e-4,
          microbatches: int = 1, quantize_dense: bool = False,
          lut_activations: bool = False, overrides: dict | None = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced(**(overrides or {}))
    if quantize_dense or lut_activations:
        cfg = dataclasses.replace(cfg, quantize_dense=quantize_dense,
                                  lut_activations=lut_activations)
    model = Model(cfg)
    opt = AdamW(lr=lr)
    step_fn = jax.jit(make_train_step(model, opt,
                                      microbatches=microbatches),
                      donate_argnums=(0, 1))
    return cfg, model, opt, step_fn


def train(arch: str, *, steps: int, batch: int, seq: int,
          reduced: bool = True, ckpt_dir: str = "", ckpt_every: int = 50,
          lr: float = 3e-4, seed: int = 0, microbatches: int = 1,
          log_every: int = 10, resume: bool = True,
          quantize_dense: bool = False, lut_activations: bool = False,
          overrides: dict | None = None):
    cfg, model, opt, step_fn = build(
        arch, reduced=reduced, lr=lr, microbatches=microbatches,
        quantize_dense=quantize_dense, lut_activations=lut_activations,
        overrides=overrides)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    corpus = MarkovCorpus(cfg.vocab_size, seed=seed)
    start = 0
    if ckpt_dir and resume:
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            state = ckpt_lib.restore(ckpt_dir, last,
                                     (params, opt_state))
            params, opt_state = state
            start = last
            print(f"resumed from step {last}")

    monitor = StragglerMonitor()
    losses = []
    t_start = time.perf_counter()
    for step in range(start, steps):
        batch_np = corpus.batch(batch, seq)
        if cfg.family == "vlm":
            batch_np["vision"] = np.random.RandomState(step).normal(
                0, 1, (batch, cfg.vision_tokens, cfg.vision_dim)
            ).astype(np.float32 if cfg.dtype == "float32" else np.float32)
        if cfg.family == "audio":
            batch_np["frames"] = np.random.RandomState(step).normal(
                0, 1, (batch, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32)
        batch_dev = jax.tree_util.tree_map(jnp.asarray, batch_np)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        monitor.observe(time.perf_counter() - t0)
        losses.append(loss)
        if (step + 1) % log_every == 0 or step == start:
            tput = batch * seq * log_every / max(
                time.perf_counter() - t_start, 1e-9)
            t_start = time.perf_counter()
            print(f"step {step + 1:5d}  loss {loss:7.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  "
                  f"~{tput_fmt(tput)} tok/s")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, step + 1, (params, opt_state))
    return params, losses, corpus


def tput_fmt(x: float) -> str:
    return f"{x/1e3:.1f}k" if x > 1e3 else f"{x:.0f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quantize-dense", action="store_true",
                    help="paper technique: int8 linear layers")
    ap.add_argument("--lut-activations", action="store_true",
                    help="paper technique: LUT activations")
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          reduced=args.reduced, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, lr=args.lr,
          microbatches=args.microbatches,
          quantize_dense=args.quantize_dense,
          lut_activations=args.lut_activations)


if __name__ == "__main__":
    main()
