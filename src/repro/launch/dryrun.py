import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)).

Lowers + compiles every (architecture x input-shape) cell on the
single-pod (16 data x 16 model = 256) and multi-pod (2 pod x 16 x 16 =
512) meshes, printing memory_analysis() and cost_analysis() and appending
structured results to experiments/dryrun_results.json (resumable — done
cells are skipped on re-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, shape_for, supports
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        opt_state_shardings,
                                        param_shardings,
                                        param_shardings_fsdp)
from repro.launch.analytic import model_flops
from repro.launch.hlo_analysis import (corrected_totals,
                                       normalize_cost_analysis)
from repro.launch.mesh import describe, make_production_mesh
from repro.models.api import Model, input_specs
from repro.optim.adam import AdamW
from repro.train.loop import make_train_step

RESULTS_PATH = "experiments/dryrun_results.json"


def _result_key(arch, shape, multi_pod):
    return f"{arch}|{shape}|{'2pod' if multi_pod else '1pod'}"


def load_results(path=RESULTS_PATH) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def save_results(results: dict, path=RESULTS_PATH):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Collective-byte extraction from HLO text (for §Roofline).
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\([^)]*\)|[a-z0-9\[\],\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u32|s16|u16|s8|u8|pred|"
                       r"f8e4m3fn|f8e5m2|u64)\[([0-9,]*)\]")


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum output-shape bytes of every collective op, by kind.

    Output bytes are the per-device payload GSPMD materializes; for
    all-reduce in/out sizes match, for all-gather the output is the
    gathered buffer (upper bound on wire bytes per device).
    """
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo):
        shapes_txt, kind = m.group(2), m.group(3)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes_txt):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[kind] = totals.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


# ---------------------------------------------------------------------------
# Cell lowering.
# ---------------------------------------------------------------------------

def build_step(arch: str, shape_name: str, mesh, cfg_overrides=None):
    """Returns (jitted_fn, example_args_as_ShapeDtypeStructs)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    model = Model(cfg)
    shape = shape_for(cfg, shape_name)
    specs = input_specs(cfg, shape)
    pshapes = model.param_shapes()
    pshard = (param_shardings_fsdp(mesh, pshapes) if cfg.fsdp
              else param_shardings(mesh, pshapes,
                                   tp_dense=cfg.tp_dense))

    if shape.kind == "train":
        opt = AdamW(lr=3e-4)
        ostate_shapes = opt.init_shapes(pshapes)
        oshard = _opt_shardings(mesh, ostate_shapes,
                                opt_state_shardings(mesh, pshapes))
        step = make_train_step(model, opt,
                               microbatches=shape.microbatches)
        bshard = batch_shardings(mesh, specs)
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn, (pshapes, ostate_shapes, specs)

    if shape.kind == "prefill":
        bshard = batch_shardings(mesh, {k: v for k, v in specs.items()})

        def prefill_fn(params, batch):
            return model.prefill(params, batch, max_seq=shape.seq_len)

        fn = jax.jit(prefill_fn, in_shardings=(pshard, bshard))
        return fn, (pshapes, specs)

    # decode: serve_step(params, tokens, cache) -> (logits, cache)
    cache_shapes = specs["cache"]
    cshard = cache_shardings(mesh, cache_shapes)
    tok_shard = batch_shardings(mesh, {"tokens": specs["tokens"]})["tokens"]

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    fn = jax.jit(serve_step,
                 in_shardings=(pshard, tok_shard, cshard),
                 out_shardings=(None, cshard),
                 donate_argnums=(2,))
    return fn, (pshapes, specs["tokens"], cache_shapes)


def _opt_shardings(mesh, ostate_shapes, pshard):
    """Adam m/v inherit param shardings; step is replicated."""
    from repro.optim.adam import AdamState
    rep = NamedSharding(mesh, P())
    return AdamState(step=rep, m=pshard, v=pshard)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             results: dict, verbose: bool = True,
             mesh_shape: tuple = ()) -> dict:
    """mesh_shape: optional (data, model) override for §Perf mesh
    experiments (e.g. --mesh-shape 64,4); production meshes otherwise."""
    key = _result_key(arch, shape_name, multi_pod)
    if mesh_shape:
        key += f"|mesh{mesh_shape[0]}x{mesh_shape[1]}"
    cfg = get_config(arch)
    ok, reason = supports(cfg, shape_name)
    if not ok:
        entry = {"status": "skipped", "reason": reason}
        results[key] = entry
        save_results(results)
        return entry

    mesh = (jax.make_mesh(mesh_shape, ("data", "model")) if mesh_shape
            else make_production_mesh(multi_pod=multi_pod))
    t0 = time.perf_counter()
    try:
        from repro.distributed import act_sharding
        fn, args = build_step(arch, shape_name, mesh)
        with mesh, act_sharding.use_mesh(mesh):
            lowered = fn.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = normalize_cost_analysis(compiled.cost_analysis())
            hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        corrected = corrected_totals(hlo)
        analytic = model_flops(cfg, shape_for(cfg, shape_name))
        entry = {
            "status": "ok",
            "mesh": describe(mesh),
            "n_devices": int(np.prod(list(mesh.shape.values()))),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes",
                                          0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
            "collectives": coll,
            "corrected": corrected,
            "analytic": analytic,
            "hlo_ops": len(hlo.splitlines()),
        }
        if verbose:
            print(f"[OK] {key}: compile={t_compile:.0f}s "
                  f"flops={corrected['flops']:.3e} "
                  f"(model {analytic['model_flops']:.3e}) "
                  f"coll={corrected['collective_bytes']:.3e}B "
                  f"args={entry['argument_bytes']/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — failures are data here
        entry = {"status": "error", "error": f"{type(e).__name__}: {e}",
                 "traceback": traceback.format_exc()[-2000:]}
        if verbose:
            print(f"[FAIL] {key}: {entry['error']}")
    results[key] = entry
    save_results(results)
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have results")
    ap.add_argument("--mesh-shape", default="",
                    help="logical (data,model) override, e.g. 64,4 — "
                         "reproduces the §Perf mesh experiments")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split(",")) \
        if args.mesh_shape else ()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = [False, True]
    if args.multi_pod_only:
        pods = [True]
    if args.single_pod_only:
        pods = [False]

    results = load_results()
    n_ok = n_fail = n_skip = 0
    for multi_pod in pods:
        for arch in archs:
            for shape in shapes:
                key = _result_key(arch, shape, multi_pod)
                if mesh_shape:
                    key += f"|mesh{mesh_shape[0]}x{mesh_shape[1]}"
                if not args.force and results.get(key, {}).get(
                        "status") in ("ok", "skipped"):
                    print(f"[cached] {key}: {results[key]['status']}")
                    continue
                entry = run_cell(arch, shape, multi_pod, results,
                                 mesh_shape=mesh_shape)
                s = entry["status"]
                n_ok += s == "ok"
                n_fail += s == "error"
                n_skip += s == "skipped"
    print(f"\ndone: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"(results in {RESULTS_PATH})")


if __name__ == "__main__":
    main()
