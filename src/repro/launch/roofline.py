"""Roofline analysis (deliverable (g)) — reads experiments/dryrun_results.json.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / (links * link_bw)

All three in seconds-per-step for ONE chip's program (the dry-run HLO is
the per-device SPMD program).  The dominant term is the bottleneck; the
roofline fraction reported in EXPERIMENTS.md §Perf is
    useful_time / max(term)   with   useful_time = MODEL_FLOPS /
                                     (n_chips * peak)
i.e. how close the useful math comes to the achievable step time.

FLOPs/bytes come from the loop-corrected HLO walk (launch/hlo_analysis) —
``cost_analysis()`` counts while bodies once (verified; its raw numbers
are retained in the JSON for reference).  Collective bytes are summed from
the per-op payloads in the compiled HLO, trip-corrected the same way.

Hardware constants (TPU v5e class, per the assignment):
  197 TFLOP/s bf16 per chip - 819 GB/s HBM - ~50 GB/s/link ICI.
We charge the collective term at 2 links' worth of concurrent ICI
bandwidth (a 2-D torus drives >= 2 links for ring collectives along one
axis); single-link numbers are 2x larger, noted in the table.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_LINK_BW = 50e9           # B/s per link
ICI_LINKS = 2                # concurrent links charged for collectives

RESULTS_PATH = "experiments/dryrun_results.json"


# ---------------------------------------------------------------------------
# GPU roofline (the ModeledGpuSystem target — DESIGN.md §10.4).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GpuRoofline:
    """Calibrated kernel-time/energy model of a discrete GPU.

    ``kernel_seconds(flops, bytes)`` prices one launch at
    ``launch_overhead + max(flops/peak, bytes/hbm_bw)`` — the classic
    roofline with a fixed dispatch cost.  The overhead term is what the
    paper's comparison turns on for the small iterative workloads: a GD
    step whose math takes microseconds still pays the full kernel-launch
    latency every iteration, which is exactly when PIM wins (Figs.
    13-17) and why the fused step engine matters on every target.

    Used by :class:`repro.systems.gpu_model.ModeledGpuSystem` to price
    real compiled HLO programs, replacing the previously hard-coded
    paper speedup constants in benchmarks/fig13_17_compare.py with a
    model whose inputs (FLOPs, bytes) are measured from the very
    programs the workloads execute.

    Calibration provenance (each constant against published numbers,
    not guesses):
      peak_flops   19.5 TFLOP/s — A100 datasheet fp32 peak (non-tensor-
                   core; the paper's ML kernels are fp32 BLAS-style
                   loops, not TF32 matmuls).
      hbm_bw       1555 GB/s — A100-SXM4-40G datasheet HBM2e peak.
      achievable_bw_fraction  0.85 — STREAM-class/bandwidthTest
                   microbenchmarks sustain ~1.3-1.4 TB/s of the 1555
                   peak on A100 (the familiar ~85% DRAM efficiency);
                   pricing memory-bound kernels at the full datasheet
                   rate flatters the GPU column of Figs. 13-17.
      launch_overhead_s  5 µs — measured empty-kernel CUDA launch
                   latency (cudaLaunchKernel + driver) on PCIe/SXM
                   systems is ~3-7 µs; 5 µs is the conventional
                   midpoint.  This is the constant the PIM-vs-GPU
                   comparison actually turns on for tiny iterative
                   steps.
      tdp_w        400 W — A100-SXM4 board TDP.
    """

    name: str = "a100-sxm4-40g"
    peak_flops: float = 19.5e12      # fp32 (non-TC: the paper's ML
    #                                  kernels are fp32 BLAS-style loops)
    hbm_bw: float = 1.555e12         # B/s datasheet peak (40 GB HBM2e)
    #: fraction of datasheet HBM bandwidth real kernels sustain
    achievable_bw_fraction: float = 0.85
    launch_overhead_s: float = 5e-6  # CUDA kernel-launch latency
    tdp_w: float = 400.0             # board power for the energy model

    @property
    def achievable_bw(self) -> float:
        """Sustained HBM bandwidth the memory term is priced at."""
        return self.hbm_bw * self.achievable_bw_fraction

    def kernel_seconds(self, flops: float, bytes_: float) -> float:
        return self.launch_overhead_s + max(flops / self.peak_flops,
                                            bytes_ / self.achievable_bw)

    def kernel_energy_j(self, seconds: float) -> float:
        return seconds * self.tdp_w


def a100() -> GpuRoofline:
    """The default calibration: NVIDIA A100-SXM4 (the class of GPU the
    paper's Table 4 comparison machine carries)."""
    return GpuRoofline()


def terms(entry: dict, n_chips: int, arch: str = "",
          shape_name: str = "") -> Optional[dict]:
    if entry.get("status") != "ok":
        return None
    corr = entry["corrected"]
    ana = entry["analytic"]
    t_compute = corr["flops"] / PEAK_FLOPS
    if arch and shape_name:
        from repro.configs.base import get_config
        from repro.configs.shapes import shape_for
        from repro.launch.analytic import memory_bytes
        cfg = get_config(arch)
        mem = memory_bytes(cfg, shape_for(cfg, shape_name), n_chips)
        t_memory = mem["total"] / HBM_BW
    else:
        t_memory = corr["traffic_bytes"] / HBM_BW
    t_coll = corr["collective_bytes"] / (ICI_LINKS * ICI_LINK_BW)
    bound = max(("compute", t_compute), ("memory", t_memory),
                ("collective", t_coll), key=lambda kv: kv[1])[0]
    useful = ana["model_flops"] / (n_chips * PEAK_FLOPS)
    step = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bound": bound,
        "model_flops": ana["model_flops"],
        "hlo_flops_per_chip": corr["flops"],
        "useful_ratio": ana["model_flops"] / max(
            corr["flops"] * n_chips, 1e-9),
        "roofline_fraction": useful / max(step, 1e-30),
        "step_time_s": step,
    }


def _fmt(x: float) -> str:
    return f"{x:.3e}"


def build_table(results: dict, mesh: str = "1pod") -> list:
    rows = []
    for key, entry in sorted(results.items()):
        parts = key.split("|")
        if len(parts) != 3:
            continue  # --mesh-shape experiment entries
        arch, shape, m = parts
        if m != mesh:
            continue
        if entry.get("status") == "skipped":
            rows.append({"arch": arch, "shape": shape,
                         "status": "skipped",
                         "reason": entry.get("reason", "")[:60]})
            continue
        if entry.get("status") != "ok":
            rows.append({"arch": arch, "shape": shape, "status": "error"})
            continue
        n_chips = entry.get("n_devices", 256)
        t = terms(entry, n_chips, arch, shape)
        rows.append({"arch": arch, "shape": shape, "status": "ok", **t})
    return rows


def render_markdown(rows: list, mesh: str) -> str:
    out = [f"### Roofline — {mesh} mesh", "",
           "| arch | shape | compute s | memory s | collective s | bound |"
           " MODEL/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['t_compute_s'])} | "
            f"{_fmt(r['t_memory_s'])} | {_fmt(r['t_collective_s'])} | "
            f"{r['bound']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS_PATH)
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    sections = []
    for mesh in ("1pod", "2pod"):
        rows = build_table(results, mesh)
        if rows:
            sections.append(render_markdown(rows, mesh))
    text = "\n\n".join(sections) + "\n"
    with open(args.out, "w") as f:
        f.write(text)
    print(text)


if __name__ == "__main__":
    main()
