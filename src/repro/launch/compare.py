"""Fig. 13-17 / Tables 5-7: the PIM vs host-CPU vs GPU comparison,
driven end-to-end through the single ``System`` API (DESIGN.md §10.5).

For each of the paper's four workloads, the SAME ``Workload`` object
fits on all three execution targets:

  pim        the paper's best PIM version (INT32/BUI ladder for GD,
             int16 Lloyd's), wall-clock measured on the semantic model
             and DPU seconds from the hierarchical cost model
             (``HierarchicalCostModel`` — Fig. 8-12 calibration, with
             rank-serialized broadcast/gather legs, DESIGN.md §12);
  host       the processor-centric fp32 baseline, wall-clock measured
             in this container (replacing the deleted ad-hoc
             ``train_cpu_baseline`` loops), DRAM traffic counted;
  gpu-model  HostSystem numerics priced on the calibrated A100
             roofline (``launch/roofline.GpuRoofline``) — replacing the
             previously echoed paper constants with a model fed by the
             measured FLOPs/bytes of the compiled programs.

The paper's reported speedups ride along as reference columns so the
reproduction stays auditable.  Output: an aligned table on stdout and a
JSON record (default ``benchmarks/out/compare.json``).

  PYTHONPATH=src python -m repro.launch.compare --tiny
  make compare
"""
from __future__ import annotations

import argparse
import time

from repro.api import HierarchicalCostModel, get_workload, make_system
from repro.obs import Column, render_table, write_json
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset, make_recsys)

SYSTEMS = ("pim", "host", "gpu-model")

#: the paper's reported cross-target ratios (reference columns only —
#: the gpu-model rows are computed, not echoed)
PAPER_REFERENCE = {
    "linreg": {"gpu_over_pim": 4.1},       # §5.4.1, GPU vs LIN-BUI
    "logreg": {"pim_over_cpu": 3.9},       # LOG-BUI-LUT vs CPU
    "dtree": {"pim_over_cpu": 27.0, "pim_over_gpu": 1.34},
    "kmeans": {"pim_over_cpu": 2.8, "pim_over_gpu": 3.2},
}

#: per-target workload versions: PIM runs the paper's quantized
#: versions, the processor-centric targets run fp32 (no quantization
#: round-trip, exact transcendentals)
PLAN = [
    {"workload": "linreg", "versions": {"pim": "int32", "host": "fp32",
                                        "gpu-model": "fp32"},
     "cost": ("lin", "int32")},
    {"workload": "logreg", "versions": {"pim": "int32_lut_wram",
                                        "host": "fp32",
                                        "gpu-model": "fp32"},
     "cost": ("log", "int32_lut_wram")},
    {"workload": "dtree", "versions": {k: "fp32" for k in SYSTEMS},
     "cost": ("dtr", "fp32")},
    {"workload": "kmeans", "versions": {"pim": "int16", "host": "fp32",
                                        "gpu-model": "fp32"},
     "cost": ("kme", "int16")},
    # the EMB extension (DESIGN.md §15): PIM runs the Q(frac_bits)
    # fixed-point tables with a deferred-update window, the
    # processor-centric targets the eager fp32 baseline
    {"workload": "emb", "versions": {"pim": "int32", "host": "fp32",
                                     "gpu-model": "fp32"},
     "cost": ("emb", "int32")},
]


def _make_data(workload: str, n: int, f: int, seed: int = 0):
    if workload == "kmeans":
        X, _, _ = make_blobs(n, f, centers=8, seed=seed)
        return X, None
    if workload == "dtree":
        return make_classification(n, f, seed=seed, class_sep=1.4)
    if workload == "emb":
        # f rides as the embedding dim elsewhere; the pair width is 2
        return make_recsys(n, n_users=max(64, n // 16),
                           n_items=max(48, n // 24), dim=f, seed=seed)
    X, y, _ = make_linear_dataset(n, f, seed=seed)
    return X, y


def _shapes(tiny: bool) -> dict:
    if tiny:
        return {"linreg": (1024, 8, {"n_iters": 30}),
                "logreg": (1024, 8, {"n_iters": 30}),
                "dtree": (2048, 8, {"max_depth": 4}),
                "kmeans": (1024, 8, {"n_clusters": 4, "max_iter": 15}),
                "emb": (1024, 4, {"n_iters": 30, "batch": 32, "dim": 4,
                                  "lr": 1.0, "frac_bits": 12,
                                  "flush_every": 4})}
    return {"linreg": (8192, 16, {"n_iters": 300}),
            "logreg": (8192, 16, {"n_iters": 300}),
            "dtree": (60_000, 16, {"max_depth": 10}),
            "kmeans": (20_000, 16, {"n_clusters": 16, "max_iter": 100}),
            "emb": (16_384, 8, {"n_iters": 300, "batch": 256, "dim": 8,
                                "lr": 1.0, "frac_bits": 12,
                                "flush_every": 8})}


def _iterations(workload: str, result, params: dict) -> int:
    """Training passes the fit performed (sizes the PIM cost model)."""
    if workload == "kmeans":
        return int(result.attributes["n_iter_"])
    if workload == "dtree":
        # one split-evaluate + one commit pass per grown node pair
        return 2 * int(result.attributes["n_nodes_"])
    return int(params["n_iters"])


def run_compare(tiny: bool = False, cores: int = 16,
                seed: int = 0) -> dict:
    """Fit all four workloads on all three systems; return the record."""
    rows = []
    for plan in PLAN:
        name = plan["workload"]
        wl = get_workload(name)
        n, f, params = _shapes(tiny)[name]
        X, y = _make_data(name, n, f, seed)
        per_system: dict = {}
        for kind in SYSTEMS:
            system = make_system(kind, n_cores=cores)
            ds = system.put(X, y)
            spec = wl.spec(plan["versions"][kind], **params)
            wl.fit(ds, spec)           # warm: compile + materialize views
            snap = system.stats.snapshot()
            gpu_snap = system.gpu.snapshot() if kind == "gpu-model" else None
            t0 = time.perf_counter()
            result = wl.fit(ds, spec)  # measured: the session steady state
            wall_s = time.perf_counter() - t0
            score = (wl.score(result, X) if wl.unsupervised
                     else wl.score(result, X, y))
            s = system.stats.delta(snap)
            row = {
                "workload": name,
                "system": kind,
                "version": spec.version,
                "samples": n,
                "features": f,
                "wall_s": wall_s,
                "score": score,
                "kernel_launches": s.kernel_launches,
                "dram_bytes": s.dram_bytes,
                "cpu_to_pim_bytes": s.cpu_to_pim,
                "pim_to_cpu_bytes": s.pim_to_cpu,
            }
            iters = _iterations(name, result, params)
            row["iterations"] = iters
            if kind == "pim":
                cost_wl, cost_ver = plan["cost"]
                model = HierarchicalCostModel(system.topology)
                # the model's free k knob: cluster count (KME) or
                # minibatch size (EMB); inert for the GD workloads
                kern = params.get("n_clusters", params.get("batch", 16))
                kernel_s = iters * model.workload_seconds(
                    cost_wl, cost_ver, n, f, cores,
                    system.config.n_threads, k=kern)
                row["modeled_s"] = iters * model.step_seconds(
                    cost_wl, cost_ver, n, f, n_cores=cores,
                    n_threads=system.config.n_threads, k=kern)
                # the topology split: per-DPU kernel vs the rank-
                # serialized host-link legs (DESIGN.md §12)
                row["modeled_kernel_s"] = kernel_s
                row["modeled_transfer_s"] = row["modeled_s"] - kernel_s
            elif kind == "gpu-model":
                gpu = system.gpu.delta(gpu_snap)
                row["modeled_s"] = gpu.modeled_seconds
                row["modeled_energy_j"] = gpu.modeled_energy_j
                row["modeled_flops"] = gpu.flops
            else:
                row["modeled_s"] = wall_s    # host: measured IS the model
            # drift accounting (DESIGN.md §13.5): this container's wall
            # time over the target's model — trivially 1.0 on host,
            # where the measurement IS the model
            row["drift_ratio"] = (wall_s / row["modeled_s"]
                                  if row["modeled_s"] > 0 else None)
            per_system[kind] = row
            rows.append(row)
        # cross-target ratios (the paper's headline numbers)
        pim_s = per_system["pim"]["modeled_s"]
        host_s = per_system["host"]["modeled_s"]
        gpu_s = per_system["gpu-model"]["modeled_s"]
        ratios = {
            "pim_over_host": host_s / max(pim_s, 1e-12),
            "pim_over_gpu_model": gpu_s / max(pim_s, 1e-12),
            "paper_reference": PAPER_REFERENCE.get(name, {}),
        }
        for row in per_system.values():
            row["ratios"] = ratios
    return {"meta": {"tiny": tiny, "cores": cores, "seed": seed,
                     "systems": list(SYSTEMS)},
            "rows": rows}


#: the comparison table columns (repro.obs.format — shared formatter)
COMPARE_COLUMNS = (
    Column("workload", width=9, align="<"),
    Column("system", width=10, align="<"),
    Column("version", width=15, align="<"),
    Column("wall_s", "wall s", width=9, spec=".3f"),
    Column("modeled_s", "model s", width=10, spec=".3e"),
    Column("drift_ratio", "drift", width=9, spec=".3g"),
    Column("score", width=11, spec=".4f"),
    Column("kernel_launches", "launches", width=9, spec="d"),
)


def _ratio_note(row: dict) -> str:
    r = row.get("ratios", {})
    if row["system"] == "host":
        return f"pim {r.get('pim_over_host', 0.0):.2f}x faster"
    if row["system"] == "gpu-model":
        return (f"pim {r.get('pim_over_gpu_model', 0.0):.2f}x; "
                f"paper {r.get('paper_reference', {})}")
    return ""


def render_compare_table(record: dict) -> str:
    return render_table(record["rows"], COMPARE_COLUMNS,
                        extra=_ratio_note, rule=True)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="small shapes (seconds, CI-friendly)")
    ap.add_argument("--cores", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/out/compare.json",
                    help="JSON record path ('' disables)")
    args = ap.parse_args(argv)

    record = run_compare(tiny=args.tiny, cores=args.cores, seed=args.seed)
    print(render_compare_table(record))
    if args.out:
        # run-metadata envelope (DESIGN.md §13.7): git sha, timestamp,
        # jax version — the record stays attributable across PRs
        record = write_json(args.out, record)
        print(f"\nrecorded -> {args.out}")
    return record


if __name__ == "__main__":
    main()
