"""Workload-session launcher: train any registered PIM-ML workload.

The CLI face of the unified API (repro/api): one System session, one
resident PimDataset, N fits over it — version ladders and
hyperparameter sweeps pay the data placement once, which is the paper's
execution model (§2.2) and the enabler for serving many
training/scoring requests over resident data (ROADMAP north star).

``--system`` picks the execution target (DESIGN.md §10): the default
PIM machine, the processor-centric host baseline, or the modeled-GPU
target — the same workloads run unmodified on any of them
(``repro.launch.compare`` drives all three side by side).

  PYTHONPATH=src python -m repro.launch.pim_ml --workload linreg \
      --versions int32,hyb --samples 8192 --features 16 --iters 300 \
      --sweep lr=0.05,0.1,0.2 --reduce fabric

  PYTHONPATH=src python -m repro.launch.pim_ml --workload kmeans \
      --samples 20000 --param n_clusters=16 --param n_init=2

  PYTHONPATH=src python -m repro.launch.pim_ml --workload linreg \
      --system host --versions fp32
"""
from __future__ import annotations

import argparse
import time

from repro.api import (get_workload, list_workloads, make_estimator,
                       make_system)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset, make_recsys)
from repro.obs import Column

#: per-fit table columns (repro.obs.format — the shared formatter the
#: launch CLIs render through)
FIT_COLUMNS = (
    Column("version", width=16, align="<"),
    Column("sweep", width=14, align="<", default=""),
    Column("score", width=9, spec=".4f"),
    Column("fit_s", width=7, spec=".2f"),
    Column("shard_transfers", "shards", width=6, spec="d"),
)


def _parse_value(text: str):
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


def _make_data(workload: str, n: int, f: int, seed: int):
    if workload == "kmeans":
        X, _, _ = make_blobs(n, f, centers=16, seed=seed)
        return X, None
    if workload == "dtree":
        return make_classification(n, f, seed=seed, class_sep=1.4)
    if workload == "emb":
        # --features rides as the embedding dim; the pair width is 2
        return make_recsys(n, n_users=max(64, n // 16),
                           n_items=max(48, n // 24), dim=max(2, f),
                           seed=seed)
    X, y, _ = make_linear_dataset(n, f, seed=seed)
    return X, y


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="linreg",
                    choices=sorted(list_workloads()))
    ap.add_argument("--versions", default="",
                    help="comma list; default = all versions")
    ap.add_argument("--samples", type=int, default=8192)
    ap.add_argument("--features", type=int, default=16)
    ap.add_argument("--cores", type=int, default=16)
    ap.add_argument("--system", default="pim",
                    choices=("pim", "host", "gpu-model"),
                    help="execution target (DESIGN.md §10): the PIM "
                         "machine, the processor-centric host baseline, "
                         "or the A100-roofline modeled GPU")
    ap.add_argument("--iters", type=int, default=0,
                    help="override n_iters/max_iter when > 0")
    ap.add_argument("--reduce", default="fabric",
                    choices=("fabric", "host", "hierarchical"))
    ap.add_argument("--kernel-backend", default=None,
                    choices=("pallas_tpu", "pallas_interpret", "jnp_ref"),
                    help="kernel-dispatch backend for the trainer hot "
                         "paths (default: per-platform auto-selection)")
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="compile this many consecutive training steps "
                         "into one lax.scan launch (LIN/LOG/KME; "
                         "DESIGN.md §9).  1 = per-step host loop; 32 is "
                         "a good default for the fused engine")
    ap.add_argument("--sweep", default="",
                    help="hyper sweep, e.g. lr=0.05,0.1,0.2")
    ap.add_argument("--param", action="append", default=[],
                    help="extra hyperparameter, e.g. n_clusters=8")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    wl = get_workload(args.workload)
    versions = ([v for v in args.versions.split(",") if v]
                or list(wl.versions))
    params = dict(p.split("=", 1) for p in args.param)
    params = {k: _parse_value(v) for k, v in params.items()}
    if args.kernel_backend:
        params["kernel_backend"] = args.kernel_backend
    if args.fuse_steps > 1:
        if "fuse_steps" not in wl.defaults:
            ap.error(f"--fuse-steps does not apply to {wl.name} "
                     f"(not an iterative GD/Lloyd's workload)")
        params["fuse_steps"] = args.fuse_steps
    if args.iters > 0:
        iter_key = next((k for k in ("max_iter", "n_iters")
                         if k in wl.defaults), None)
        if iter_key is None:
            ap.error(f"--iters does not apply to {wl.name} "
                     f"(no iteration hyperparameter; try --param "
                     f"max_depth=N)")
        params[iter_key] = args.iters

    sweep = [("", None)]
    if args.sweep:
        key, _, vals = args.sweep.partition("=")
        sweep = [(key, _parse_value(v)) for v in vals.split(",")]

    system = make_system(args.system, n_cores=args.cores,
                         reduce=args.reduce)
    X, y = _make_data(wl.name, args.samples, args.features, args.seed)
    ds = system.put(X, y)
    print(f"session: {wl.name} on {args.system} ({args.cores} cores, "
          f"reduce={args.reduce}), dataset "
          f"{args.samples}x{args.features} (resident)")

    # stream one formatted row per fit (header first — the shared
    # column specs keep this table in lockstep with the other CLIs)
    print("  " + " ".join(c.head() for c in FIT_COLUMNS))
    for ver in versions:
        for skey, sval in sweep:
            p = dict(params)
            if skey:
                p[skey] = sval
            t0 = time.perf_counter()
            est = make_estimator(wl.name, version=ver, system=system,
                                 **p).fit(ds)
            dt = time.perf_counter() - t0
            score = (est.score(X) if wl.unsupervised else est.score(X, y))
            row = {"version": ver,
                   "sweep": f"{skey}={sval}" if skey else None,
                   "score": score, "fit_s": dt,
                   "shard_transfers": system.stats.shard_transfers}
            print("  " + " ".join(c.cell(row) for c in FIT_COLUMNS))

    s = system.stats
    if system.kind == "pim":
        print(f"transfers: cpu->pim {s.cpu_to_pim:,} B "
              f"(dataset shards {s.shard_bytes:,} B in {s.shard_transfers} "
              f"transfers), pim->cpu {s.pim_to_cpu:,} B, "
              f"inter-core via host {s.inter_core_via_host:,} B")
    else:
        print(f"traffic: DRAM {s.dram_bytes:,} B streamed over "
              f"{s.kernel_launches} launches "
              f"({s.shard_transfers} view materializations, "
              f"{s.shard_bytes:,} B resident)")
    if system.kind == "gpu-model":
        g = system.gpu
        print(f"modeled A100: {g.modeled_seconds * 1e3:.3f} ms, "
              f"{g.modeled_energy_j:.3f} J over {g.launches} launches "
              f"({g.flops:.3e} FLOPs, {g.hbm_bytes:.3e} HBM B)")


if __name__ == "__main__":
    main()
