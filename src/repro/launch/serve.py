"""Serving launcher: batched generation with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models.api import Model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=args.slots,
                         max_seq=args.max_seq)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size,
                                       args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    out = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in out)
    print(f"served {len(out)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    for i, r in enumerate(out[:3]):
        print(f"req{i}: prompt={r.prompt[:8].tolist()}... "
              f"output={r.output[:12]}...")


if __name__ == "__main__":
    main()
