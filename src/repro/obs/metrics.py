"""Metrics registry: counters, gauges, histograms (DESIGN.md §13.4).

One structured home for the runtime's numeric telemetry, with the two
semantics every existing ad-hoc stats object already needed:

  ``snapshot()/delta()``   attributable readings when many jobs share
                           one instrument (the ``TransferStats``
                           discipline, DESIGN.md §7.2);
  parent mirroring         a child registry forwards every increment to
                           its parent, so slice-scoped metrics stay
                           per-job readable while global totals keep
                           accumulating — the ``_MirrorStats`` /
                           ``_MirrorGpuReport`` pattern (PR 6)
                           generalized to arbitrary metrics.

The scheduler owns a registry for its control-plane counters
(admissions, evictions, checkpoints, drift samples — sched/scheduler.py)
and ``PimScheduler.stats()`` / ``JobHandle.metrics()`` render registry
plus the legacy dataclass counters into one JSON-serializable surface.

Histograms are fixed-boundary (no allocation per observe): ``bounds``
gives the upper edges; observations above the last edge land in the
overflow bucket.  ``DRIFT_BUCKETS`` is the log ladder for
modeled-vs-measured wall-time ratios (container wall time over modeled
UPMEM seconds routinely sits orders of magnitude above 1 — the point is
*stability*, not unity; DESIGN.md §13.5).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Optional, Sequence, Tuple

#: log-spaced ratio buckets for measured/modeled drift histograms
DRIFT_BUCKETS: Tuple[float, ...] = (
    0.01, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0, 100.0, 1e3, 1e4, 1e5, 1e6)

#: One process-wide reentrant lock serializes every metric write AND the
#: registry's lazy creation path.  The scheduler's background drain
#: thread (serve mode, DESIGN.md §14.2) increments these concurrently
#: with caller-thread ``stats()``/``metrics()`` reads; a single coarse
#: lock keeps parent-mirroring chains atomic end to end (child += n and
#: parent += n commit together) at negligible cost — metric updates are
#: control-plane, not hot-loop.  Reentrant because a mirrored child's
#: update calls the parent's under the same lock.
_LOCK = threading.RLock()


class Counter:
    """Monotonic counter; increments forward to a parent counter."""

    __slots__ = ("value", "_parent")

    def __init__(self, parent: Optional["Counter"] = None):
        self.value = 0
        self._parent = parent

    def inc(self, n: int = 1) -> None:
        with _LOCK:
            self.value += n
            if self._parent is not None:
                self._parent.inc(n)

    def snapshot(self) -> int:
        return self.value

    def delta(self, snapshot: int) -> int:
        return self.value - snapshot


class Gauge:
    """Point-in-time value; sets propagate to the parent (last write
    wins there, exactly as a shared gauge should behave)."""

    __slots__ = ("value", "_parent")

    def __init__(self, parent: Optional["Gauge"] = None):
        self.value = 0.0
        self._parent = parent

    def set(self, value: float) -> None:
        with _LOCK:
            self.value = float(value)
            if self._parent is not None:
                self._parent.set(value)

    def snapshot(self) -> float:
        return self.value

    def delta(self, snapshot: float) -> float:
        return self.value - snapshot


class Histogram:
    """Fixed-boundary histogram with count/total/min/max.

    ``bounds`` are inclusive upper edges; bucket i counts observations
    ``<= bounds[i]`` (and the final bucket everything above the last
    edge).  ``observe`` forwards to the parent histogram when mirrored.
    """

    __slots__ = ("bounds", "buckets", "count", "total", "min", "max",
                 "_parent")

    def __init__(self, bounds: Sequence[float] = DRIFT_BUCKETS,
                 parent: Optional["Histogram"] = None):
        self.bounds = tuple(sorted(float(b) for b in bounds))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket edge")
        self.buckets = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._parent = parent

    def observe(self, value: float) -> None:
        value = float(value)
        with _LOCK:
            self.buckets[bisect.bisect_left(self.bounds, value)] += 1
            self.count += 1
            self.total += value
            self.min = value if self.min is None else min(self.min, value)
            self.max = value if self.max is None else max(self.max, value)
            if self._parent is not None:
                self._parent.observe(value)

    @property
    def mean(self) -> Optional[float]:
        return (self.total / self.count) if self.count else None

    def to_dict(self) -> dict:
        with _LOCK:   # consistent multi-field reading vs. observe()
            return {"bounds": list(self.bounds),
                    "buckets": list(self.buckets),
                    "count": self.count, "total": self.total,
                    "mean": self.mean, "min": self.min, "max": self.max}

    def snapshot(self) -> dict:
        return self.to_dict()

    def delta(self, snapshot: dict) -> dict:
        """Observations since ``snapshot`` (bucket-wise difference;
        min/max cannot be un-merged and are reported as None)."""
        if tuple(snapshot.get("bounds", ())) != self.bounds:
            raise ValueError("histogram delta across different bounds")
        buckets = [a - b for a, b in zip(self.buckets,
                                         snapshot["buckets"])]
        count = self.count - snapshot["count"]
        total = self.total - snapshot["total"]
        return {"bounds": list(self.bounds), "buckets": buckets,
                "count": count, "total": total,
                "mean": (total / count) if count else None,
                "min": None, "max": None}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metrics with registry-level snapshot/delta and mirroring.

    ``MetricsRegistry(parent=global_registry)`` creates a *child* whose
    metrics forward every increment/observation to the same-named
    metric of the parent (created there on demand with matching type) —
    per-slice attribution without double bookkeeping.
    """

    def __init__(self, parent: Optional["MetricsRegistry"] = None):
        self._parent = parent
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind: str, **kwargs):
        with _LOCK:
            metric = self._metrics.get(name)
            if metric is None:
                parent_metric = (self._parent._get(name, kind, **kwargs)
                                 if self._parent is not None else None)
                metric = _KINDS[kind](parent=parent_metric, **kwargs)
                self._metrics[name] = metric
            elif not isinstance(metric, _KINDS[kind]):
                raise TypeError(
                    f"metric {name!r} is a {type(metric).__name__}, "
                    f"not a {kind}")
            return metric

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str,
                  bounds: Sequence[float] = DRIFT_BUCKETS) -> Histogram:
        return self._get(name, "histogram", bounds=bounds)

    def names(self) -> tuple:
        with _LOCK:
            return tuple(sorted(self._metrics))

    def snapshot(self) -> dict:
        """Plain-value snapshot of every metric (JSON-serializable)."""
        with _LOCK:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())}

    def delta(self, snapshot: dict) -> dict:
        """Per-metric change since ``snapshot``.  Metrics created after
        the snapshot delta against a zero baseline."""
        with _LOCK:
            out = {}
            for name, m in sorted(self._metrics.items()):
                if name in snapshot:
                    out[name] = m.delta(snapshot[name])
                elif isinstance(m, Histogram):
                    out[name] = m.to_dict()
                else:
                    out[name] = m.snapshot()
            return out

    def to_dict(self) -> dict:
        with _LOCK:
            return {name: (m.to_dict() if isinstance(m, Histogram)
                           else m.value)
                    for name, m in sorted(self._metrics.items())}
