"""Span tracer: the event source of the telemetry layer (DESIGN.md §13).

One process-global :class:`Tracer` collects timing *events* — nestable
spans, instant markers, and counter samples — into a thread-safe ring
buffer.  Every execution layer is instrumented against it: ``System``
kernel launches and fused chunks (systems/base.py), dataset shard
transfers (api/dataset.py), model broadcasts (systems/pim.py),
scheduler admission / gang-step chunks / elastic events
(sched/scheduler.py), and allocator channel occupancy
(sched/allocator.py).  The buffer renders to a Chrome trace-event file
via :mod:`repro.obs.chrome_trace` (``pim_jobs --trace out.json`` or the
``REPRO_TRACE`` environment variable).

Overhead contract (asserted by tests/test_obs.py): the tracer is
**disabled by default** and a disabled call is one attribute check plus
a constant return — no event dict, no timestamp, no lock.  Hot paths
that would pay even for building a span *name* guard on
``TRACER.enabled`` first (the ``_launch_span`` idiom in
systems/base.py).  Enabled, each event is one ``perf_counter`` pair and
one deque append; the ring buffer (default 200k events) bounds memory
on long-running services by dropping the *oldest* events.

Tracks: every event names a ``track`` — a free-form string rendered as
its own timeline row.  The repo's taxonomy (DESIGN.md §13.2):

  ``sched``             scheduler control flow (admission, defragment)
  ``target:<name>``     per-execution-System timeline of chunk spans
  ``job:<name>``        per-job timeline (one row per tenant)
  ``system:<kind>``     kernel launches / transfers of one System kind
  ``channels:<name>``   per-memory-channel occupancy counters

Timestamps are microseconds of ``time.perf_counter()`` since tracer
construction (monotonic; wall-clock anchoring travels in the run
metadata envelope, repro/obs/runmeta.py).  Spans measure *host-visible*
time: under jax async dispatch a launch span covers dispatch plus any
blocking the call itself performs.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional

#: default ring-buffer capacity (events); ~100 B/event -> ~20 MB ceiling
DEFAULT_CAPACITY = 200_000


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """An open span; appends one complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_track", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, track: str, cat: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer.now_us()
        return self

    def __exit__(self, *exc):
        t = self._tracer
        t._append({"ph": "X", "name": self._name, "cat": self._cat,
                   "track": self._track, "ts": self._t0,
                   "dur": t.now_us() - self._t0,
                   "args": self._args or {}})
        return False


class Tracer:
    """Thread-safe ring buffer of trace events.

    ``enabled`` is the single hot-path gate: every emitting method
    checks it first and returns immediately when off.  Events are plain
    dicts (``ph``/``name``/``cat``/``track``/``ts``[/``dur``]/``args``)
    — the exporter maps ``track`` strings onto Chrome trace pid/tid
    pairs (repro/obs/chrome_trace.py)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.enabled = False
        self._events: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()

    # -- control -------------------------------------------------------------

    def enable(self, capacity: Optional[int] = None) -> None:
        """Turn event collection on (idempotent).  ``capacity`` resizes
        the ring buffer, discarding buffered events."""
        if capacity is not None and capacity != self._events.maxlen:
            with self._lock:
                self._events = deque(self._events, maxlen=capacity)
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def now_us(self) -> float:
        """Microseconds since tracer construction (monotonic)."""
        return (time.perf_counter() - self._epoch) * 1e6

    def _append(self, event: dict) -> None:
        # deque.append with maxlen is atomic under the GIL; the lock
        # only guards structural operations (events()/clear()/resize)
        self._events.append(event)

    # -- emission ------------------------------------------------------------

    def span(self, name: str, track: str = "main", cat: str = "default",
             **args):
        """Context manager timing a nested span on ``track``.

        Disabled: returns the shared no-op immediately.  Spans on one
        track must nest (the exporter validates containment) — which
        they do by construction when emitted from ``with`` blocks on a
        single thread per track."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, track, cat, args or None)

    def instant(self, name: str, track: str = "main",
                cat: str = "default", **args) -> None:
        """A zero-duration marker (elastic preempt/resume/retry/...)."""
        if not self.enabled:
            return
        self._append({"ph": "i", "name": name, "cat": cat, "track": track,
                      "ts": self.now_us(), "args": args})

    def counter(self, name: str, value: float, track: str = "counters",
                cat: str = "counter") -> None:
        """Sample a numeric series (e.g. per-channel occupancy)."""
        if not self.enabled:
            return
        self._append({"ph": "C", "name": name, "cat": cat, "track": track,
                      "ts": self.now_us(), "args": {"value": value}})

    # -- inspection ----------------------------------------------------------

    def events(self) -> list:
        """Snapshot of the buffered events (oldest first)."""
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        return len(self._events)


#: the process-global tracer every instrumentation site emits to
TRACER = Tracer()


def span(name: str, track: str = "main", cat: str = "default", **args):
    return TRACER.span(name, track, cat, **args)


def instant(name: str, track: str = "main", cat: str = "default",
            **args) -> None:
    TRACER.instant(name, track, cat, **args)


def counter(name: str, value: float, track: str = "counters") -> None:
    TRACER.counter(name, value, track)
