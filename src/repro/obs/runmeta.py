"""Run-metadata envelope for persisted results (DESIGN.md §13.7).

Every JSON the benchmarks and CLIs write into ``benchmarks/out/`` is a
point on the repo's perf trajectory — but a bare number is
unattributable once the tree moves.  :func:`run_meta` captures the
provenance that makes a record comparable across PRs:

  ``git_sha``      commit the run was taken at (None outside a repo)
  ``git_dirty``    whether the worktree had uncommitted changes
  ``timestamp``    UTC ISO-8601 wall-clock instant
  ``jax_version``  the library actually executing the kernels
  ``python`` / ``platform``  interpreter and host identification

:func:`write_json` stamps the envelope under a ``run_meta`` key and
writes atomically (tmp + rename) — ``benchmarks/common.py`` re-exports
it so every bench shares one writer.
"""
from __future__ import annotations

import json
import os
import platform as _platform
import subprocess
import sys
from datetime import datetime, timezone
from typing import Optional


def _git(args, cwd: str) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git"] + args, cwd=cwd, capture_output=True, text=True,
            timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.strip()


def run_meta(cwd: Optional[str] = None) -> dict:
    """The provenance envelope; every field degrades to None rather
    than raising (git absent, detached container, ...)."""
    cwd = cwd or os.path.dirname(os.path.abspath(__file__))
    sha = _git(["rev-parse", "HEAD"], cwd)
    status = _git(["status", "--porcelain"], cwd)
    try:
        import jax
        jax_version = jax.__version__
    except Exception:
        jax_version = None
    return {
        "git_sha": sha,
        "git_dirty": bool(status) if status is not None else None,
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "jax_version": jax_version,
        "python": sys.version.split()[0],
        "platform": _platform.platform(),
    }


def write_json(path: str, payload: dict, indent: int = 2) -> dict:
    """Stamp ``payload["run_meta"]`` and write atomically; returns the
    stamped payload.  The envelope is added at write time so records
    carry the provenance of the moment they were persisted."""
    payload = dict(payload)
    payload["run_meta"] = run_meta()
    directory = os.path.dirname(path)
    if directory:
        os.makedirs(directory, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=indent)
    os.replace(tmp, path)
    return payload
