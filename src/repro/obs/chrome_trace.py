"""Chrome trace-event JSON export of the tracer buffer (DESIGN.md §13.3).

Renders :mod:`repro.obs.trace` events as the Trace Event Format that
``chrome://tracing`` and Perfetto load: the scheduler timeline becomes
one row per target System, per job, and per memory channel, with
elastic preempt/resume/retry markers as instant events and channel
occupancy as counter series.

Track mapping: the tracer's free-form ``track`` strings carry a
``group:member`` convention (``target:pim``, ``job:job0:linreg/int32``,
``channels:pim``).  The exporter assigns one Chrome *process* (pid) per
group and one *thread* (tid) per distinct track, then emits ``M``
metadata events naming both — so Perfetto groups the rows exactly along
the repo's span taxonomy.  Assignment order is first-appearance, which
is deterministic for a deterministic event stream (asserted under a
seeded manifest by tests/test_obs.py).

``validate_chrome_trace`` is the schema contract the tests assert:
required fields per phase, numeric timestamps, and proper span
containment per (pid, tid) row.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

#: phases this exporter emits (a subset of the trace-event format)
_PHASES = ("X", "i", "C", "M")


def to_chrome_trace(events: List[dict]) -> dict:
    """Convert tracer events to a ``{"traceEvents": [...]}`` document.

    Events keep their buffer order (which is time order per track);
    metadata rows for every pid/tid are prepended so viewers label the
    tracks before the first sample arrives."""
    pids: dict = {}
    tids: dict = {}
    body = []
    for ev in events:
        track = str(ev.get("track", "main"))
        group = track.split(":", 1)[0]
        pid = pids.setdefault(group, len(pids) + 1)
        if track not in tids:
            tids[track] = (pid, len(tids) + 1)
        tid = tids[track][1]
        out = {
            "ph": ev["ph"],
            "name": str(ev["name"]),
            "cat": str(ev.get("cat", "default")),
            "ts": float(ev["ts"]),
            "pid": pid,
            "tid": tid,
            "args": dict(ev.get("args") or {}),
        }
        if ev["ph"] == "X":
            out["dur"] = max(0.0, float(ev.get("dur", 0.0)))
        elif ev["ph"] == "i":
            out["s"] = "t"      # thread-scoped instant
        body.append(out)

    meta = []
    for group, pid in pids.items():
        meta.append({"ph": "M", "name": "process_name", "pid": pid,
                     "tid": 0, "ts": 0.0,
                     "args": {"name": group}})
    for track, (pid, tid) in tids.items():
        meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                     "tid": tid, "ts": 0.0,
                     "args": {"name": track}})
    return {"traceEvents": meta + body, "displayTimeUnit": "ms"}


def write_chrome_trace(events: List[dict], path: str) -> dict:
    """Export ``events`` to ``path`` (atomic tmp+rename); returns the
    document."""
    doc = to_chrome_trace(events)
    directory = os.path.dirname(path)
    if directory:
        os.makedirs(directory, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh)
    os.replace(tmp, path)
    return doc


def track_names(doc: dict) -> set:
    """The track (thread) names declared by a trace document."""
    return {ev["args"]["name"] for ev in doc.get("traceEvents", [])
            if ev.get("ph") == "M" and ev.get("name") == "thread_name"}


def validate_chrome_trace(doc: dict) -> None:
    """Assert the trace-event schema; raises ``ValueError`` on the
    first violation.

    Checks (the tests/test_obs.py contract):
      * top level is ``{"traceEvents": [...]}``;
      * every event has ``ph``/``name``/``pid``/``tid``/``ts``, with
        integer pid/tid and numeric ts;
      * ``X`` events carry a non-negative ``dur``;
      * per (pid, tid) row, ``X`` spans properly nest — a span either
        starts after the enclosing one ends or lies fully inside it.
    """
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace document must carry a traceEvents list")
    rows: dict = {}
    for i, ev in enumerate(events):
        for field in ("ph", "name", "pid", "tid", "ts"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if not isinstance(ev["pid"], int) or not isinstance(ev["tid"], int):
            raise ValueError(f"event {i} pid/tid must be ints: {ev}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i} ts must be numeric: {ev}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i} X-span needs dur >= 0: {ev}")
            rows.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for key, spans in rows.items():
        _check_nesting(key, spans)


def _check_nesting(row, spans: List[dict]) -> None:
    """Spans on one row must form a forest: children inside parents."""
    stack: List[tuple] = []     # (start, end) of open ancestors
    for ev in sorted(spans, key=lambda e: (e["ts"], -e["dur"])):
        start, end = ev["ts"], ev["ts"] + ev["dur"]
        while stack and start >= stack[-1][1]:
            stack.pop()
        if stack and end > stack[-1][1] + 1e-6:
            raise ValueError(
                f"row {row}: span {ev['name']!r} [{start}, {end}] "
                f"overlaps its enclosing span ending at {stack[-1][1]}")
        stack.append((start, end))


def load_chrome_trace(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def summarize(doc: dict) -> dict:
    """Per-track event counts + span time (quick CLI sanity line)."""
    names = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    out: dict = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M":
            continue
        track = names.get((ev["pid"], ev["tid"]),
                          f"{ev['pid']}:{ev['tid']}")
        row = out.setdefault(track, {"events": 0, "span_us": 0.0})
        row["events"] += 1
        if ev["ph"] == "X":
            row["span_us"] += ev.get("dur", 0.0)
    return out
