"""Shared column-spec table rendering for the launch CLIs (DESIGN.md §13.6).

``pim_jobs``, ``pim_ml``, and ``compare`` used to hand-roll their own
f-string tables; a new metric meant editing three printers.  Each CLI
now declares its columns as :class:`Column` specs over its report rows
(plain dicts) and calls :func:`render_table` — so anything added to
``job_report``/``run_compare`` rows appears everywhere by adding one
spec entry.

A :class:`Column` maps a row key to a fixed-width cell:

  ``Column("modeled_dpu_seconds", "dpu_s", width=10, spec="10.3e")``

``spec`` is a ``format()`` mini-language string applied when the value
is present; missing keys render as ``default`` (``"-"``).  ``extra`` on
:func:`render_table` appends a free-form suffix per row (error strings,
ratio notes) outside the column grid.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Sequence


@dataclass(frozen=True)
class Column:
    """One table column: row ``key`` -> fixed-width formatted cell."""

    key: str
    header: Optional[str] = None
    width: int = 10
    spec: str = "s"                  # format() spec for present values
    align: str = ">"                 # header/missing-value alignment
    default: str = "-"

    @property
    def title(self) -> str:
        return self.header if self.header is not None else self.key

    def cell(self, row: dict) -> str:
        value = row.get(self.key)
        if value is None:
            text = self.default
        else:
            try:
                text = format(value, self.spec)
            except (TypeError, ValueError):
                text = str(value)
        if len(text) > self.width:
            # left-truncate numbers never; clip long labels from the right
            text = text[: self.width]
        return f"{text:{self.align}{self.width}}"

    def head(self) -> str:
        return f"{self.title[: self.width]:{self.align}{self.width}}"


def render_table(rows: Iterable[dict], columns: Sequence[Column],
                 extra: Optional[Callable[[dict], str]] = None,
                 rule: bool = False) -> str:
    """Render ``rows`` under a header line; one string, no trailing \\n.

    ``extra(row)`` may return a suffix appended after the last column
    (empty string for none); ``rule=True`` draws a dash rule under the
    header."""
    lines: List[str] = [" ".join(c.head() for c in columns)]
    if rule:
        lines.append("-" * len(lines[0]))
    for row in rows:
        line = " ".join(c.cell(row) for c in columns)
        if extra is not None:
            suffix = extra(row)
            if suffix:
                line = f"{line}  {suffix}"
        lines.append(line)
    return "\n".join(lines)


def format_bytes(n: int) -> str:
    """Thousands-separated byte count (``1,234,567 B``)."""
    return f"{n:,} B"


def format_ratio(value: Optional[float]) -> str:
    """Drift/speedup ratio with sensible sig-figs; ``-`` when absent."""
    if value is None:
        return "-"
    if value >= 100:
        return f"{value:.0f}x"
    return f"{value:.2f}x"
