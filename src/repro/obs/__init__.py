"""repro.obs — the unified telemetry layer (DESIGN.md §13).

Zero-dependency observability for the whole runtime:

  :mod:`repro.obs.trace`         span tracer (ring buffer, global TRACER)
  :mod:`repro.obs.chrome_trace`  Chrome trace-event JSON export
  :mod:`repro.obs.metrics`       counters / gauges / histograms registry
  :mod:`repro.obs.format`        shared CLI table rendering
  :mod:`repro.obs.runmeta`       provenance envelope for persisted JSON

Environment hook: setting ``REPRO_TRACE=/path/to/trace.json`` enables
the global tracer at import time and registers an atexit export of the
buffer to that path — any entry point (CLI, pytest, notebook) becomes
traceable without code changes.
"""
from __future__ import annotations

import atexit
import os

from repro.obs.chrome_trace import (load_chrome_trace, summarize,
                                    to_chrome_trace, track_names,
                                    validate_chrome_trace,
                                    write_chrome_trace)
from repro.obs.format import Column, format_bytes, format_ratio, render_table
from repro.obs.metrics import (DRIFT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.runmeta import run_meta, write_json
from repro.obs.trace import TRACER, Tracer, counter, instant, span

__all__ = [
    "TRACER", "Tracer", "span", "instant", "counter",
    "to_chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "load_chrome_trace", "track_names", "summarize",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "DRIFT_BUCKETS",
    "Column", "render_table", "format_bytes", "format_ratio",
    "run_meta", "write_json",
]


def _install_env_trace() -> None:
    path = os.environ.get("REPRO_TRACE")
    if not path:
        return
    TRACER.enable()

    def _export() -> None:
        events = TRACER.events()
        if events:
            write_chrome_trace(events, path)

    atexit.register(_export)


_install_env_trace()
