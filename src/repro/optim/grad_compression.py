"""int8-compressed gradient all-reduce with error feedback.

The paper's central numerics insight — quantize to match what the hardware
moves/computes natively — applied to the *collective* term of the roofline:
gradients are symmetrically quantized to int8 before the cross-replica
reduction (4x fewer bytes on the wire than f32, 2x fewer than bf16), with a
persistent error-feedback buffer so the quantization noise is unbiased over
steps (Karimireddy et al.-style EF-SGD).

Used by the explicit-DP trainer (shard_map over the data axis; the paper's
PIM schedule for LMs) — the pjit path keeps XLA's fused reductions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def compress_decompress_psum(g: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Quantize -> int8 psum (in int32 to avoid overflow) -> dequantize.

    The scale itself is psum-maxed first (one tiny f32 collective) so every
    replica uses the same grid; the payload collective is int8-width.
    """
    amax = jnp.max(jnp.abs(g))
    amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    # accumulate in int32: world size up to 2^24 replicas stays exact
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def ef_compress_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str,
                     world: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback variant: returns (mean gradient, new error buffer)."""
    corrected = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127)
    new_err = corrected - q * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / world, new_err


def init_error_buffers(grads_tree):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_tree)


def compressed_bytes_saved(grads_tree) -> tuple[int, int]:
    """(bytes f32 all-reduce, bytes int8 all-reduce) for reporting."""
    n = sum(int(jnp.size(g)) for g in jax.tree_util.tree_leaves(grads_tree))
    return 4 * n, n
