"""AdamW in pure JAX (no optax in this container).

f32 master moments regardless of param dtype (bf16 weights get f32 m/v —
the standard mixed-precision recipe); moments inherit the parameter
sharding so optimizer state scales with the model shards.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0

    def init(self, params) -> AdamState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                         v=jax.tree_util.tree_map(jnp.copy, zeros))

    def init_shapes(self, param_shapes) -> AdamState:
        zeros = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
            param_shapes)
        return AdamState(step=jax.ShapeDtypeStruct((), jnp.int32), m=zeros,
                         v=zeros)

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        gf = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g))
                for g in jax.tree_util.tree_leaves(gf)) + 1e-12)
            scale = jnp.minimum(1.0, self.grad_clip / gnorm)
            gf = jax.tree_util.tree_map(lambda g: g * scale, gf)
        else:
            gnorm = jnp.float32(0.0)
        m = jax.tree_util.tree_map(
            lambda mm, g: self.b1 * mm + (1 - self.b1) * g, state.m, gf)
        v = jax.tree_util.tree_map(
            lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
            state.v, gf)
        t = step.astype(jnp.float32)
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, AdamState(step=step, m=m, v=v), gnorm


@dataclasses.dataclass(frozen=True)
class SGD:
    """Plain SGD (the paper's host-side update rule for LIN/LOG)."""
    lr: float = 0.1

    def init(self, params):
        return AdamState(step=jnp.zeros((), jnp.int32), m={}, v={})

    def update(self, grads, state, params):
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - self.lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, AdamState(step=state.step + 1, m={}, v={}), \
            jnp.float32(0.0)
