"""Fault injection: deterministic step failures for recovery testing.

``REPRO_INJECT_FAULT`` (env) or a scheduler-level :class:`FaultInjector`
plants exceptions inside job steps; the scheduler's supervised-retry
path (DESIGN.md §11.4) restores the job's last in-memory snapshot and
continues, burning one unit of the job's retry budget per recovery —
the `run_with_recovery` contract (train/fault_tolerance.py) applied
per-tenant.

Env syntax — comma-separated ``pattern:step[:count]`` entries::

    REPRO_INJECT_FAULT="job0*:3"        # fail job0* at its 3rd step
    REPRO_INJECT_FAULT="*:2:5"          # fail every job's step 2, 5x
    REPRO_INJECT_FAULT="lin*:1,kme*:4"  # several plans

``pattern`` is an fnmatch glob over the job name; ``step`` is the
1-based scheduling turn at which the fault fires; ``count`` is how many
times that entry fires across retries (default 1 — the retry survives).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import os
from typing import List, Optional

ENV_VAR = "REPRO_INJECT_FAULT"


class InjectedFault(RuntimeError):
    """The planted failure (distinguishable from organic errors)."""


@dataclasses.dataclass
class _Plan:
    pattern: str
    step: int
    count: int


class FaultInjector:
    """Callable scheduler hook: ``injector(job_name, step) -> bool``
    returns True when a planted fault should fire this turn (the
    scheduler then raises :class:`InjectedFault` inside the job's step,
    where it is indistinguishable from a real kernel failure)."""

    def __init__(self, plans: Optional[List[_Plan]] = None):
        self.plans = list(plans or [])
        self.fired = 0

    @classmethod
    def parse(cls, text: str) -> "FaultInjector":
        plans = []
        for entry in filter(None, (e.strip() for e in text.split(","))):
            parts = entry.split(":")
            if len(parts) not in (2, 3):
                raise ValueError(
                    f"bad {ENV_VAR} entry {entry!r}; expected "
                    f"pattern:step[:count]")
            plans.append(_Plan(parts[0], int(parts[1]),
                               int(parts[2]) if len(parts) == 3 else 1))
        return cls(plans)

    def plan(self, pattern: str, step: int, count: int = 1) -> None:
        self.plans.append(_Plan(pattern, step, count))

    def __call__(self, job_name: str, step: int) -> bool:
        for p in self.plans:
            if p.count > 0 and p.step == step \
                    and fnmatch.fnmatch(job_name, p.pattern):
                p.count -= 1
                self.fired += 1
                return True
        return False


def injector_from_env(environ=None) -> Optional[FaultInjector]:
    """The ambient injector, or None when ``REPRO_INJECT_FAULT`` is
    unset/empty.  Read once at scheduler construction."""
    text = (environ if environ is not None else os.environ).get(ENV_VAR)
    if not text:
        return None
    return FaultInjector.parse(text)
