"""Elastic job runtime (DESIGN.md §11).

The robustness layer over the scheduler/System stack: resumable
trainers expose their chunk-boundary carry as lazy
:class:`~repro.systems.base.ChunkTick` snapshots; this package gives
those snapshots an on-disk life (atomic job checkpoints via
train/checkpoint.py), an identity (config+dataset fingerprints), a
migration policy (which System kinds a carry may resume on), and a
failure source (deterministic fault injection) — the pieces
``PimScheduler`` composes into preemption, priority eviction,
defragmentation, cross-System migration, supervised retry, and
crash-survivable job queues.
"""
from __future__ import annotations

from .checkpoint import (has_checkpoint, job_dir, load_snapshot,
                         save_snapshot)
from .fault import (ENV_VAR, FaultInjector, InjectedFault,
                    injector_from_env)
from .fingerprint import (dataset_fingerprint, job_fingerprint,
                          spec_fingerprint)
from .state import (SCHEMA_VERSION, check_migration, migration_ok,
                    pack_rng, snapshot_iters, unpack_rng)

__all__ = [
    "ENV_VAR", "FaultInjector", "InjectedFault", "SCHEMA_VERSION",
    "check_migration", "dataset_fingerprint", "has_checkpoint",
    "injector_from_env", "job_dir", "job_fingerprint", "load_snapshot",
    "migration_ok", "pack_rng", "save_snapshot", "snapshot_iters",
    "spec_fingerprint", "unpack_rng",
]
