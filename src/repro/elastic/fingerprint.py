"""Config + dataset fingerprints: what makes a checkpoint resumable.

A chunk-boundary snapshot is only valid against the *same* training
problem: same host arrays, same workload/version/hyperparameters.  The
fingerprint is a sha256 over both, stored inside every job checkpoint
(DESIGN.md §11.1) and re-derived at resume time — a mismatch (edited
manifest, regenerated dataset, different seed) refuses to resume
instead of silently continuing a different fit.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Mapping, Optional

import numpy as np


def _hash_array(h, arr: Optional[np.ndarray]) -> None:
    if arr is None:
        h.update(b"none")
        return
    a = np.ascontiguousarray(arr)
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())


def _jsonable(value: Any) -> Any:
    """Params may hold numpy scalars / enums; normalize for hashing."""
    if isinstance(value, (np.integer, np.floating)):
        return value.item()
    return value


def dataset_fingerprint(X: np.ndarray,
                        y: Optional[np.ndarray] = None) -> str:
    h = hashlib.sha256()
    _hash_array(h, np.asarray(X))
    _hash_array(h, None if y is None else np.asarray(y))
    return h.hexdigest()[:32]


def spec_fingerprint(workload: str, version: str,
                     params: Mapping[str, Any]) -> str:
    h = hashlib.sha256()
    doc = {"workload": workload, "version": version,
           "params": {k: _jsonable(v) for k, v in sorted(params.items())}}
    h.update(json.dumps(doc, sort_keys=True, default=str).encode())
    return h.hexdigest()[:32]


def job_fingerprint(workload: str, version: str,
                    params: Mapping[str, Any], X: np.ndarray,
                    y: Optional[np.ndarray] = None) -> str:
    """The combined identity a checkpoint is bound to."""
    return (spec_fingerprint(workload, version, params)
            + "-" + dataset_fingerprint(X, y))
