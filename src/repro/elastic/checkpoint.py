"""Job checkpoints on disk: snapshot dicts through the atomic format.

One job <-> one checkpoint directory (``<root>/<job-key>/``) holding
versioned ``step_<iters>/`` entries written by
:func:`repro.train.checkpoint.save` — the same tmp-dir+rename atomic
publish and ``keep_last`` pruning the elastic-rescale trainer uses, so
a crash mid-save never shadows a good checkpoint (DESIGN.md §11.1).

The snapshot's ``arrays`` section is the saved pytree; its ``meta``
section plus the scheduler-level envelope (workload, version, params,
fingerprint, accounting counters) ride in the manifest's
``extra_meta``.  :func:`load_snapshot` rebuilds the exact
``{"arrays", "meta"}`` dict a trainer's ``fit_steps(state=...)``
consumes, and surfaces the envelope for validation.
"""
from __future__ import annotations

import os
import re
from typing import Optional, Tuple

from ..obs.trace import TRACER
from ..train import checkpoint as ckpt
from .state import SCHEMA_VERSION

#: manifest keys that belong to the envelope / base format, not to the
#: trainer's snapshot meta.
_ENVELOPE_KEYS = ("elastic_schema", "workload", "version", "params",
                  "fingerprint", "system_kind", "iters", "steps",
                  "accounting")
_BASE_KEYS = ("exotic_dtypes", "step", "time", "n_arrays",
              "total_bytes", "keys_checksum")


def job_dir(root: str, key: str) -> str:
    """Filesystem-safe per-job checkpoint directory under ``root``."""
    safe = re.sub(r"[^A-Za-z0-9._-]+", "_", key)
    return os.path.join(root, safe)


def save_snapshot(directory: str, snapshot: dict, *, envelope: dict,
                  keep_last: int = 2) -> str:
    """Write one job snapshot atomically; returns the checkpoint path.

    ``envelope`` carries the scheduler-level identity/accounting
    (workload, version, params, fingerprint, system_kind, iters,
    steps); the trainer's ``meta`` section is nested under ``snap_meta``
    so trainer keys can never collide with envelope or base-format
    keys.
    """
    iters = int(envelope.get("iters", 0))
    extra = {"elastic_schema": SCHEMA_VERSION,
             "snap_meta": dict(snapshot.get("meta", {})),
             **envelope}
    if not TRACER.enabled:
        return ckpt.save(directory, iters,
                         dict(snapshot.get("arrays", {})),
                         keep_last=keep_last, extra_meta=extra)
    with TRACER.span("ckpt.save", "sched", "elastic", iters=iters):
        return ckpt.save(directory, iters,
                         dict(snapshot.get("arrays", {})),
                         keep_last=keep_last, extra_meta=extra)


def load_snapshot(directory: str,
                  step: Optional[int] = None) -> Tuple[dict, dict]:
    """``(snapshot, envelope)`` from the latest (or given) checkpoint.

    Raises FileNotFoundError when the directory holds no checkpoint.
    """
    if step is None:
        step = ckpt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint under {directory!r}")
    arrays, manifest = ckpt.restore_raw(directory, step)
    schema = manifest.get("elastic_schema")
    if schema != SCHEMA_VERSION:
        raise ValueError(
            f"checkpoint {directory!r} step {step} has elastic schema "
            f"{schema!r}; this runtime reads {SCHEMA_VERSION}")
    snapshot = {"arrays": arrays,
                "meta": dict(manifest.get("snap_meta", {}))}
    envelope = {k: manifest[k] for k in _ENVELOPE_KEYS if k in manifest}
    return snapshot, envelope


def has_checkpoint(directory: str) -> bool:
    return ckpt.latest_step(directory) is not None
