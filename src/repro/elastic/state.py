"""Serializable trainer state: the chunk-boundary snapshot schema.

A *snapshot* is what a resumable trainer's ``fit_steps`` generator
materializes at a chunk boundary (``ChunkTick.snapshot()`` —
DESIGN.md §11.1): a two-part dict

    {"arrays": {name: np.ndarray}, "meta": {json-able scalars}}

``arrays`` holds the StepProgram carry (weights/bias/scale for GD,
centroids + done-latch for K-Means) plus the packed rng stream;
``meta`` holds iteration counters, history, convergence flags — every
value JSON-serializable, so the whole snapshot round-trips through
``train/checkpoint.py``'s npz + manifest format unchanged.

This module owns the pieces the trainers and the scheduler both need:

  * full-fidelity numpy rng serialization (:func:`pack_rng` /
    :func:`unpack_rng`): the MT19937 key vector travels in ``arrays``,
    the stream position in ``meta`` — resuming restores the *exact*
    stream, so a resumed minibatch SGD or K-Means restart draws the
    same samples an uninterrupted fit would (bit-identity, not
    replay-by-count);
  * the cross-System migration compatibility matrix
    (:func:`migration_ok`): which execution targets a checkpoint taken
    on one System kind may resume on (DESIGN.md §11.3).

No imports from repro.core/api/sched — the trainers import *this*
module, never the reverse.
"""
from __future__ import annotations

from typing import Mapping, Optional

import numpy as np

#: snapshot schema version; bumped on incompatible layout changes and
#: validated on restore.
SCHEMA_VERSION = 1

_RNG_KEY = "rng_mt_keys"          # uint32[624] in arrays
_RNG_META = ("rng_pos", "rng_has_gauss", "rng_cached_gaussian")


def pack_rng(rng: np.random.RandomState) -> tuple[dict, dict]:
    """``(arrays, meta)`` fragments capturing the full MT19937 state.

    Merged into a snapshot's two sections; :func:`unpack_rng` inverts.
    Serializing the generator state itself (not a draw count) is what
    makes resume exact for *any* consumption pattern — per-iteration
    minibatch offsets, per-chunk pre-draws, per-restart init choices.
    """
    kind, keys, pos, has_gauss, cached = rng.get_state()
    assert kind == "MT19937", kind
    return ({_RNG_KEY: np.asarray(keys, np.uint32)},
            {"rng_pos": int(pos), "rng_has_gauss": int(has_gauss),
             "rng_cached_gaussian": float(cached)})


def unpack_rng(arrays: Mapping, meta: Mapping
               ) -> Optional[np.random.RandomState]:
    """Rebuild the RandomState a snapshot packed; None if it holds no
    rng (full-batch GD never draws, so its snapshots may omit it)."""
    keys = arrays.get(_RNG_KEY)
    if keys is None:
        return None
    rng = np.random.RandomState()
    rng.set_state(("MT19937", np.asarray(keys, np.uint32),
                   int(meta["rng_pos"]), int(meta["rng_has_gauss"]),
                   float(meta["rng_cached_gaussian"])))
    return rng


# ---------------------------------------------------------------------------
# Migration compatibility (DESIGN.md §11.3).
# ---------------------------------------------------------------------------

#: fp32 versions per workload: float carries migrate across System
#: kinds (tolerance-tested — reduction order and transcendental flavor
#: differ between PIM and a processor-centric target); every other
#: version is fixed-point and resumes bit-exactly ONLY on a
#: numerically-like target.
_FLOAT_VERSIONS = ("fp32",)

#: System kinds whose execution is numerically identical: the modeled
#: GPU *is* HostSystem execution with a roofline price tag
#: (systems/gpu_model.py), so checkpoints move freely between them.
_LIKE_KINDS = {
    "host": {"host", "gpu-model"},
    "gpu-model": {"host", "gpu-model"},
    "pim": {"pim"},
}


def migration_ok(from_kind: str, to_kind: str, version: str) -> bool:
    """May a ``version`` checkpoint taken on ``from_kind`` resume on
    ``to_kind``?  Same-kind is always fine; float carries migrate
    anywhere (tolerance, not bit-identity); integer carries only
    between numerically-like kinds."""
    if from_kind == to_kind:
        return True
    if version in _FLOAT_VERSIONS:
        return True
    return to_kind in _LIKE_KINDS.get(from_kind, {from_kind})


def check_migration(from_kind: str, to_kind: str, version: str) -> None:
    if not migration_ok(from_kind, to_kind, version):
        raise ValueError(
            f"cannot resume a {version!r} checkpoint taken on "
            f"{from_kind!r} on a {to_kind!r} target: fixed-point "
            f"carries are only bit-valid on numerically-like systems "
            f"(DESIGN.md §11.3); fp32 jobs may migrate freely")


def snapshot_iters(state: Optional[Mapping]) -> int:
    """Trainer iterations a snapshot covers (0 for None — restart)."""
    if not state:
        return 0
    return int(state.get("meta", {}).get("iters", 0))
