"""Sharded, prefetching host->device data feed.

The PIM lesson applied to the input pipeline: training data *stays device-
resident*; only fresh batches cross the host boundary, staged one step
ahead (double buffering) so the feed overlaps compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class PrefetchLoader:
    """Wraps a host batch source; device_puts with the given shardings one
    batch ahead on a background thread."""

    def __init__(self, source: Callable[[], dict], shardings=None,
                 prefetch: int = 2):
        self.source = source
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.source()
            if self.shardings is not None:
                batch = jax.device_put(batch, self.shardings)
            else:
                batch = jax.tree_util.tree_map(jax.numpy.asarray, batch)
            try:
                self._q.put(batch, timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                # retry until consumer catches up
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=1.0)
                        break
                    except queue.Full:
                        pass

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
