"""Synthetic LM token pipeline.

``MarkovCorpus`` samples from a fixed random bigram chain, so a trained LM
can push loss well below uniform entropy — giving examples/train_lm.py a
real learning signal without external datasets (offline container).
"""
from __future__ import annotations

import numpy as np


class MarkovCorpus:
    """Order-1 Markov token stream with a skewed transition matrix."""

    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 8):
        self.vocab = vocab_size
        rng = np.random.RandomState(seed)
        # each token transitions to `branching` likely successors
        succ = rng.randint(0, vocab_size, size=(vocab_size, branching))
        probs = rng.dirichlet(np.ones(branching) * 0.5,
                              size=vocab_size)
        self.succ = succ
        self.probs = probs.astype(np.float64)
        self._rng = np.random.RandomState(seed + 1)

    def entropy_bound(self) -> float:
        """Per-token entropy of the chain (nats) — the loss floor."""
        h = -np.sum(self.probs * np.log(np.maximum(self.probs, 1e-12)),
                    axis=1)
        return float(h.mean())

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len + 1), np.int32)
        state = self._rng.randint(0, self.vocab, size=batch)
        out[:, 0] = state
        for t in range(1, seq_len + 1):
            u = self._rng.rand(batch, 1)
            cdf = np.cumsum(self.probs[state], axis=1)
            choice = (u < cdf).argmax(axis=1)
            state = self.succ[state, choice]
            out[:, t] = state
        return out

    def batch(self, batch: int, seq_len: int) -> dict:
        toks = self.sample(batch, seq_len)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class UniformTokens:
    """i.i.d. uniform tokens (for pure-throughput benchmarks)."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self._rng = np.random.RandomState(seed)

    def batch(self, batch: int, seq_len: int) -> dict:
        toks = self._rng.randint(0, self.vocab,
                                 size=(batch, seq_len + 1)).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
