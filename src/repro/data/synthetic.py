"""Synthetic dataset generators (paper §4, Table 3).

The paper evaluates training quality on synthetic datasets with uniformly
distributed random samples (values with 4 decimal digits for LIN/LOG), and
uses synthetic data sized per-core for the weak/strong scaling experiments.
scikit-learn is not available in this container, so the generators below
reimplement the relevant subset (make_classification-style informative/
redundant/random attributes for DTR; isotropic blobs for KME).
"""
from __future__ import annotations

import numpy as np


def round_decimals(x: np.ndarray, decimals: int) -> np.ndarray:
    """Paper §4.1: samples have a fixed number of decimal digits."""
    return np.round(x, decimals).astype(np.float32)


def make_linear_dataset(n_samples: int = 8192, n_features: int = 16,
                        decimals: int = 4, seed: int = 0,
                        task: str = "classification",
                        noise: float = 0.0):
    """Uniform random samples + ground-truth linear model (LIN/LOG quality).

    ``task="classification"`` binarizes the linear response at its median —
    the paper's "training error rate" for LIN/LOG counts thresholded
    prediction errors on the training set (their real datasets, SUSY/Skin,
    are binary classification).
    Returns (X float32 [n, f], y float32 [n], w_true float32 [f+1]).
    """
    rng = np.random.RandomState(seed)
    X = rng.uniform(0.0, 1.0, size=(n_samples, n_features))
    X = round_decimals(X, decimals)
    w = rng.uniform(-1.0, 1.0, size=n_features).astype(np.float32)
    b = np.float32(rng.uniform(-0.5, 0.5))
    resp = X @ w + b
    if noise:
        resp = resp + rng.normal(0.0, noise, size=n_samples)
    if task == "classification":
        y = (resp > np.median(resp)).astype(np.float32)
    else:
        y = resp.astype(np.float32)
    return X.astype(np.float32), y, np.concatenate([w, [b]]).astype(np.float32)


def make_classification(n_samples: int = 600_000, n_features: int = 16,
                        n_informative: int = 4, n_redundant: int = 4,
                        n_classes: int = 2, class_sep: float = 1.0,
                        seed: int = 0):
    """DTR quality dataset (paper §4.1): 4 informative + 4 redundant
    (random linear combination of the informative) + 8 random attributes,
    float32, *not* quantized.  Follows the make_classification recipe:
    class clusters at hypercube vertices in informative subspace."""
    rng = np.random.RandomState(seed)
    n_random = n_features - n_informative - n_redundant
    assert n_random >= 0
    # class centroids: distinct +-class_sep hypercube corners
    centroids = np.zeros((n_classes, n_informative))
    for c in range(n_classes):
        bits = [(c >> i) & 1 for i in range(n_informative)]
        centroids[c] = (2.0 * np.array(bits) - 1.0) * class_sep
    y = rng.randint(0, n_classes, size=n_samples)
    X_inf = centroids[y] + rng.normal(0, 1.0, size=(n_samples, n_informative))
    A = rng.normal(0, 1.0, size=(n_informative, n_redundant))
    X_red = X_inf @ A
    X_rand = rng.normal(0, 1.0, size=(n_samples, n_random))
    X = np.concatenate([X_inf, X_red, X_rand], axis=1)
    perm = rng.permutation(n_features)
    return X[:, perm].astype(np.float32), y.astype(np.int32)


def make_blobs(n_samples: int = 100_000, n_features: int = 16,
               centers: int = 16, cluster_std: float = 1.0,
               center_box: tuple = (-10.0, 10.0), seed: int = 0):
    """KME quality dataset (paper §4.1): 16 isotropic clusters, float32."""
    rng = np.random.RandomState(seed)
    C = rng.uniform(center_box[0], center_box[1], size=(centers, n_features))
    y = rng.randint(0, centers, size=n_samples)
    X = C[y] + rng.normal(0, cluster_std, size=(n_samples, n_features))
    return X.astype(np.float32), y.astype(np.int32), C.astype(np.float32)


def make_recsys(n_samples: int = 16384, n_users: int = 512,
                n_items: int = 256, dim: int = 8, zipf_a: float = 1.2,
                noise: float = 0.02, seed: int = 0):
    """EMB quality dataset (DESIGN.md §15): (user, item, rating) triples.

    Ids draw from a truncated Zipf-like (Pareto) distribution — the
    power-law popularity skew real recsys traffic has, and the regime
    where deferred-update dedup actually saves flush traffic (hot rows
    are touched many times per window but ship once).  Ratings come
    from a ground-truth low-rank model so a dot-product embedding can
    drive the loss down.  Returns (pairs int32 [n, 2], y float32 [n]).
    """
    rng = np.random.RandomState(seed)
    U = (rng.randn(n_users, dim) * (0.5 / np.sqrt(dim))).astype(np.float32)
    I = (rng.randn(n_items, dim) * (0.5 / np.sqrt(dim))).astype(np.float32)
    u = np.minimum(rng.pareto(zipf_a, n_samples).astype(np.int64), n_users - 1)
    i = np.minimum(rng.pareto(zipf_a, n_samples).astype(np.int64), n_items - 1)
    y = np.sum(U[u] * I[i], axis=1)
    if noise:
        y = y + rng.normal(0.0, noise, size=n_samples)
    pairs = np.stack([u, i], axis=1).astype(np.int32)
    return pairs, y.astype(np.float32)


def make_scaling_dataset(workload: str, n_cores: int, per_core_samples: int,
                         n_features: int = 16, seed: int = 0):
    """Weak/strong-scaling inputs (paper Table 3): synthetic, sized per core."""
    n = n_cores * per_core_samples
    if workload in ("lin", "log"):
        X, y, _ = make_linear_dataset(n, n_features, seed=seed)
        return X, y
    if workload == "dtr":
        return make_classification(n, n_features, seed=seed)
    if workload == "kme":
        X, y, _ = make_blobs(n, n_features, seed=seed)
        return X, y
    if workload == "emb":
        return make_recsys(n, seed=seed)
    raise ValueError(workload)
