"""Lookup-table based transcendental functions (paper §3.2, Fig. 4).

The paper replaces Taylor-series sigmoid with a LUT of pre-computed sigmoid
values: boundary B=20, 10 fractional bits -> 20*1024 entries of 16 bits
(40 KB), exploiting sigmoid's symmetry sigmoid(-x) = 1 - sigmoid(x).  The
LUT fits in the DPU's 64 KB WRAM scratchpad; an MRAM-resident variant is
only ~3% slower because each query is a single access.

TPU adaptation: WRAM -> VMEM.  kernels/lut_activation pins the table in
VMEM inside a Pallas kernel; the "MRAM" variant is an HBM-resident XLA
gather.  This module is the backend-agnostic functional core used by both
and by the faithful LOG-*-LUT reproductions.

Also provided: fixed-point Taylor-series sigmoid (the paper's non-LUT
baseline, LOG-INT32) and a generic ``ActivationLut`` used by the LM stack
(models/quantized.py) to run SiLU/GELU through the same technique.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .fixed_point import _shift_round, from_fixed, to_fixed


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SigmoidLut:
    """Paper-faithful sigmoid LUT (Fig. 4).

    ``table[i] = round(sigmoid(i / 2**frac_bits) * 2**value_frac)`` for
    i in [0, boundary << frac_bits).  Stored int16 (value_frac=15 keeps
    sigmoid in [0, 32767]).
    """

    table: jnp.ndarray  # int16 [boundary << frac_bits]
    frac_bits: int
    boundary: int
    value_frac: int

    def tree_flatten(self):
        return (self.table,), (self.frac_bits, self.boundary, self.value_frac)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (table,) = children
        return cls(table, *aux)

    @property
    def nbytes(self) -> int:
        return int(self.table.size) * 2


def build_sigmoid_lut(boundary: int = 20, frac_bits: int = 10,
                      value_frac: int = 15) -> SigmoidLut:
    n = boundary << frac_bits
    xs = np.arange(n, dtype=np.float64) / float(1 << frac_bits)
    vals = 1.0 / (1.0 + np.exp(-xs))
    table = np.clip(np.round(vals * (1 << value_frac)), 0,
                    2 ** 15 - 1).astype(np.int16)
    return SigmoidLut(jnp.asarray(table), frac_bits, boundary, value_frac)


def lut_sigmoid_fixed(x_q: jnp.ndarray, lut: SigmoidLut) -> jnp.ndarray:
    """Sigmoid of Q(lut.frac_bits) fixed-point input -> Q(lut.value_frac).

    Mirrors the DPU kernel: take |x|, clamp at the boundary (sigmoid
    saturates), one table read, reflect for negative inputs.
    """
    xq = x_q.astype(jnp.int32)
    neg = xq < 0
    idx = jnp.minimum(jnp.abs(xq), lut.table.size - 1)
    v = lut.table[idx].astype(jnp.int32)
    one = jnp.int32(1 << lut.value_frac)
    return jnp.where(neg, one - v, v)


def lut_sigmoid_float(x: jnp.ndarray, lut: SigmoidLut) -> jnp.ndarray:
    """Float-in/float-out wrapper (quantize index, LUT, dequantize)."""
    x_q = to_fixed(x, lut.frac_bits)
    return from_fixed(lut_sigmoid_fixed(x_q, lut), lut.value_frac)


# ---------------------------------------------------------------------------
# Taylor-series sigmoid — the paper's LOG-INT32 / LOG-FP32 baseline on DPUs.
# ---------------------------------------------------------------------------

def taylor_exp_fixed(x_q: jnp.ndarray, frac_bits: int, terms: int = 8,
                     range_shift: int = 3) -> jnp.ndarray:
    """exp(-|x|) for Q(frac_bits) input, fixed-point Taylor with range
    reduction: exp(-x) = exp(-x / 2**m) ** (2**m), Taylor on the reduced
    argument (|t| < 1 keeps the series convergent in fixed point).
    Returns Q(frac_bits).  This is deliberately the *slow, iterative*
    method the paper measures 53x LUT speedup against (§5.2.2).
    """
    one = jnp.int32(1 << frac_bits)
    a = jnp.abs(x_q.astype(jnp.int32))
    # clamp: exp(-20) is below Q10 resolution anyway (matches LUT boundary)
    a = jnp.minimum(a, 20 << frac_bits)
    t = a >> range_shift  # reduced argument, Q(frac_bits)
    # Horner evaluation of sum_k (-t)^k / k!
    acc = jnp.zeros_like(t) + one // math.factorial(terms - 1)
    for k in range(terms - 2, -1, -1):
        acc = one // math.factorial(k) - _shift_round(t * acc, frac_bits)
    acc = jnp.maximum(acc, 0)
    for _ in range(range_shift):  # square back up
        acc = _shift_round(acc * acc, frac_bits)
    return acc


def taylor_sigmoid_fixed(x_q: jnp.ndarray, frac_bits: int,
                         terms: int = 8) -> jnp.ndarray:
    """sigmoid(x) = 1 / (1 + exp(-x)) in Q(frac_bits) via Taylor exp and
    integer division (both emulated-and-slow on the DPU, per the paper)."""
    one = jnp.int32(1 << frac_bits)
    e = taylor_exp_fixed(x_q, frac_bits, terms=terms)  # exp(-|x|), Q(f)
    # sigmoid(|x|) = 1/(1+exp(-|x|)); integer divide (emulated on DPU).
    # numerator 2**(2f) fits int32 for f <= 15 (we use f=10).
    pos = (jnp.int32(1 << (2 * frac_bits)) // jnp.maximum(one + e, 1))
    return jnp.where(x_q < 0, one - pos, pos)


# ---------------------------------------------------------------------------
# Generic activation LUT for the LM stack (beyond-paper application).
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ActivationLut:
    """Uniform-grid LUT for an arbitrary activation over [x_min, x_max].

    Used by models/quantized.py to run SiLU/GELU the way the paper runs
    sigmoid (Recommendation #5: convert computation to memory accesses).
    Values stored float32 (TPU VMEM is big enough; the DPU constraint that
    forced int16 storage does not bind here — recorded in DESIGN.md §2).
    """

    table: jnp.ndarray  # float32 [n_entries]
    x_min: float
    x_max: float

    def tree_flatten(self):
        return (self.table,), (self.x_min, self.x_max)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (table,) = children
        return cls(table, *aux)

    @classmethod
    def from_fn(cls, fn: Callable, x_min: float = -8.0, x_max: float = 8.0,
                n_entries: int = 4096) -> "ActivationLut":
        xs = np.linspace(x_min, x_max, n_entries, dtype=np.float64)
        # keep the table as a host numpy array: ActivationLuts are cached
        # at module level and reused across jit traces — a jnp array
        # materialized inside one trace would leak its tracer into the next
        table = np.asarray(fn(xs), dtype=np.float32)
        return cls(table, float(x_min), float(x_max))

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        table = jnp.asarray(self.table)  # per-trace constant
        n = table.shape[0]
        t = (x.astype(jnp.float32) - self.x_min) / (self.x_max - self.x_min)
        idx = jnp.clip(jnp.round(t * (n - 1)), 0, n - 1).astype(jnp.int32)
        return table[idx].astype(x.dtype)


def silu_lut(n_entries: int = 4096) -> ActivationLut:
    return ActivationLut.from_fn(lambda x: x / (1.0 + np.exp(-x)),
                                 x_min=-12.0, x_max=12.0, n_entries=n_entries)


def gelu_lut(n_entries: int = 4096) -> ActivationLut:
    # tanh-form GELU (no scipy dependency in this offline container)
    c = np.sqrt(2.0 / np.pi)
    return ActivationLut.from_fn(
        lambda x: 0.5 * x * (1 + np.tanh(c * (x + 0.044715 * x ** 3))),
        x_min=-12.0, x_max=12.0, n_entries=n_entries)
