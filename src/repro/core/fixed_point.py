"""Fixed-point (Q-format) arithmetic, the numeric substrate of the paper.

The UPMEM DPUs evaluated in the paper have no FPU: the paper's LIN-INT32 /
LOG-INT32 versions represent real values as 32-bit fixed point Q(m.f)
integers (value = int / 2**f).  The hybrid-precision versions (LIN-HYB /
LOG-HYB-LUT) use 8-bit inputs x 16-bit weights with 16/32-bit accumulation.

TPU note: JAX defaults to 32-bit integers and TPUs have no fast int64, so —
unlike the UPMEM code, which leans on 64-bit accumulators — every helper
here is written so intermediate products *provably* fit in int32:
multiplications shift right by ``frac_bits`` immediately after each product
(the paper's DPU code does the same for its 32-bit dot products).  Where the
paper uses int64 accumulators (K-Means per-cluster sums), core/kmeans.py
instead narrows the quantization range so exact int32 accumulation holds;
see the module docstring there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mul_round_f32(a, b):
    """Correctly-rounded float32 product with *pinned* two-rounding
    semantics for the consumer: ``x - mul_round_f32(s, g)`` computes
    round(x - round(s*g)) in EVERY execution context.

    A plain f32 ``s * g`` adjacent to a subtract gets FMA-contracted by
    XLA CPU inside jitted computations (observed: jit == single-rounding
    fma while eager/numpy == two roundings, diverging by 1 ULP per step
    and shape-dependently — neither ``optimization_barrier`` nor bitcast
    round-trips block the contraction).  The fused step engine
    (core/pim.py StepProgram) needs the compiled scan to be bit-identical
    to the eager per-step loop, so the product is computed exactly in
    float64 (24-bit mantissas -> the f64 product is exact) and rounded
    once by the down-convert; a convert cannot be contracted into the
    f32 subtract, so the two roundings survive any fusion decision.

    CAVEAT — inside a jit trace BOTH operands must be *traced* values
    (arguments or carry elements), not closed-over constants: every
    concrete float64 value — eagerly up-converted constants, weak python
    scalars, even literals — is canonicalized back to f32 when the jaxpr
    is lowered (the x64 context is long exited by then), leaving a
    mixed-dtype multiply that fails MLIR verification.  The fused
    trainers therefore thread the update scale through the scan carry.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    with jax.experimental.enable_x64():
        p = a.astype(jnp.float64) * b.astype(jnp.float64)
        return p.astype(jnp.float32)


def to_fixed(x, frac_bits: int, dtype=jnp.int32):
    """float -> Q(frac_bits) fixed point, saturating at the dtype range."""
    info = jnp.iinfo(dtype)
    scaled = jnp.round(jnp.asarray(x, jnp.float32) * np.float32(1 << frac_bits))
    return jnp.clip(scaled, info.min, info.max).astype(dtype)


def from_fixed(q, frac_bits: int):
    return q.astype(jnp.float32) / np.float32(1 << frac_bits)


def saturate(x, dtype):
    info = jnp.iinfo(dtype)
    return jnp.clip(x, info.min, info.max).astype(dtype)


def fx_mul(a, b, frac_bits: int, out_dtype=jnp.int32):
    """Q(f) * Q(f) -> Q(f) with the post-product shift the DPU code uses.

    Inputs are widened to int32 for the product; callers must keep operand
    magnitudes below 2**(31 - frac_bits) (asserted in tests, guaranteed by
    the dataset quantizers which produce |x| < 2**frac_bits ranges).
    """
    prod = a.astype(jnp.int32) * b.astype(jnp.int32)
    return _shift_round(prod, frac_bits).astype(out_dtype)


def _shift_round(x, shift: int):
    """Arithmetic right-shift with round-to-nearest (ties toward +inf).

    Plain ``>>`` floors, which introduces a systematic negative bias that
    visibly degrades gradient-descent convergence; the DPU library rounds.
    """
    if shift == 0:
        return x
    return (x + (1 << (shift - 1))) >> shift


def fx_dot(x_q, w_q, frac_bits: int):
    """Fixed-point dot product along the last axis: Q(f) · Q(f) -> Q(f).

    Each product is shifted back to Q(f) *before* accumulation (as in the
    paper's 32-bit DPU kernels), so the int32 accumulator holds
    sum_i round(x_i * w_i / 2**f), exactly reproducible across backends.
    """
    prod = x_q.astype(jnp.int32) * w_q.astype(jnp.int32)
    return jnp.sum(_shift_round(prod, frac_bits), axis=-1)


def fx_dot_hybrid(x_q8, w_q16, x_frac: int, w_frac: int, out_frac: int,
                  acc_dtype=jnp.int16):
    """Hybrid-precision dot product (paper's LIN-HYB / LOG-HYB-LUT).

    8-bit inputs x 16-bit weights; products are rescaled to Q(out_frac) and
    accumulated in *16-bit* (``acc_dtype``) with saturation — the paper
    states "the dot product result is 16-bit width", which is exactly the
    precision loss that raises HYB training error (Fig. 6/7, §5.1).
    Returns Q(out_frac) in int32 (the widened final value).
    """
    prod = x_q8.astype(jnp.int32) * w_q16.astype(jnp.int32)  # Q(x_frac+w_frac)
    shift = x_frac + w_frac - out_frac
    prod = _shift_round(prod, shift) if shift > 0 else prod << (-shift)
    # saturating 16-bit accumulation, sequentially over the feature axis
    info = jnp.iinfo(acc_dtype)
    acc = jnp.zeros(prod.shape[:-1], jnp.int32)
    # feature counts are small (paper uses 16); unrolled cumulative clip
    # models the DPU's 16-bit register accumulation faithfully.
    n = prod.shape[-1]
    for i in range(n):
        acc = jnp.clip(acc + prod[..., i], info.min, info.max)
    return acc


def fx_recip(d_q, frac_bits: int, iters: int = 3):
    """Fixed-point reciprocal via Newton-Raphson (DPUs emulate division).

    Input Q(f) > 0; returns Q(f) approximation of 1/d.  Seed from a
    float-free shift-based estimate: 1/d ~= 2**(2f) / d via integer divide
    (DPU runtime also exposes integer division, just slowly).
    """
    one = jnp.int32(1 << frac_bits)
    d = d_q.astype(jnp.int32)
    x = (jnp.int32(1) << (2 * frac_bits)) // jnp.maximum(d, 1)
    for _ in range(iters):
        # x <- x * (2 - d*x)   in Q(f)
        dx = _shift_round(d * x, frac_bits)
        x = _shift_round(x * (2 * one - dx), frac_bits)
    return x
