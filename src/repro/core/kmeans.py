"""K-Means clustering on the PIM system (paper §3.4, Lloyd's method).

PIM flow exactly as §3.4: the training set is partitioned over PIM cores and
quantized to 16-bit integers; per iteration every core (1) finds each
point's nearest centroid with integer distance arithmetic, (2) accumulates
per-cluster per-coordinate sums + counts; the host (3) reduces partials,
recomputes centroids in float, checks the relative Frobenius norm for
convergence, and re-broadcasts quantized centroids.  The whole algorithm is
restarted ``n_init`` times; the host keeps the clustering with the lowest
inertia (within-cluster sum of squares), which the PIM cores compute after
convergence.

Numerics adaptation (DESIGN.md §2): UPMEM accumulates distances/sums in
int64; TPUs have no fast int64, so we quantize coordinates to +-2047
(12-bit range stored in int16) which makes the int32 distance and
coordinate-sum accumulations *exact* for up to 2^9 features and ~2^19
points per cluster per core — far beyond the evaluated sizes.  The paper's
own quantization (+-32767) exists to avoid the identical overflow problem
on the DPU; quality parity is preserved (ARI ~ 0.999 vs float CPU, §5.1.4).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..elastic.state import pack_rng, unpack_rng
from ..kernels import dispatch
from ..systems import (ChunkPipeline, ChunkTick, System, chunk_schedule,
                       run_steps)
from .metrics import frobenius_shift

# 12-bit symmetric range stored in int16 (see docstring).  The quantizing
# + sharding path, PimDataset.kmeans_view (repro/api/dataset.py), imports
# this constant — single source of truth.
QUANT_RANGE = 2047

#: "int16" is the paper's PIM version (quantized Lloyd's); "fp32" is the
#: processor-centric float path — the baseline the paper compares
#: against (sklearn, §5.1.4), now runnable on ANY System through the
#: same trainer (DESIGN.md §10.3).
VERSIONS = ("int16", "fp32")


@dataclasses.dataclass
class KMeansConfig:
    k: int = 16
    max_iters: int = 300
    tol: float = 1e-4           # relative Frobenius norm (paper §5.1.4)
    n_init: int = 1
    seed: int = 0
    #: data/arithmetic precision: "int16" (paper's quantized PIM
    #: version) or "fp32" (un-quantized float Lloyd's — the processor-
    #: centric baseline; no quantization round-trip)
    version: str = "int16"
    #: kernel backend for the assignment hot path (None = auto-select;
    #: see repro.kernels.dispatch) — all backends are numerically
    #: identical (integer ops, asserted by the parity tests)
    kernel_backend: Optional[str] = None
    #: step fusion (DESIGN.md §9): compile this many Lloyd's iterations
    #: into ONE lax.scan launch per chunk.  Convergence is checked on
    #: device (a ``done`` flag in the scan carry freezes the centroids),
    #: so a chunk may cover fewer *effective* iterations than its length;
    #: the host still stops draining chunks at the first converged one.
    #: The fused update recomputes centroids in float32 on device where
    #: the per-step host loop uses float64 — inertia/centroids agree to
    #: float tolerance, not bit-exactly (the assignment kernel itself is
    #: integer and exact).  1 = the paper's host-orchestrated loop.
    fuse_steps: int = 1
    #: chunk pipelining (DESIGN.md §14.1): fused chunks in flight before
    #: the host drains a boundary (2 = double-buffered, 1 = serial
    #: cadence).  The done-latch makes overshot in-flight chunks frozen
    #: no-ops, so pipelined convergence is exact — a discarded chunk
    #: never changes the centroids.  Ignored unless ``fuse_steps > 1``.
    pipeline_depth: int = 2


@dataclasses.dataclass
class KMeansResult:
    centroids: np.ndarray       # float32 [k, F] (dequantized)
    inertia: float
    n_iters: int
    labels: Optional[np.ndarray] = None


def _assign_kernel_factory(k: int, backend=None, quantized: bool = True):
    """Assignment + accumulation.

    The int16 (PIM) version routes through the kernel-dispatch layer
    (op ``kmeans_assign``: Pallas on TPU, jnp oracle elsewhere); the
    fp32 (processor-centric baseline) version is an inline float
    distance + one-hot accumulation — no quantization, native float
    matmul, the paper's sklearn-style hot loop.

    Neither path has a validity-mask concept, so padding is corrected
    here: shard padding rows are all-zero vectors (see
    ``PimSystem.shard_rows``), which contribute nothing to ``sums`` and
    exactly one spurious count at their assigned label — subtracted via
    a masked one-hot.
    """
    be = dispatch.resolve_backend(backend)

    def _kernel(Xq, valid, Cq):
        if quantized:
            labels, sums, counts = dispatch.launch(
                "kmeans_assign", Xq, Cq, backend=be)
        else:
            x = Xq
            c = Cq
            # same tie-breaking expression as the quantized op: the
            # per-row ||x||^2 constant cannot change an argmin
            dist = jnp.sum(c * c, axis=1)[None, :] - 2.0 * (x @ c.T)
            labels = jnp.argmin(dist, axis=1).astype(jnp.int32)
            oh = (labels[:, None] ==
                  jnp.arange(k, dtype=jnp.int32)[None, :])
            sums = oh.astype(jnp.float32).T @ x
            counts = jnp.sum(oh.astype(jnp.int32), axis=0)
        pad_oh = ((labels[:, None] ==
                   jnp.arange(k, dtype=jnp.int32)[None, :])
                  & ~valid[:, None]).astype(jnp.int32)
        return {"sums": sums, "counts": counts - jnp.sum(pad_oh, axis=0)}
    return _kernel


def _inertia_kernel_factory(k: int, quantized: bool = True):
    def _kernel(Xq, valid, Cq):
        acc = jnp.int32 if quantized else jnp.float32
        x = Xq.astype(acc)
        c = Cq.astype(acc)
        cross = x @ c.T
        xnorm = jnp.sum(x * x, axis=1)
        cnorm = jnp.sum(c * c, axis=1)
        dist = xnorm[:, None] - 2 * cross + cnorm[None, :]
        best = jnp.min(dist, axis=1)
        # int32 sums can overflow over a whole shard: accumulate in f32 on
        # the way out (the host reduces in f64)
        return {"inertia": jnp.sum(
            jnp.where(valid, best, 0).astype(jnp.float32))}
    return _kernel


def _labels_kernel_factory(k: int, quantized: bool = True):
    """Labels-only predict path: a plain argmin over the same distance
    expression the assignment kernel uses (identical tie-breaking),
    WITHOUT routing through the full assign+accumulate kernel — a
    Pallas kernel computes every declared output, so the dispatch op
    would materialize (K, F) sums nobody reads on the inference path."""
    def _kernel(Xq, valid, Cq):
        acc = jnp.int32 if quantized else jnp.float32
        x = Xq.astype(acc)
        c = Cq.astype(acc)
        dist = jnp.sum(c * c, axis=1)[None, :] - 2 * (x @ c.T)
        return jnp.argmin(dist, axis=1).astype(jnp.int32)
    return _kernel


def _make_lloyd_step_fns(cfg: KMeansConfig):
    """(prepare, update) for one fused Lloyd's iteration (DESIGN.md §9).

    Carry: ``(C float32 [k,F] in quantized units, done bool, n_it
    int32)``.  ``done`` latches once the relative Frobenius shift drops
    below ``cfg.tol`` and freezes the centroids, so a chunk that
    overshoots convergence is a no-op for the tail steps; ``n_it``
    counts only the steps taken while not yet converged — matching the
    host loop's iteration count exactly."""
    tol = np.float32(cfg.tol)
    quantized = cfg.version == "int16"

    def prepare(carry):
        C, _, _ = carry
        if quantized:
            return (jnp.round(C).astype(jnp.int16),)
        return (C,)

    def update(carry, reduced):
        C, done, n_it = carry
        sums = jnp.asarray(reduced["sums"], jnp.float32)
        counts = jnp.asarray(reduced["counts"], jnp.float32)
        newC = jnp.where(counts[:, None] > 0,
                         sums / jnp.maximum(counts[:, None], 1.0), C)
        shift = (jnp.linalg.norm(newC - C)
                 / jnp.maximum(jnp.linalg.norm(C), 1e-12))
        newC = jnp.where(done, C, newC)
        n_it = n_it + jnp.where(done, 0, 1).astype(jnp.int32)
        done = done | (shift < tol)
        return (newC, done, n_it), None
    return prepare, update


def fit_steps(dataset, cfg: Optional[KMeansConfig] = None,
              return_labels: bool = True, *,
              state: Optional[dict] = None):
    """Generator form of Lloyd's: one assign/update scheduling step per
    ``next()`` (across all ``n_init`` restarts), KMeansResult on
    StopIteration — the gang-stepping surface; :func:`fit` drains it.
    Each ``next()`` yields a :class:`~repro.systems.base.ChunkTick`:
    the number of Lloyd's iterations it covered (1, or a whole
    ``cfg.fuse_steps`` scan chunk — DESIGN.md §9) with a lazy snapshot
    of the restart state (centroids, done-latch, restart index, rng
    stream, best-so-far).  Pass a snapshot back as ``state`` to resume
    mid-restart bit-exactly: the rng restores to the same stream
    position, so later restarts draw the same init points an
    uninterrupted fit would (DESIGN.md §11.2).
    The end-of-restart inertia/labels passes don't get their own step;
    they run at the head of the ``next()`` that follows convergence."""
    cfg = cfg or KMeansConfig()
    assert cfg.version in VERSIONS, cfg.version
    quantized = cfg.version == "int16"
    pim = dataset.system
    n = dataset.n
    rng = np.random.RandomState(cfg.seed)
    view = dataset.kmeans_view(cfg.version)
    Xs, valid = view.shards, view.mask
    Xq_np, scale = view.host_q, view.scale

    def _cast_centroids(C):
        """Broadcast form of the carry: rounded int16 on the quantized
        path (the paper's re-quantized centroids), plain float32 on the
        processor-centric fp32 path."""
        if quantized:
            return jnp.asarray(np.round(C).astype(np.int16))
        return jnp.asarray(C, jnp.float32)

    be = dispatch.resolve_backend(cfg.kernel_backend)
    tag = dispatch.backend_tag(be)
    # the int16 names predate the fp32 version and tests/benchmarks
    # match them verbatim; fp32 kernels get their own namespace
    vtag = "" if quantized else "fp32/"
    assign_k = pim.named_kernel(
        f"kme.assign/{vtag}k{cfg.k}/{tag}",
        lambda: _assign_kernel_factory(cfg.k, be, quantized))
    inertia_k = pim.named_kernel(
        f"kme.inertia/{vtag}k{cfg.k}",
        lambda: _inertia_kernel_factory(cfg.k, quantized))
    labels_k = pim.named_kernel(
        f"kme.labels/{vtag}k{cfg.k}",
        lambda: _labels_kernel_factory(cfg.k, quantized))

    program = None
    if cfg.fuse_steps > 1:
        prepare, update = _make_lloyd_step_fns(cfg)
        program = pim.step_program(
            assign_k, prepare, update,
            name=f"kme.step/{vtag}k{cfg.k}/{tag}/tol{cfg.tol}/n{n}")

    best: Optional[KMeansResult] = None
    init0 = 0
    it_total = 0        # iterations yielded across all restarts
    resume: Optional[dict] = None
    if state is not None:
        arrays, meta = state["arrays"], state["meta"]
        init0 = int(meta["init"])
        it_total = int(meta["iters"])
        resume = {"C": np.asarray(arrays["C"], np.float32),
                  "done": bool(meta["done"]),
                  "n_it": int(meta["n_it"]),
                  "it_sched": int(meta["it_sched"])}
        if meta.get("has_best"):
            best = KMeansResult(
                centroids=np.asarray(arrays["best_centroids"],
                                     np.float32),
                inertia=float(meta["best_inertia"]),
                n_iters=int(meta["best_n_iters"]),
                labels=(np.asarray(arrays["best_labels"])
                        if "best_labels" in arrays else None))
        restored = unpack_rng(arrays, meta)
        if restored is not None:
            rng = restored

    init = init0
    C = None
    done = False
    n_it = 0
    it_sched = 0        # chunk-scheduled iterations (fused resume key)

    def _make_snapshot(C_v, done_v, n_it_v, it_total_v, it_sched_v,
                       ra, rm):
        """Snapshot closure bound to one chunk boundary's state.  Under
        pipelining the device carry has been dispatched past this
        boundary by drain time; ``best``/``init`` stay live — they only
        change between restarts, and every boundary of a restart drains
        (or is discarded) before the restart ends (DESIGN.md §14.1)."""
        def _snap():
            arrays = {"C": np.asarray(C_v, np.float32)}
            meta = {"iters": int(it_total_v), "init": int(init),
                    "done": bool(done_v), "n_it": int(n_it_v),
                    "it_sched": int(it_sched_v),
                    "has_best": best is not None}
            if best is not None:
                arrays["best_centroids"] = np.asarray(best.centroids,
                                                      np.float32)
                meta["best_inertia"] = float(best.inertia)
                meta["best_n_iters"] = int(best.n_iters)
                if best.labels is not None:
                    arrays["best_labels"] = np.asarray(best.labels)
            arrays.update(ra)
            meta.update(rm)
            return {"arrays": arrays, "meta": meta}
        return _snap

    def _snapshot():
        ra, rm = pack_rng(rng)
        return _make_snapshot(C, done, n_it, it_total, it_sched,
                              ra, rm)()

    for init in range(init0, cfg.n_init):
        if resume is not None:
            # re-enter the preempted restart: NO new init draw — the
            # rng stream was saved post-draw, so later restarts stay
            # aligned with an uninterrupted fit
            C, done = resume["C"], resume["done"]
            n_it, it_sched = resume["n_it"], resume["it_sched"]
            resume = None
        else:
            # host picks random points as initial centroids (paper:
            # random init)
            idx = rng.choice(n, size=cfg.k, replace=False)
            C = Xq_np[idx].astype(np.float32)           # quantized units
            done = False
            n_it = 0
            it_sched = 0
        if program is not None:
            # Double-buffered chunk pipeline (DESIGN.md §14.1): the
            # convergence flag of boundary N is read while chunk N+1
            # executes.  The done-latch freezes a converged carry, so
            # the overshot in-flight chunk is a frozen no-op — it is
            # discarded unread, and the converged boundary's carry is
            # the exact serial result.  Iteration counters advance at
            # drain time (from dispatch-side tags), so discarded chunks
            # never count.
            dcarry = (jnp.asarray(C), jnp.asarray(bool(done)),
                      jnp.asarray(n_it, jnp.int32))
            pipe = ChunkPipeline(program, max(1, int(cfg.pipeline_depth)))
            final = None        # carry of the last drained boundary

            def _drain(bnd):
                nonlocal it_sched, it_total
                it_sched, it_total, ra, rm = bnd.tag
                return ChunkTick(
                    bnd.k, _make_snapshot(bnd.carry[0], bnd.carry[1],
                                          bnd.carry[2], it_total,
                                          it_sched, ra, rm))

            disp_sched, disp_total = it_sched, it_total
            stop = bool(done)   # resumed post-convergence: dispatch nothing
            for k in chunk_schedule(cfg.max_iters, cfg.fuse_steps, 0,
                                    start=it_sched):
                if stop:
                    break
                disp_sched += k
                disp_total += k
                dcarry, drained = pipe.dispatch(
                    dcarry, (Xs, valid), k,
                    tag=(disp_sched, disp_total, *pack_rng(rng)))
                for bnd in drained:
                    final = bnd.carry
                    yield _drain(bnd)
                    if bool(bnd.carry[1]):  # converged at this boundary
                        stop = True
                        break
            if not stop:
                for bnd in pipe.flush():
                    final = bnd.carry
                    yield _drain(bnd)
                    if bool(bnd.carry[1]):
                        break
            if final is not None:
                C = np.asarray(final[0], np.float32)
                n_it = int(final[2])
        else:
            while not done and n_it < cfg.max_iters:
                Cq = pim.broadcast((_cast_centroids(C),))[0]
                part = pim.map_reduce(assign_k, (Xs, valid), (Cq,))
                sums = np.asarray(part["sums"], np.float64)
                counts = np.asarray(part["counts"], np.float64)
                newC = np.where(counts[:, None] > 0,
                                sums / np.maximum(counts[:, None], 1), C)
                shift = frobenius_shift(C, newC)
                C = newC.astype(np.float32)
                n_it += 1
                it_sched = n_it
                done = shift < cfg.tol
                it_total += 1
                yield ChunkTick(1, _snapshot)
        part = pim.map_reduce(
            inertia_k, (Xs, valid), (_cast_centroids(C),))
        # inertia needs + ||x||^2 which the kernel includes; convert units
        inertia = float(part["inertia"]) * float(scale) ** 2
        if best is None or inertia < best.inertia:
            best = KMeansResult(centroids=C * scale, inertia=inertia,
                                n_iters=n_it)
            if return_labels:
                lbl = pim.map_elementwise(
                    labels_k, (Xs, valid), (_cast_centroids(C),))
                best.labels = np.asarray(lbl).reshape(-1)[: n]
    return best


def fit(dataset, cfg: Optional[KMeansConfig] = None,
        return_labels: bool = True) -> KMeansResult:
    """Lloyd's over a bank-resident PimDataset.  The int16-quantized view
    is materialized once; all ``n_init`` restarts — and any later refit
    with different (k, seed, tol) — reuse the resident shards."""
    return run_steps(fit_steps(dataset, cfg, return_labels))


def train(X: np.ndarray, pim: System,
          cfg: Optional[KMeansConfig] = None,
          return_labels: bool = True) -> KMeansResult:
    """Deprecated shim: re-quantizes + re-partitions X on every call.
    Prefer ``fit(pim.put(X), cfg)`` (repro.api)."""
    warnings.warn("kmeans.train(X, pim, ...) is deprecated; use "
                  "kmeans.fit(pim.put(X), cfg)", DeprecationWarning,
                  stacklevel=2)
    from ..api.dataset import as_dataset
    return fit(as_dataset(X, None, pim), cfg, return_labels)

# The CPU comparison point (float Lloyd's — the paper uses sklearn) is
# no longer an ad-hoc numpy loop here: run version="fp32" on
# repro.systems.HostSystem, e.g. ``kmeans.fit(make_system("host").
# put(X), KMeansConfig(version="fp32"))`` — same trainer, no
# quantization round-trip.
