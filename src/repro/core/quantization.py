"""Symmetric quantization utilities (paper §3, §5.4).

The paper applies *symmetric quantization* to training datasets so that the
PIM cores can use natively-supported integer arithmetic (UPMEM DPUs have no
FPU; 8-bit multiply is native, 32-bit multiply is emulated).  On TPU the
analogous native fast path is the MXU int8 x int8 -> int32 matmul, so the
same dataset-quantization machinery feeds both the faithful reproduction
(core/linreg.py, core/logreg.py, core/kmeans.py) and the beyond-paper
quantized LM layers (models/quantized.py, kernels/quant_matmul).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

_INT_DTYPES = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}

#: storage width in bytes of every on-bank dtype used by the workloads.
#: This is THE dtype-width table: core/pim.py's DpuCostModel derives its
#: per-element MRAM byte counts from it instead of string-matching on
#: version names, so cost model and quantizer cannot drift.
STORAGE_BYTES = {"fp32": 4, "int32": 4, "int16": 2, "int8": 1}


def storage_bytes(dtype_name: str) -> int:
    """Bytes per element for a named storage dtype (see STORAGE_BYTES)."""
    try:
        return STORAGE_BYTES[dtype_name]
    except KeyError:
        raise ValueError(
            f"unknown storage dtype {dtype_name!r}; "
            f"known: {sorted(STORAGE_BYTES)}") from None


def int_dtype_for_bits(bits: int):
    """Smallest signed integer dtype that stores `bits`-bit values."""
    for b, dt in _INT_DTYPES.items():
        if bits <= b:
            return dt
    raise ValueError(f"unsupported bit width {bits}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantParams:
    """Symmetric quantization parameters: ``x ~= q * scale``.

    ``scale`` may be a scalar (per-tensor) or an array broadcastable against
    the quantized tensor (per-channel / per-column).
    """

    scale: jnp.ndarray
    bits: int
    axis: Optional[int] = None

    # -- pytree protocol (scale is a leaf; bits/axis are static) ------------
    def tree_flatten(self):
        return (self.scale,), (self.bits, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (scale,) = children
        bits, axis = aux
        return cls(scale=scale, bits=bits, axis=axis)

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def symmetric_quantize(
    x: jnp.ndarray,
    bits: int = 8,
    axis: Optional[int] = None,
    eps: float = 1e-12,
) -> tuple[jnp.ndarray, QuantParams]:
    """Quantize ``x`` symmetrically to signed ``bits``-bit integers.

    axis=None  -> one scale for the whole tensor (paper's dataset quantization)
    axis=k     -> per-slice scales along every axis *except* k is reduced
                  (i.e. one scale per index of axis k), used per-channel.
    """
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q.astype(int_dtype_for_bits(bits)), QuantParams(scale=scale, bits=bits, axis=axis)


def dequantize(q: jnp.ndarray, params: QuantParams) -> jnp.ndarray:
    return q.astype(jnp.float32) * params.scale


def quantize_with(x: jnp.ndarray, params: QuantParams) -> jnp.ndarray:
    """Quantize using pre-computed params (e.g. train-set params on eval data)."""
    qmax = params.qmax
    q = jnp.clip(jnp.round(x / params.scale), -qmax - 1, qmax)
    return q.astype(int_dtype_for_bits(params.bits))


def quantization_snr_db(x: Union[np.ndarray, jnp.ndarray], bits: int) -> float:
    """Signal-to-quantization-noise ratio in dB (diagnostic used in tests)."""
    x = jnp.asarray(x, jnp.float32)
    q, p = symmetric_quantize(x, bits=bits)
    err = x - dequantize(q, p)
    num = jnp.sum(x * x)
    den = jnp.maximum(jnp.sum(err * err), 1e-30)
    return float(10.0 * jnp.log10(num / den))
