"""Decision tree training on the PIM system (paper §3.3).

Extremely randomized trees [Geurts'06] for classification: at each step, one
uniform-random threshold per feature is drawn inside the leaf's [min, max]
and the best (feature, threshold) pair by Gini impurity makes the split.

Host/PIM split exactly as the paper describes:
  - the HOST owns the tree, the active frontier, and the splitting
    decisions; it issues three commands to the PIM cores:
      * min-max        (per leaf x feature, to draw candidate thresholds)
      * split-evaluate (partial per-class below-threshold counts -> Gini)
      * split-commit   (points move to their child leaf)
  - the PIM CORES own immutable shards of the training points plus a
    per-point ``leaf_id`` array.

Layout adaptation (paper Fig. 5): the DPU implementation physically reorders
feature values so each leaf's points are contiguous, turning split-evaluate
into streaming MRAM->WRAM DMA.  The JAX semantic model keeps a leaf_id
array and uses segment reductions, which is functionally identical; the
*physical* streaming layout is realized in the Pallas kernel
(kernels/gini_split) whose grid streams feature blocks HBM->VMEM, and its
benefit is captured by the DPU cost model.  Commit updates are O(n) gathers
(the JAX analogue of the paper's "partial reorder").
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch
from ..systems import System, run_steps


@dataclasses.dataclass
class TreeConfig:
    max_depth: int = 10
    n_classes: int = 2
    min_samples_split: int = 2
    seed: int = 0
    #: kernel backend for split-evaluate (None = auto-select; see
    #: repro.kernels.dispatch) — integer counts, so every backend is
    #: bit-identical (asserted by the parity tests)
    kernel_backend: Optional[str] = None


@dataclasses.dataclass
class Tree:
    """Array-encoded binary tree (host-side)."""

    feature: np.ndarray    # int32 [max_nodes], -1 = leaf
    threshold: np.ndarray  # float32 [max_nodes]
    left: np.ndarray       # int32 [max_nodes]
    right: np.ndarray      # int32 [max_nodes]
    leaf_class: np.ndarray  # int32 [max_nodes]
    depth: np.ndarray      # int32 [max_nodes]
    n_nodes: int

    def predict(self, X: np.ndarray) -> np.ndarray:
        """Vectorized host-side inference."""
        X = np.asarray(X, np.float32)
        node = np.zeros(X.shape[0], np.int32)
        for _ in range(int(self.depth.max()) + 1):
            f = self.feature[node]
            is_split = f >= 0
            if not is_split.any():
                break
            fx = X[np.arange(X.shape[0]), np.maximum(f, 0)]
            go_left = fx <= self.threshold[node]
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(is_split, nxt, node)
        return self.leaf_class[node]


# ---------------------------------------------------------------------------
# PIM-core kernels (pure functions of the core-resident shard).
# ---------------------------------------------------------------------------

def make_minmax_kernel(max_nodes: int):
    """Per-core per-leaf min/max of every feature (min-max command).

    Returns ("neg_min", "max") encoded so that the cross-core *sum* reduce
    of PimSystem cannot be used — min/max need max-reduce.  We encode via
    one-hot segment ops and let the host combine with np.minimum/np.maximum
    (ReduceVia.HOST semantics; on fabric this is a psum of masked +-inf).
    """
    BIG = np.float32(3.4e38)

    def _kernel(Xc, leaf_id, valid, _dummy):
        # segment min/max over leaves: (n_pc, F) -> (max_nodes, F)
        lid = jnp.where(valid, leaf_id, max_nodes - 1)
        mins = jax.ops.segment_min(
            jnp.where(valid[:, None], Xc, BIG), lid,
            num_segments=max_nodes)
        maxs = jax.ops.segment_max(
            jnp.where(valid[:, None], Xc, -BIG), lid,
            num_segments=max_nodes)
        return {"min": mins, "max": maxs}
    return _kernel


_BIG = np.float32(3.4e38)  # sentinel larger than any real feature value


def make_split_eval_kernel(max_nodes: int, n_classes: int, backend=None):
    """split-evaluate: per (leaf, feature, class) below-threshold counts +
    per (leaf, class) totals.  One random threshold per feature (ERT).

    Routed through the kernel-dispatch layer (op ``gini_split``: Pallas
    on TPU, jnp segment-sum oracle elsewhere).  The dispatch op has no
    validity-mask concept, so invalid rows are pre-routed to a spill
    slot — leaf ``max_nodes - 1``, class ``n_classes - 1`` — with
    their feature values forced above every finite threshold (zero
    below-counts), and their spurious total is subtracted afterwards
    so the spill slot stays usable as a real leaf (the in-line kernel
    this replaced masked totals to zero for invalid rows).
    """
    be = dispatch.resolve_backend(backend)

    def _kernel(Xc, yc, leaf_id, valid, thresholds):
        # thresholds: (max_nodes, F) candidate per leaf x feature
        x = jnp.where(valid[:, None], Xc, _BIG)       # below = 0 for pad
        y = jnp.where(valid, yc, n_classes - 1)
        leaf = jnp.where(valid, leaf_id, max_nodes - 1)
        below, total = dispatch.launch(
            "gini_split", x, y, leaf, thresholds, n_classes, backend=be)
        n_pad = jnp.sum((~valid).astype(jnp.int32))
        total = total.at[max_nodes - 1, n_classes - 1].add(-n_pad)
        return {"below": below, "total": total}
    return _kernel


def _commit_kernel(Xc, leaf_id, split_feature, split_thresh, left_id,
                   right_id):
    """split-commit: reassign each point to its child leaf (paper Fig. 5's
    reorder, realized as a leaf_id rewrite — see module docstring)."""
    f = split_feature[leaf_id]                        # (n_pc,)
    has_split = f >= 0
    fx = jnp.take_along_axis(
        Xc, jnp.maximum(f, 0)[:, None], axis=1)[:, 0]
    go_left = fx <= split_thresh[leaf_id]
    child = jnp.where(go_left, left_id[leaf_id], right_id[leaf_id])
    return jnp.where(has_split, child, leaf_id)


# ---------------------------------------------------------------------------
# Host-side Gini arithmetic.
# ---------------------------------------------------------------------------

def gini_score(below: np.ndarray, total: np.ndarray) -> np.ndarray:
    """Weighted Gini impurity of candidate splits.

    below: (L, C, F) class counts on the left side; total: (L, C).
    Returns (L, F) score (lower is better).
    """
    below = below.astype(np.float64)
    total = total.astype(np.float64)[:, :, None]       # (L, C, 1)
    above = total - below
    nl = below.sum(axis=1)                             # (L, F)
    nr = above.sum(axis=1)
    n = np.maximum(nl + nr, 1e-9)

    def side_gini(counts, m):
        m_safe = np.maximum(m, 1e-9)[:, None, :]
        p = counts / m_safe
        return 1.0 - (p * p).sum(axis=1)               # (L, F)

    gl = side_gini(below, nl)
    gr = side_gini(above, nr)
    return (nl * gl + nr * gr) / n


def fit_steps(dataset, cfg: Optional[TreeConfig] = None):
    """Generator form of tree growth: one frontier round (min-max ->
    split-evaluate -> commit) per ``next()``, the Tree on StopIteration —
    the gang-stepping surface; :func:`fit` drains it."""
    cfg = cfg or TreeConfig()
    pim = dataset.system
    rng = np.random.RandomState(cfg.seed)
    n, nf = dataset.n, dataset.n_features
    max_nodes = 2 ** (cfg.max_depth + 2)

    Xs, ys, valid = dataset.tree_view()
    leaf_id = jnp.zeros(valid.shape, jnp.int32)  # all points in root

    feature = np.full(max_nodes, -1, np.int32)
    threshold = np.zeros(max_nodes, np.float32)
    left = np.zeros(max_nodes, np.int32)
    right = np.zeros(max_nodes, np.int32)
    leaf_class = np.zeros(max_nodes, np.int32)
    depth = np.zeros(max_nodes, np.int32)
    n_nodes = 1
    frontier = [0]

    be = dispatch.resolve_backend(cfg.kernel_backend)
    minmax_k = pim.named_kernel(
        f"dtr.minmax/m{max_nodes}", lambda: make_minmax_kernel(max_nodes))
    eval_k = pim.named_kernel(
        f"dtr.eval/m{max_nodes}.c{cfg.n_classes}/{dispatch.backend_tag(be)}",
        lambda: make_split_eval_kernel(max_nodes, cfg.n_classes, be))
    commit_k = pim.named_kernel("dtr.commit", lambda: _commit_kernel)

    while frontier:
        # ---- min-max command (host draws ERT thresholds) -----------------
        mm = pim.map_reduce_custom(
            minmax_k, (Xs, leaf_id, valid), (jnp.int32(0),),
            reduce={"min": "min", "max": "max"})
        mins, maxs = np.asarray(mm["min"]), np.asarray(mm["max"])
        ok = mins <= maxs  # leaves that actually contain points
        span = np.where(ok, maxs - mins, 0.0)
        base = np.where(ok, mins, 0.0)
        thresholds = np.asarray(
            rng.uniform(0.0, 1.0, size=(max_nodes, nf)), np.float32)
        thresholds = (base + thresholds * span).astype(np.float32)

        # ---- split-evaluate command --------------------------------------
        part = pim.map_reduce(
            eval_k, (Xs, ys, leaf_id, valid),
            (jnp.asarray(thresholds),))
        below = np.asarray(part["below"])   # (L, C, F)
        total = np.asarray(part["total"])   # (L, C)
        score = gini_score(below, total)    # (L, F)

        # ---- host decides splits ----------------------------------------
        split_feature = np.full(max_nodes, -1, np.int32)
        split_thresh = np.zeros(max_nodes, np.float32)
        left_id = np.zeros(max_nodes, np.int32)
        right_id = np.zeros(max_nodes, np.int32)
        new_frontier = []
        for leaf in frontier:
            counts = total[leaf]
            n_leaf = int(counts.sum())
            leaf_class[leaf] = int(counts.argmax())
            if (n_leaf < cfg.min_samples_split
                    or (counts > 0).sum() <= 1
                    or depth[leaf] >= cfg.max_depth
                    or n_nodes + 2 > max_nodes):
                continue
            best_f = int(score[leaf].argmin())
            nl = int(below[leaf, :, best_f].sum())
            if nl == 0 or nl == n_leaf:      # degenerate threshold
                continue
            li, ri = n_nodes, n_nodes + 1
            n_nodes += 2
            feature[leaf] = best_f
            threshold[leaf] = thresholds[leaf, best_f]
            left[leaf], right[leaf] = li, ri
            depth[li] = depth[ri] = depth[leaf] + 1
            # children inherit majority class until refined
            leaf_class[li] = leaf_class[ri] = leaf_class[leaf]
            split_feature[leaf] = best_f
            split_thresh[leaf] = thresholds[leaf, best_f]
            left_id[leaf], right_id[leaf] = li, ri
            new_frontier += [li, ri]

        if not new_frontier:
            break

        # ---- split-commit command ----------------------------------------
        leaf_id = pim.map_elementwise(
            commit_k, (Xs, leaf_id),
            (jnp.asarray(split_feature), jnp.asarray(split_thresh),
             jnp.asarray(left_id), jnp.asarray(right_id)))
        frontier = new_frontier
        yield 1      # one frontier round per scheduling turn

    return Tree(feature, threshold, left, right, leaf_class, depth, n_nodes)


def fit(dataset, cfg: Optional[TreeConfig] = None) -> Tree:
    """Grow one extremely randomized tree over a bank-resident PimDataset.

    The float32 point shards stay resident; per-round only the command
    arguments (thresholds, split decisions) cross the host<->PIM boundary,
    exactly the paper's three-command protocol."""
    return run_steps(fit_steps(dataset, cfg))


def train(X: np.ndarray, y: np.ndarray, pim: System,
          cfg: Optional[TreeConfig] = None) -> Tree:
    """Deprecated shim: re-partitions (X, y) on every call.  Prefer
    ``fit(pim.put(X, y), cfg)`` (repro.api)."""
    warnings.warn("dtree.train(X, y, pim, ...) is deprecated; use "
                  "dtree.fit(pim.put(X, y), cfg)", DeprecationWarning,
                  stacklevel=2)
    from ..api.dataset import as_dataset
    return fit(as_dataset(X, y, pim), cfg)

# The CPU comparison point (the paper's baseline is sklearn; sklearn is
# unavailable offline) is no longer a duplicated numpy worklist here:
# run this same ERT workload on repro.systems.HostSystem — one resident
# image, the identical three-command protocol degenerated to plain
# array ops, e.g. ``dtree.fit(make_system("host").put(X, y), cfg)``.
