"""Quality metrics used by the paper's evaluation (§4.1).

- training error rate (%) for LIN/LOG (thresholded prediction errors)
- training accuracy for DTR
- Calinski-Harabasz score and adjusted Rand index for KME
scikit-learn is unavailable offline, so CH / ARI are implemented here and
unit-tested against hand-computed values.
"""
from __future__ import annotations

import numpy as np


def training_error_rate(pred: np.ndarray, y: np.ndarray,
                        threshold: float = 0.5) -> float:
    """% of thresholded prediction errors (paper's LIN/LOG quality metric)."""
    cls = (np.asarray(pred) > threshold).astype(np.int32)
    return float(np.mean(cls != (np.asarray(y) > threshold))) * 100.0


def accuracy(pred_labels: np.ndarray, y: np.ndarray) -> float:
    return float(np.mean(np.asarray(pred_labels) == np.asarray(y)))


def calinski_harabasz(X: np.ndarray, labels: np.ndarray) -> float:
    """Between/within dispersion ratio (paper cites [237])."""
    X = np.asarray(X, np.float64)
    labels = np.asarray(labels)
    n, _ = X.shape
    ks = np.unique(labels)
    k = len(ks)
    if k < 2:
        return 0.0
    mean = X.mean(axis=0)
    bgss = 0.0
    wgss = 0.0
    for c in ks:
        Xc = X[labels == c]
        mc = Xc.mean(axis=0)
        bgss += len(Xc) * float(((mc - mean) ** 2).sum())
        wgss += float(((Xc - mc) ** 2).sum())
    return (bgss / (k - 1)) / (wgss / (n - k))


def adjusted_rand_index(a: np.ndarray, b: np.ndarray) -> float:
    """ARI [238]; 1.0 = identical partitions (up to relabeling)."""
    a = np.asarray(a)
    b = np.asarray(b)
    n = a.size
    ua, ia = np.unique(a, return_inverse=True)
    ub, ib = np.unique(b, return_inverse=True)
    cont = np.zeros((ua.size, ub.size), np.int64)
    np.add.at(cont, (ia, ib), 1)

    def comb2(x):
        x = x.astype(np.float64)
        return x * (x - 1.0) / 2.0

    sum_ij = comb2(cont).sum()
    sum_a = comb2(cont.sum(axis=1)).sum()
    sum_b = comb2(cont.sum(axis=0)).sum()
    total = comb2(np.array([n]))[0]
    expected = sum_a * sum_b / total
    max_index = 0.5 * (sum_a + sum_b)
    if max_index == expected:
        return 1.0
    return float((sum_ij - expected) / (max_index - expected))


def frobenius_shift(old: np.ndarray, new: np.ndarray) -> float:
    """Relative Frobenius norm between consecutive centroid sets (KME
    convergence criterion, paper §3.4 / §5.1.4)."""
    denom = max(float(np.linalg.norm(old)), 1e-12)
    return float(np.linalg.norm(new - old)) / denom
