"""Compatibility shim — the PIM execution model moved to ``repro.systems``.

The ``PimSystem`` surface grew into the backend-portable ``System``
protocol (DESIGN.md §10): the shared execution machinery lives in
:mod:`repro.systems.base`, the memory-centric PIM implementation (and
the DPU cost model) in :mod:`repro.systems.pim`, with the host-CPU and
modeled-GPU targets alongside.  Every name that used to be defined here
re-exports unchanged, so ``from repro.core.pim import PimSystem`` keeps
working — new code should import from :mod:`repro.systems`.
"""
from ..systems.base import (FabricReduce, HierarchicalReduce, HostReduce,
                            ReduceStrategy, ReduceVia, StepProgram,
                            StrategyLike, System, TransferStats,
                            chunk_schedule, resolve_reduce_strategy,
                            run_steps, _host_sum, _leaf_bytes, _tree_bytes)
from ..systems.pim import (DPU_FREQ_HZ, DPU_MRAM_BYTES_PER_CYCLE,
                           DPU_OP_CYCLES, DPU_PIPELINE_SATURATION_THREADS,
                           WORKLOAD_STORAGE_DTYPE, DpuCostModel, PimConfig,
                           PimSystem, workload_element_bytes)

__all__ = [
    "DPU_FREQ_HZ", "DPU_MRAM_BYTES_PER_CYCLE", "DPU_OP_CYCLES",
    "DPU_PIPELINE_SATURATION_THREADS", "DpuCostModel", "FabricReduce",
    "HierarchicalReduce", "HostReduce", "PimConfig", "PimSystem",
    "ReduceStrategy", "ReduceVia", "StepProgram", "StrategyLike",
    "System", "TransferStats", "WORKLOAD_STORAGE_DTYPE", "chunk_schedule",
    "resolve_reduce_strategy", "run_steps", "workload_element_bytes",
]
