"""PIM execution model (paper §2.2, Fig. 3) mapped onto JAX.

The paper's system: N PIM cores, each owning a DRAM bank; training data is
partitioned once and stays bank-resident; each iteration every core computes
a partial result over its shard; partials are reduced *via the host* (DPUs
cannot talk to each other) and the updated model is re-broadcast.

JAX mapping (DESIGN.md §2):
  PIM core            -> one mesh element of a 1-D "cores" axis
  bank-resident shard -> device-resident leading-axis shard of the dataset
  host reduction      -> jax.lax.psum over "cores" (FabricReduce) or an
                         actual device_get/numpy/device_put round trip
                         (HostReduce — faithful to UPMEM's topology), or a
                         two-level rank schedule (HierarchicalReduce)

Execution surface (DESIGN.md §3):
  ``PimSystem.put(X, y)``      -> a bank-resident :class:`PimDataset` handle
                                  (repro/api/dataset.py); shards transfer to
                                  the banks ONCE and are reused across fits.
  ``register_kernel(name,fn)`` -> named kernels; jit caches are keyed by
                                  (name, generation) or by the function
                                  object itself — never by ``id(fn)``, which
                                  can be reused after GC and silently return
                                  a stale compiled kernel.
  ``map_reduce(..., strategy=)``-> reduction strategy selectable per call
                                  ("fabric" | "host" | "hierarchical"),
                                  defaulting to the system config.

Backends:
  "vmap"      single-device semantic model (cores simulated by vmap) — used
              by unit tests and quality reproduction; bit-identical to the
              sharded path because the kernels are deterministic integer ops.
  "shard_map" real multi-device execution over a jax.Mesh "cores" axis —
              used by the scaling benchmarks and the dry-run.

Also here: ``DpuCostModel``, an instruction-level cost model of the UPMEM
DPU pipeline (425 MHz, fine-grained multithreaded, throughput saturates at
11 tasklets) calibrated against the paper's measured version-to-version
speedups.  The benchmark harness uses it to reproduce Fig. 8-12 shapes
without UPMEM hardware; the calibration table is printed next to the
paper's reported ratios so the fit is auditable.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .quantization import storage_bytes


class ReduceVia(enum.Enum):
    """Legacy reduction selector (kept for config compatibility; the
    per-call ``strategy=`` argument accepts these, their string values,
    or a :class:`ReduceStrategy` instance)."""

    FABRIC = "fabric"   # on-fabric psum (TPU-native; strictly cheaper)
    HOST = "host"       # explicit host round trip (paper-faithful schedule)
    HIERARCHICAL = "hierarchical"  # rank-level fabric sum + host combine


@dataclasses.dataclass
class TransferStats:
    """Byte counters mirroring the paper's CPU-PIM / PIM-CPU breakdowns.

    ``cpu_to_pim`` counts every host->bank byte (dataset shards AND model
    broadcasts).  ``shard_transfers``/``shard_bytes`` count only dataset
    shard materializations, so callers can assert that a hyperparameter
    sweep over one :class:`PimDataset` pays for the CPU->PIM partition
    exactly once (DESIGN.md §3).  ``kernel_launches`` counts host-issued
    kernel dispatches (one per ``map_reduce``/``map_reduce_custom``/
    ``map_elementwise`` call) — the scheduler's fused gang step is
    asserted against it (DESIGN.md §7.3).

    ``host_syncs`` counts host synchronization points — places where the
    host blocks on device results (one per ``map_reduce``/
    ``map_reduce_custom`` call, one per fused :class:`StepProgram`
    chunk).  The step-fusion engine's whole point is that a k-step chunk
    costs ONE sync instead of k (DESIGN.md §9).

    ``snapshot()``/``delta(snapshot)`` make the counters attributable
    when several jobs share one system: snapshot before the job, delta
    after, and the job's own bytes fall out even though the globals keep
    interleaving (DESIGN.md §7.2).
    """

    cpu_to_pim: int = 0
    pim_to_cpu: int = 0
    inter_core_via_host: int = 0
    shard_transfers: int = 0
    shard_bytes: int = 0
    kernel_launches: int = 0
    host_syncs: int = 0

    def reset(self) -> None:
        for field in dataclasses.fields(TransferStats):
            setattr(self, field.name, 0)

    def snapshot(self) -> "TransferStats":
        """Point-in-time copy of every counter (a plain TransferStats)."""
        return TransferStats(**{f.name: getattr(self, f.name)
                                for f in dataclasses.fields(TransferStats)})

    def delta(self, snapshot: "TransferStats") -> "TransferStats":
        """Counters accumulated since ``snapshot`` was taken."""
        return TransferStats(
            **{f.name: getattr(self, f.name) - getattr(snapshot, f.name)
               for f in dataclasses.fields(TransferStats)})


def run_steps(gen):
    """Drain a trainer step generator and return its result.

    The iterative trainers expose ``fit_steps(dataset, cfg)`` generators
    (one host-orchestrated PIM iteration per ``next()``) so the job
    scheduler can gang-step many fits concurrently; ``fit`` is simply
    this drain loop.  The fitted result travels on ``StopIteration``.
    """
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


def chunk_schedule(n_iters: int, fuse_steps: int, record_every: int):
    """Chunk sizes covering ``n_iters`` fused-step iterations, with
    record points forced onto chunk boundaries: each chunk is
    ``min(fuse_steps, next record point, remaining)`` (shared by the GD
    and K-Means trainers and the fused gang — DESIGN.md §9.3)."""
    it = 0
    while it < n_iters:
        k = min(fuse_steps, n_iters - it)
        if record_every:
            next_rec = (it // record_every + 1) * record_every
            k = min(k, next_rec - it)
        yield k
        it += k


# ---------------------------------------------------------------------------
# Reduction strategies (pluggable per map_reduce call).
# ---------------------------------------------------------------------------

class ReduceStrategy:
    """How per-core partials are combined into the host-visible result.

    ``device_reduce`` runs inside the compiled step (traced); ``finalize``
    runs on the host afterwards; ``count_pim_to_cpu`` models the PIM->CPU
    bytes the schedule moves.  ``cache_token`` namespaces the jit cache.

    Step fusion (DESIGN.md §9): ``fusable`` says whether the schedule can
    run entirely on device inside a ``lax.scan`` chunk;
    ``device_reduce_full`` is the fully-on-device reduction the scan body
    uses (for :class:`HierarchicalReduce` it completes the host-combine
    leg on fabric); ``count_chunk`` is the analytic per-chunk byte
    accounting — the reduce still moves k× the single-step bytes even
    when the host round-trip is fused away.
    """

    name = "base"
    #: False when the per-step reduction needs the host (HostReduce): a
    #: StepProgram then degrades to per-step map_reduce syncs.
    fusable = True

    def device_reduce(self, partials):
        return partials

    def device_reduce_full(self, partials):
        """Complete on-device reduction for use inside a fused scan."""
        return self.device_reduce(partials)

    def finalize(self, system: "PimSystem", out):
        return out

    def count_pim_to_cpu(self, system: "PimSystem", out) -> int:
        raise NotImplementedError

    def count_chunk(self, system: "PimSystem", out, k: int) -> None:
        """Account k fused steps' reduce movement (``out`` is the
        abstract per-step ``device_reduce`` result)."""
        system.stats.pim_to_cpu += k * self.count_pim_to_cpu(system, out)

    def cache_token(self):
        return self.name


def _leaf_bytes(v) -> int:
    """nbytes of an array OR an abstract value (ShapeDtypeStruct)."""
    nb = getattr(v, "nbytes", None)
    if nb is None:
        nb = int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
    return int(nb)


def _tree_bytes(tree) -> int:
    return sum(_leaf_bytes(v) for v in jax.tree_util.tree_leaves(tree))


def _host_sum(tree, axis=0):
    """Promoted numpy reduction (int64 / float64 accumulators)."""
    return jax.tree_util.tree_map(
        lambda v: np.sum(np.asarray(v, np.int64)
                         if np.issubdtype(np.asarray(v).dtype, np.integer)
                         else np.asarray(v, np.float64), axis=axis),
        tree)


class FabricReduce(ReduceStrategy):
    """On-device sum over the cores axis (psum under shard_map)."""

    name = "fabric"

    def device_reduce(self, partials):
        return jax.tree_util.tree_map(lambda v: jnp.sum(v, axis=0),
                                      partials)

    def count_pim_to_cpu(self, system, out) -> int:
        # every core ships its partial of the reduced shape to the host
        return _tree_bytes(out) * system.config.n_cores

    def finalize(self, system, out):
        return out


class HostReduce(ReduceStrategy):
    """Paper-faithful schedule: per-core partials are copied to the host
    and reduced with numpy; the result lives on the host (the caller then
    ``broadcast``s the updated model, completing the round trip).

    Not fusable: the reduce itself IS a host round trip, so a
    :class:`StepProgram` chunk degrades to k per-step syncs (DESIGN.md
    §9) — faithful to the UPMEM topology, where fusing the update
    on-device would still leave per-step host reduction."""

    name = "host"
    fusable = False

    def count_pim_to_cpu(self, system, out) -> int:
        return _tree_bytes(out)  # stacked (n_cores, ...) leaves

    def finalize(self, system, out):
        return _host_sum(jax.device_get(out))


class HierarchicalReduce(ReduceStrategy):
    """Two-level schedule: fabric sum inside each rank of ``group_size``
    cores, then a host combine of the rank partials — the PIM analogue of
    the multi-pod RS->AR->AG decomposition in distributed/collectives.py
    (each rank's leader ships 1/group_size of the flat-host bytes over the
    host link; see ``cross_pod_bytes``)."""

    def __init__(self, group_size: int = 8):
        self.group_size = group_size
        self.name = f"hier{group_size}"

    def cache_token(self):
        return ("hier", self.group_size)

    def _groups(self, n_cores: int) -> int:
        g = self.group_size
        return n_cores // g if g > 1 and n_cores % g == 0 else 0

    def device_reduce(self, partials):
        def _grouped(v):
            n_cores = v.shape[0]
            n_groups = self._groups(n_cores)
            if not n_groups:        # awkward core count: flat host schedule
                return v
            return jnp.sum(
                v.reshape(n_groups, self.group_size, *v.shape[1:]), axis=1)
        return jax.tree_util.tree_map(_grouped, partials)

    def count_pim_to_cpu(self, system, out) -> int:
        return _tree_bytes(out)  # (n_groups, ...) rank partials

    def device_reduce_full(self, partials):
        """In a fused scan the rank partials combine on fabric instead of
        on the host (int32 accumulation — exact whenever the flat fabric
        sum is, which the GD/KME value ranges guarantee)."""
        return jax.tree_util.tree_map(
            lambda v: jnp.sum(v, axis=0), self.device_reduce(partials))

    def count_chunk(self, system, out, k: int) -> None:
        # same per-step movement as the unfused schedule: each step the
        # rank partials leave the ranks AND cross the (modeled) host
        # link, k times per chunk
        system.stats.pim_to_cpu += k * self.count_pim_to_cpu(system, out)
        if self._groups(system.config.n_cores):
            system.stats.inter_core_via_host += k * _tree_bytes(out)

    def finalize(self, system, out):
        # intra-rank movement happened "on fabric"; record the rank->host
        # leg separately so the hierarchy's saving is visible in the
        # stats (1/group_size of the flat-host bytes, same napkin as
        # collectives.cross_pod_bytes).  If the core count forced the
        # flat fallback, no rank-level reduction occurred — record none.
        if self._groups(system.config.n_cores):
            system.stats.inter_core_via_host += _tree_bytes(out)
        return _host_sum(jax.device_get(out))


_STRATEGIES: dict[str, Callable[[], ReduceStrategy]] = {
    "fabric": FabricReduce,
    "host": HostReduce,
    "hierarchical": HierarchicalReduce,
}

StrategyLike = Union[None, str, ReduceVia, ReduceStrategy]


def resolve_reduce_strategy(spec: StrategyLike,
                            default: StrategyLike = None) -> ReduceStrategy:
    if spec is None:
        spec = default if default is not None else "fabric"
    if isinstance(spec, ReduceStrategy):
        return spec
    if isinstance(spec, ReduceVia):
        spec = spec.value
    if isinstance(spec, str) and spec in _STRATEGIES:
        return _STRATEGIES[spec]()
    raise ValueError(f"unknown reduce strategy {spec!r}; "
                     f"known: {sorted(_STRATEGIES)}")


@dataclasses.dataclass
class PimConfig:
    n_cores: int = 64
    n_threads: int = 16          # tasklets per core (cost model + layouts)
    reduce: ReduceVia = ReduceVia.FABRIC   # default strategy for map_reduce
    backend: str = "vmap"        # "vmap" | "shard_map"


class PimSystem:
    """Host-orchestrated data-parallel execution over PIM cores.

    The redesigned surface (DESIGN.md §3):
      put(X, y)                 -> PimDataset (bank-resident, view-cached)
      register_kernel(name, fn) -> kernel name usable with map_* calls
      named_kernel(name, build) -> register-once helper for kernel factories
      map_reduce(kernel, ...)   -> kernel may be a registered name or a
                                   callable; ``strategy=`` picks the
                                   reduction per call
    """

    def __init__(self, config: PimConfig, devices: Optional[Sequence] = None):
        self.config = config
        self.stats = TransferStats()
        self._mesh = None
        self._jit_cache: dict = {}
        self._kernels: dict[str, Callable] = {}
        self._kernel_gen: dict[str, int] = {}
        if config.backend == "shard_map":
            devices = list(devices if devices is not None else jax.devices())
            if len(devices) < config.n_cores:
                raise ValueError(
                    f"shard_map backend needs >= {config.n_cores} devices, "
                    f"got {len(devices)} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=...)")
            self._mesh = Mesh(np.array(devices[: config.n_cores]), ("cores",))

    # -- data placement ------------------------------------------------------

    def put(self, X, y=None) -> "Any":
        """Partition a dataset across the PIM banks ONCE and return a
        :class:`repro.api.dataset.PimDataset` handle.

        The handle owns the sharded device arrays, the validity mask, and
        per-version quantized views (lazily materialized, cached), so
        repeated fits / n_init restarts / hyperparameter sweeps reuse one
        CPU->PIM transfer per view (paper §2.2: data is partitioned once
        and stays bank-resident)."""
        from ..api.dataset import PimDataset  # local import: api -> core
        return PimDataset(self, X, y)

    def shard_rows(self, x: np.ndarray, pad_value=0) -> jnp.ndarray:
        """Partition rows across cores: (n, ...) -> (n_cores, n_pc, ...).

        Equal-size shards (padding as needed) mirror the paper's requirement
        that parallel CPU->PIM transfers need equal buffer sizes per bank.
        Counts the modeled CPU->PIM transfer bytes (and the dedicated
        shard_transfers/shard_bytes counters — see TransferStats)."""
        c = self.config.n_cores
        n = x.shape[0]
        n_pc = -(-n // c)
        pad = c * n_pc - n
        if pad:
            x = np.concatenate(
                [x, np.full((pad,) + x.shape[1:], pad_value, x.dtype)], 0)
        out = x.reshape(c, n_pc, *x.shape[1:])
        self.stats.cpu_to_pim += out.nbytes
        self.stats.shard_transfers += 1
        self.stats.shard_bytes += out.nbytes
        arr = jnp.asarray(out)
        if self._mesh is not None:
            arr = jax.device_put(
                arr, NamedSharding(self._mesh, P("cores")))
        return arr

    def row_validity_mask(self, n: int) -> jnp.ndarray:
        """(n_cores, n_pc) bool mask marking real (non-padding) rows."""
        c = self.config.n_cores
        n_pc = -(-n // c)
        idx = np.arange(c * n_pc).reshape(c, n_pc)
        mask = jnp.asarray(idx < n)
        if self._mesh is not None:
            mask = jax.device_put(mask, NamedSharding(self._mesh, P("cores")))
        return mask

    def broadcast(self, tree: Any) -> Any:
        """Host -> all cores broadcast of model state (counted per core)."""
        nbytes = sum(np.asarray(v).nbytes for v in jax.tree_util.tree_leaves(tree))
        self.stats.cpu_to_pim += nbytes * self.config.n_cores
        if self._mesh is not None:
            tree = jax.device_put(
                tree, NamedSharding(self._mesh, P()))  # replicated
        return tree

    # -- kernel registry -----------------------------------------------------

    def register_kernel(self, name: str, fn: Callable) -> str:
        """Register (or replace) a named per-core kernel.

        Re-registering a name with a different function bumps a generation
        counter, orphaning any compiled entries for the old function — a
        stale kernel can never be served for a new registration."""
        if self._kernels.get(name) is not fn:
            self._kernel_gen[name] = self._kernel_gen.get(name, -1) + 1
            self._kernels[name] = fn
        return name

    def named_kernel(self, name: str, builder: Callable[[], Callable]) -> str:
        """Register ``builder()`` under ``name`` unless already present.

        The idiom for parameterized kernel factories: encode the factory
        parameters in the name (e.g. ``"kme.assign/k=16"``) and the
        compiled kernel is reused across fits and restarts."""
        if name not in self._kernels:
            self.register_kernel(name, builder())
        return name

    def registered_kernels(self) -> tuple:
        """Sorted names of all registered kernels (diagnostics/tests).

        Trainer kernel names encode their dispatch routing — e.g.
        ``"kme.assign/k16/be=pallas_tpu"`` — so this is also how tests
        assert that a fit actually went through the kernel tier."""
        return tuple(sorted(self._kernels))

    def _resolve_kernel(self, kernel) -> tuple[tuple, Callable]:
        """Map a kernel reference to (stable cache key, callable).

        Named kernels key by (name, generation).  Raw callables key by the
        function object itself — the cache then holds a strong reference,
        so the function cannot be collected and its identity can never be
        recycled for a different kernel (the id()-reuse bug this replaced).
        """
        if isinstance(kernel, str):
            fn = self._kernels.get(kernel)
            if fn is None:
                raise KeyError(
                    f"no kernel registered under {kernel!r}; "
                    f"known: {sorted(self._kernels)}")
            return ("named", kernel, self._kernel_gen[kernel]), fn
        if not callable(kernel):
            raise TypeError(f"kernel must be a registered name or a "
                            f"callable, got {type(kernel).__name__}")
        return ("fn", kernel), kernel

    # -- execution ------------------------------------------------------------

    def map_reduce(self, kernel, sharded: tuple, replicated: tuple,
                   strategy: StrategyLike = None):
        """Run ``kernel(*shard_args, *replicated)`` on every core and
        reduce the resulting pytree across cores.

        ``kernel`` is a registered name or a callable.  ``strategy`` picks
        the reduction schedule per call ("fabric" | "host" |
        "hierarchical" | a ReduceStrategy); default is the system config.
        Transfer bytes are tracked for every schedule."""
        strat = resolve_reduce_strategy(strategy, self.config.reduce)
        kkey, fn = self._resolve_kernel(kernel)
        key = ("map_reduce", kkey, len(sharded), len(replicated),
               strat.cache_token())
        step = self._jit_cache.get(key)
        if step is None:
            step = self._build_step(fn, strat)
            self._jit_cache[key] = step
        self.stats.kernel_launches += 1
        self.stats.host_syncs += 1
        out = step(tuple(sharded), tuple(replicated))
        self.stats.pim_to_cpu += strat.count_pim_to_cpu(self, out)
        return strat.finalize(self, out)

    def map_reduce_custom(self, kernel, sharded: tuple,
                          replicated: tuple, reduce: dict):
        """Like map_reduce but with per-key reduce ops ("sum"|"min"|"max").

        Used by DTR's min-max command (the host reduces per-core extrema).
        """
        kkey, fn = self._resolve_kernel(kernel)
        key = ("custom", kkey, tuple(sorted(reduce.items())))
        step = self._jit_cache.get(key)
        if step is None:
            def _step(sharded_, replicated_, _fn=fn):
                partials = self._per_core(_fn, sharded_, replicated_)
                return {k: (jnp.sum(v, axis=0) if reduce[k] == "sum"
                            else jnp.min(v, axis=0) if reduce[k] == "min"
                            else jnp.max(v, axis=0))
                        for k, v in partials.items()}
            step = jax.jit(_step)
            self._jit_cache[key] = step
        self.stats.kernel_launches += 1
        self.stats.host_syncs += 1
        out = step(tuple(sharded), tuple(replicated))
        self.stats.pim_to_cpu += _tree_bytes(out) * self.config.n_cores
        return out

    def map_elementwise(self, kernel, sharded: tuple, replicated: tuple):
        """Per-core kernel with *no* reduction: output stays core-resident
        (DTR's split-commit).  Only the replicated command arguments cross
        the host<->PIM boundary; counted accordingly."""
        kkey, fn = self._resolve_kernel(kernel)
        key = ("elem", kkey)
        step = self._jit_cache.get(key)
        if step is None:
            step = jax.jit(
                lambda s, r, _fn=fn: self._per_core(_fn, s, r))
            self._jit_cache[key] = step
        self.stats.kernel_launches += 1
        self.stats.cpu_to_pim += sum(
            np.asarray(v).nbytes for v in replicated) * self.config.n_cores
        return step(tuple(sharded), tuple(replicated))

    def _per_core(self, local_fn, sharded, replicated):
        """Trace the per-core kernel under vmap or shard_map."""
        if self._mesh is None:
            return jax.vmap(lambda *s: local_fn(*s, *replicated))(*sharded)
        mesh = self._mesh

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(tuple(P("cores") for _ in sharded), P()),
            out_specs=P("cores"))
        def _shmap(shard_args, rep):
            local = [jnp.squeeze(a, 0) for a in shard_args]
            out = local_fn(*local, *rep)
            return jax.tree_util.tree_map(lambda v: v[None], out)
        return _shmap(sharded, replicated)

    def _build_step(self, local_fn, strat: ReduceStrategy):
        """Compile one PIM step: per-core kernel + on-device reduce stage."""
        def step(sharded, replicated):
            partials = self._per_core(local_fn, sharded, replicated)
            return strat.device_reduce(partials)
        return jax.jit(step)

    def step_program(self, kernel, prepare: Callable, update: Callable,
                     *, name: str,
                     strategy: StrategyLike = None) -> "StepProgram":
        """Build a :class:`StepProgram` over a registered kernel.

        ``prepare(carry) -> replicated`` derives the per-step broadcast
        arguments (e.g. quantized weights) from the carry; ``update(carry,
        reduced) -> (carry, out)`` applies the host-update math — both
        pure jnp functions, traced into the fused chunk.  ``name`` is the
        jit-cache namespace for the closure pair and must encode every
        parameter baked into it (same convention as ``named_kernel``)."""
        return StepProgram(self, kernel, prepare, update, name=name,
                           strategy=strategy)


class StepProgram:
    """k consecutive training steps compiled into ONE ``lax.scan`` launch.

    The unfused trainers drive every iteration from the host: broadcast
    the model, launch the kernel, reduce, pull the result back, update in
    numpy, repeat — the CPU<->PIM synchronization cadence the paper (and
    PIM-Opt, arXiv:2404.07164) identify as the dominant cost once kernels
    are resident.  A StepProgram keeps the whole iterate-update-broadcast
    cycle on device: per scan step it runs ``prepare(carry)`` (weight
    quantization), the per-core kernel, the strategy's full on-device
    reduce, and ``update(carry, reduced)`` (dequantize + GD update) —
    with the carry buffers donated, so k steps cost one dispatch and one
    host sync instead of k of each (DESIGN.md §9).

    Numerics: prepare/update are the *same* closures the serial loop
    applies between launches, so for the integer versions a fused chunk
    is bit-identical to k unfused steps (asserted by
    tests/test_step_fusion.py).

    Degradation: a non-``fusable`` strategy (HostReduce — the reduce
    itself is a host round trip) runs the chunk as k ordinary
    ``map_reduce`` steps with identical accounting to the unfused loop.
    """

    def __init__(self, system: PimSystem, kernel, prepare: Callable,
                 update: Callable, *, name: str,
                 strategy: StrategyLike = None):
        self.system = system
        self.prepare = prepare
        self.update = update
        self.name = name
        self.strategy = resolve_reduce_strategy(strategy,
                                                system.config.reduce)
        self._kernel = kernel
        self._kkey, self._fn = system._resolve_kernel(kernel)

    # -- fused chunk ---------------------------------------------------------

    def _build_chunk(self, k: int):
        prepare, update, strat = self.prepare, self.update, self.strategy
        per_core, fn = self.system._per_core, self._fn

        def chunk(carry, sharded):
            def one_step(carry, _):
                replicated = prepare(carry)
                partials = per_core(fn, sharded, replicated)
                reduced = strat.device_reduce_full(partials)
                return update(carry, reduced)
            return jax.lax.scan(one_step, carry, None, length=k)
        # donate the carry: the model state is updated in place on
        # device, never materialized on the host inside the chunk
        return jax.jit(chunk, donate_argnums=0)

    def _reduced_shape(self, carry, sharded):
        """Abstract per-step ``device_reduce`` output (eval_shape, cached)
        — what the analytic chunk accounting sizes the reduce legs by.
        Keyed by the operand shapes: one system can run same-named
        programs over datasets of different widths (and slices share
        the parent cache), so name alone would serve stale shapes and
        corrupt the byte accounting."""
        sig = tuple((v.shape, str(v.dtype)) for v in
                    jax.tree_util.tree_leaves((carry, sharded)))
        key = ("step_bytes", self._kkey, self.name,
               self.strategy.cache_token(), sig,
               self.system.config.n_cores)
        out = self.system._jit_cache.get(key)
        if out is None:
            def reduce_stage(carry, sharded):
                partials = self.system._per_core(
                    self._fn, sharded, self.prepare(carry))
                return self.strategy.device_reduce(partials)
            out = jax.eval_shape(reduce_stage, carry, sharded)
            self.system._jit_cache[key] = out
        return out

    def run(self, carry, sharded: tuple, k: int):
        """Advance ``carry`` by ``k`` fused steps over the resident
        shards; returns ``(carry, outs)`` where ``outs`` stacks the
        per-step emits (None when ``update`` emits nothing).

        One kernel launch and one host sync for the whole chunk; the
        analytic byte accounting charges the carry broadcast once, the
        reduce movement k times, and one chunk-boundary PIM->CPU sync of
        the final carry + emits (DESIGN.md §9.2)."""
        sharded = tuple(sharded)
        if k <= 0:
            return carry, None
        if not self.strategy.fusable:
            return self._run_per_step(carry, sharded, k)
        # n_cores in the key: slices share the parent jit cache (vmap
        # backend) and hierarchical rank-partial shapes depend on width
        key = ("step_program", self._kkey, self.name,
               self.strategy.cache_token(), len(sharded), k,
               self.system.config.n_cores)
        chunk = self.system._jit_cache.get(key)
        if chunk is None:
            chunk = self._build_chunk(k)
            self.system._jit_cache[key] = chunk
        stats = self.system.stats
        stats.kernel_launches += 1
        stats.host_syncs += 1
        # the carry (model state) enters the banks once per chunk
        stats.cpu_to_pim += _tree_bytes(carry) * self.system.config.n_cores
        self.strategy.count_chunk(
            self.system, self._reduced_shape(carry, sharded), k)
        carry, outs = chunk(carry, sharded)
        # one pim->cpu sync per chunk boundary: final carry + emits
        stats.pim_to_cpu += _tree_bytes(carry) + _tree_bytes(outs)
        return carry, outs

    def _run_per_step(self, carry, sharded: tuple, k: int):
        """HostReduce degradation: k single steps, each with the per-step
        broadcast + host reduce + host-visible update of the unfused
        loop (byte/launch/sync accounting identical to not fusing)."""
        outs = []
        for _ in range(k):
            replicated = self.system.broadcast(self.prepare(carry))
            reduced = self.system.map_reduce(
                self._kernel, sharded, tuple(replicated),
                strategy=self.strategy)
            carry, out = self.update(carry, reduced)
            outs.append(out)
        if outs and outs[0] is not None:
            outs = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            outs = None
        return carry, outs


# ---------------------------------------------------------------------------
# DPU cost model (benchmark harness only — reproduces Fig. 8-12 shapes).
# ---------------------------------------------------------------------------

#: instruction-cost table (cycles/op at full pipeline) — calibrated so the
#: modeled version ratios match the paper's measured speedups:
#:   LIN-INT32 ~= 10x LIN-FP32 ("order of magnitude", §5.2.1)
#:   LIN-HYB   ~= 1.41x LIN-INT32 (+41%)
#:   LIN-BUI   ~= 1.25x LIN-HYB  (+25%)
#:   LOG LUT   ~= 53x  LOG-INT32 Taylor (§5.2.2)
#:   LOG-HYB-LUT ~= 1.28x LOG-INT32-LUT(WRAM); LOG-BUI-LUT ~= 1.43x HYB
DPU_OP_CYCLES: dict[str, float] = {
    "add32": 1.0,          # native
    "cmp": 1.0,            # native
    "load": 1.0,           # WRAM load (per 32-bit word, post-DMA)
    "mul8_builtin": 4.0,   # custom built-in multiply (Listing 1d)
    "mul16": 7.0,          # compiler-generated 8/16-bit multiply (Listing 1b)
    "mul32_emul": 24.0,    # runtime-emulated 32-bit multiply
    "div32_emul": 56.0,    # runtime-emulated division
    "fadd_emul": 55.0,     # software float add
    "fmul_emul": 70.0,     # software float multiply
    "lut_query_wram": 2.0,   # index clamp + load
    "lut_query_mram": 6.0,   # + DMA latency amortized over batched queries
}

#: MRAM streaming bandwidth per DPU, bytes/cycle (≈ 700 MB/s at 425 MHz)
DPU_MRAM_BYTES_PER_CYCLE = 1.6
DPU_FREQ_HZ = 425e6
DPU_PIPELINE_SATURATION_THREADS = 11

#: on-bank storage dtype of the training data per (workload, version) —
#: the explicit table the cost model's MRAM byte counting reads, with the
#: per-dtype widths shared with quantization.STORAGE_BYTES.  Mirrors the
#: quantized views PimDataset materializes (repro/api/dataset.py).
WORKLOAD_STORAGE_DTYPE: dict[tuple[str, str], str] = {
    ("lin", "fp32"): "fp32",
    ("lin", "int32"): "int32",
    ("lin", "hyb"): "int8",
    ("lin", "bui"): "int8",
    ("log", "fp32"): "fp32",
    ("log", "int32"): "int32",
    ("log", "int32_lut_mram"): "int32",
    ("log", "int32_lut_wram"): "int32",
    ("log", "hyb_lut"): "int8",
    ("log", "bui_lut"): "int8",
    ("dtr", "fp32"): "fp32",
    ("kme", "int16"): "int16",
}


def workload_element_bytes(workload: str, version: str) -> int:
    """Bytes per stored feature value for a workload version."""
    try:
        name = WORKLOAD_STORAGE_DTYPE[(workload, version)]
    except KeyError:
        raise ValueError(
            f"no storage dtype recorded for {workload}/{version}; "
            f"add it to WORKLOAD_STORAGE_DTYPE") from None
    return storage_bytes(name)


@dataclasses.dataclass
class DpuCostModel:
    """Analytic single-DPU kernel-time model.

    ``cycles = max(instr_cycles / throughput(threads), mram_bytes / bw)``
    where throughput(t) = min(t, 11) / 11  (fine-grained multithreading:
    one instruction per cycle only once >= 11 tasklets are resident).
    """

    freq_hz: float = DPU_FREQ_HZ
    saturation_threads: int = DPU_PIPELINE_SATURATION_THREADS

    def kernel_seconds(self, instr_cycles: float, mram_bytes: float,
                       n_threads: int) -> float:
        tp = min(n_threads, self.saturation_threads) / self.saturation_threads
        compute = instr_cycles / max(tp, 1e-9)
        memory = mram_bytes / DPU_MRAM_BYTES_PER_CYCLE
        return max(compute, memory) / self.freq_hz

    # -- per-workload instruction estimates (per sample, F features) --------
    #
    # Calibrated against the paper's measured version-to-version speedups
    # (§5.2.1/§5.2.2) rather than summed from DPU_OP_CYCLES: the compiled
    # inner loops also contain loads, address arithmetic and loop control,
    # so the per-feature totals below are the fitted quantities.  Anchors:
    #   bui  ~ custom mul (4 instr, Listing 1d) + load/acc     -> 8
    #   hyb  ~ compiler 16-bit mul (7 instr, Listing 1b) + l/a -> 10
    #   int32~ emulated 32-bit mul + shifts                    -> 14
    #   fp32 ~ software float mul+add                          -> 120
    # giving fp32/int32 = 8.6x ("order of magnitude"), int32/hyb = 1.40
    # (+41%), hyb/bui = 1.25 (+25%).
    LIN_INSTR_PER_FEATURE = {"fp32": 120.0, "int32": 14.0,
                             "hyb": 10.0, "bui": 8.0}

    #: per-sample sigmoid cost.  The Taylor numbers are fitted to the
    #: paper's measured 53x LUT-over-Taylor speedup and the 65% INT32-over-
    #: FP32 reduction (§5.2.2) — the DPU Taylor loop iterates with emulated
    #: high-precision arithmetic, which is why it is this expensive.
    LOG_SIGMOID_CYCLES = {"fp32": 66_000.0, "int32": 24_000.0,
                          "int32_lut_mram": 6.0, "int32_lut_wram": 2.0,
                          "hyb_lut": 2.0, "bui_lut": 2.0}

    @staticmethod
    def lin_instr(version: str, n_features: int) -> float:
        per_feat = DpuCostModel.LIN_INSTR_PER_FEATURE[version]
        overhead = 24.0 if version == "fp32" else 10.0
        # dot product + gradient pass back over features (second pass)
        return 2 * n_features * per_feat + overhead

    @staticmethod
    def log_instr(version: str, n_features: int) -> float:
        base_ver = {"fp32": "fp32", "int32": "int32",
                    "int32_lut_mram": "int32", "int32_lut_wram": "int32",
                    "hyb_lut": "hyb", "bui_lut": "bui"}[version]
        base = DpuCostModel.lin_instr(base_ver, n_features)
        return base + DpuCostModel.LOG_SIGMOID_CYCLES[version]

    @staticmethod
    def dtr_split_evaluate_instr(n_points: int) -> float:
        c = DPU_OP_CYCLES
        return n_points * (c["load"] + c["cmp"] + c["add32"])

    @staticmethod
    def kme_instr(n_points: int, n_features: int, k: int) -> float:
        c = DPU_OP_CYCLES
        per_pt = k * n_features * (c["load"] + c["mul16"] + c["add32"]) \
            + k * c["cmp"] + n_features * c["add32"]
        return n_points * per_pt

    # -- end-to-end modeled time for the scaling benchmarks ------------------

    def workload_seconds(self, workload: str, version: str, n_samples: int,
                         n_features: int, n_cores: int, n_threads: int,
                         k: int = 16) -> float:
        n_pc = -(-n_samples // n_cores)
        elem_bytes = workload_element_bytes(workload, version)
        bytes_ = n_pc * n_features * elem_bytes
        if workload == "lin":
            instr = n_pc * self.lin_instr(version, n_features)
        elif workload == "log":
            instr = n_pc * self.log_instr(version, n_features)
        elif workload == "dtr":
            instr = self.dtr_split_evaluate_instr(n_pc) * n_features
        elif workload == "kme":
            instr = self.kme_instr(n_pc, n_features, k)
        else:
            raise ValueError(workload)
        return self.kernel_seconds(instr, bytes_, n_threads)
