"""PIM execution model (paper §2.2, Fig. 3) mapped onto JAX.

The paper's system: N PIM cores, each owning a DRAM bank; training data is
partitioned once and stays bank-resident; each iteration every core computes
a partial result over its shard; partials are reduced *via the host* (DPUs
cannot talk to each other) and the updated model is re-broadcast.

JAX mapping (DESIGN.md §2):
  PIM core            -> one mesh element of a 1-D "cores" axis
  bank-resident shard -> device-resident leading-axis shard of the dataset
  host reduction      -> jax.lax.psum over "cores" (ReduceVia.FABRIC) or an
                         actual device_get/numpy/device_put round trip
                         (ReduceVia.HOST — faithful to UPMEM's topology)

Backends:
  "vmap"      single-device semantic model (cores simulated by vmap) — used
              by unit tests and quality reproduction; bit-identical to the
              sharded path because the kernels are deterministic integer ops.
  "shard_map" real multi-device execution over a jax.Mesh "cores" axis —
              used by the scaling benchmarks and the dry-run.

Also here: ``DpuCostModel``, an instruction-level cost model of the UPMEM
DPU pipeline (425 MHz, fine-grained multithreaded, throughput saturates at
11 tasklets) calibrated against the paper's measured version-to-version
speedups.  The benchmark harness uses it to reproduce Fig. 8-12 shapes
without UPMEM hardware; the calibration table is printed next to the
paper's reported ratios so the fit is auditable.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ReduceVia(enum.Enum):
    FABRIC = "fabric"   # on-fabric psum (TPU-native; strictly cheaper)
    HOST = "host"       # explicit host round trip (paper-faithful schedule)


@dataclasses.dataclass
class TransferStats:
    """Byte counters mirroring the paper's CPU-PIM / PIM-CPU breakdowns."""

    cpu_to_pim: int = 0
    pim_to_cpu: int = 0
    inter_core_via_host: int = 0

    def reset(self) -> None:
        self.cpu_to_pim = self.pim_to_cpu = self.inter_core_via_host = 0


@dataclasses.dataclass
class PimConfig:
    n_cores: int = 64
    n_threads: int = 16          # tasklets per core (cost model + layouts)
    reduce: ReduceVia = ReduceVia.FABRIC
    backend: str = "vmap"        # "vmap" | "shard_map"


class PimSystem:
    """Host-orchestrated data-parallel execution over PIM cores."""

    def __init__(self, config: PimConfig, devices: Optional[Sequence] = None):
        self.config = config
        self.stats = TransferStats()
        self._mesh = None
        self._jit_cache: dict = {}
        if config.backend == "shard_map":
            devices = list(devices if devices is not None else jax.devices())
            if len(devices) < config.n_cores:
                raise ValueError(
                    f"shard_map backend needs >= {config.n_cores} devices, "
                    f"got {len(devices)} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=...)")
            self._mesh = Mesh(np.array(devices[: config.n_cores]), ("cores",))

    # -- data placement ------------------------------------------------------

    def shard_rows(self, x: np.ndarray, pad_value=0) -> jnp.ndarray:
        """Partition rows across cores: (n, ...) -> (n_cores, n_pc, ...).

        Equal-size shards (padding as needed) mirror the paper's requirement
        that parallel CPU->PIM transfers need equal buffer sizes per bank.
        Counts the modeled CPU->PIM transfer bytes.
        """
        c = self.config.n_cores
        n = x.shape[0]
        n_pc = -(-n // c)
        pad = c * n_pc - n
        if pad:
            x = np.concatenate(
                [x, np.full((pad,) + x.shape[1:], pad_value, x.dtype)], 0)
        out = x.reshape(c, n_pc, *x.shape[1:])
        self.stats.cpu_to_pim += out.nbytes
        arr = jnp.asarray(out)
        if self._mesh is not None:
            arr = jax.device_put(
                arr, NamedSharding(self._mesh, P("cores")))
        return arr

    def row_validity_mask(self, n: int) -> jnp.ndarray:
        """(n_cores, n_pc) bool mask marking real (non-padding) rows."""
        c = self.config.n_cores
        n_pc = -(-n // c)
        idx = np.arange(c * n_pc).reshape(c, n_pc)
        mask = jnp.asarray(idx < n)
        if self._mesh is not None:
            mask = jax.device_put(mask, NamedSharding(self._mesh, P("cores")))
        return mask

    def broadcast(self, tree: Any) -> Any:
        """Host -> all cores broadcast of model state (counted per core)."""
        nbytes = sum(np.asarray(v).nbytes for v in jax.tree_util.tree_leaves(tree))
        self.stats.cpu_to_pim += nbytes * self.config.n_cores
        if self._mesh is not None:
            tree = jax.device_put(
                tree, NamedSharding(self._mesh, P()))  # replicated
        return tree

    # -- execution ------------------------------------------------------------

    def map_reduce(self, local_fn: Callable, sharded: tuple, replicated: tuple):
        """Run ``local_fn(*shard_args, *replicated)`` on every core and
        sum-reduce the resulting pytree across cores.

        FABRIC: reduction happens on-device (psum / vmap-sum).
        HOST:   per-core partials are copied to the host, reduced with
                numpy, and the result lives on the host (the caller then
                ``broadcast``s the updated model, completing the paper's
                round trip).  Transfer bytes are tracked either way.
        """
        fabric = self.config.reduce is ReduceVia.FABRIC
        key = (id(local_fn), len(sharded), len(replicated), fabric)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._build_step(local_fn, fabric)
            self._jit_cache[key] = fn
        out = fn(tuple(sharded), tuple(replicated))

        out_bytes = sum(v.nbytes for v in jax.tree_util.tree_leaves(out))
        # every core ships its partial of the same shape to the host
        self.stats.pim_to_cpu += out_bytes * (
            self.config.n_cores if fabric else 1)

        if self.config.reduce is ReduceVia.HOST:
            host_partials = jax.device_get(out)  # (n_cores, ...) leaves
            return jax.tree_util.tree_map(
                lambda v: np.sum(np.asarray(v, np.int64)
                                 if np.issubdtype(v.dtype, np.integer)
                                 else np.asarray(v, np.float64), axis=0),
                host_partials)
        return out

    def map_reduce_custom(self, local_fn: Callable, sharded: tuple,
                          replicated: tuple, reduce: dict):
        """Like map_reduce but with per-key reduce ops ("sum"|"min"|"max").

        Used by DTR's min-max command (the host reduces per-core extrema).
        """
        key = ("custom", id(local_fn), tuple(sorted(reduce.items())))
        fn = self._jit_cache.get(key)
        if fn is None:
            def step(sharded_, replicated_):
                partials = self._per_core(local_fn, sharded_, replicated_)
                return {k: (jnp.sum(v, axis=0) if reduce[k] == "sum"
                            else jnp.min(v, axis=0) if reduce[k] == "min"
                            else jnp.max(v, axis=0))
                        for k, v in partials.items()}
            fn = jax.jit(step)
            self._jit_cache[key] = fn
        out = fn(tuple(sharded), tuple(replicated))
        self.stats.pim_to_cpu += sum(
            v.nbytes for v in jax.tree_util.tree_leaves(out)
        ) * self.config.n_cores
        return out

    def map_elementwise(self, local_fn: Callable, sharded: tuple,
                        replicated: tuple):
        """Per-core kernel with *no* reduction: output stays core-resident
        (DTR's split-commit).  Only the replicated command arguments cross
        the host<->PIM boundary; counted accordingly."""
        key = ("elem", id(local_fn))
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = jax.jit(lambda s, r: self._per_core(local_fn, s, r))
            self._jit_cache[key] = fn
        self.stats.cpu_to_pim += sum(
            np.asarray(v).nbytes for v in replicated) * self.config.n_cores
        return fn(tuple(sharded), tuple(replicated))

    def _per_core(self, local_fn, sharded, replicated):
        """Trace the per-core kernel under vmap or shard_map."""
        if self._mesh is None:
            return jax.vmap(lambda *s: local_fn(*s, *replicated))(*sharded)
        mesh = self._mesh

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(tuple(P("cores") for _ in sharded), P()),
            out_specs=P("cores"))
        def _shmap(shard_args, rep):
            local = [jnp.squeeze(a, 0) for a in shard_args]
            out = local_fn(*local, *rep)
            return jax.tree_util.tree_map(lambda v: v[None], out)
        return _shmap(sharded, replicated)

    def _build_step(self, local_fn, fabric: bool):
        """Compile one PIM step: per-core kernel (+ on-fabric sum reduce)."""
        def step(sharded, replicated):
            partials = self._per_core(local_fn, sharded, replicated)
            if fabric:
                return jax.tree_util.tree_map(
                    lambda v: jnp.sum(v, axis=0), partials)
            return partials
        return jax.jit(step)


# ---------------------------------------------------------------------------
# DPU cost model (benchmark harness only — reproduces Fig. 8-12 shapes).
# ---------------------------------------------------------------------------

#: instruction-cost table (cycles/op at full pipeline) — calibrated so the
#: modeled version ratios match the paper's measured speedups:
#:   LIN-INT32 ~= 10x LIN-FP32 ("order of magnitude", §5.2.1)
#:   LIN-HYB   ~= 1.41x LIN-INT32 (+41%)
#:   LIN-BUI   ~= 1.25x LIN-HYB  (+25%)
#:   LOG LUT   ~= 53x  LOG-INT32 Taylor (§5.2.2)
#:   LOG-HYB-LUT ~= 1.28x LOG-INT32-LUT(WRAM); LOG-BUI-LUT ~= 1.43x HYB
DPU_OP_CYCLES: dict[str, float] = {
    "add32": 1.0,          # native
    "cmp": 1.0,            # native
    "load": 1.0,           # WRAM load (per 32-bit word, post-DMA)
    "mul8_builtin": 4.0,   # custom built-in multiply (Listing 1d)
    "mul16": 7.0,          # compiler-generated 8/16-bit multiply (Listing 1b)
    "mul32_emul": 24.0,    # runtime-emulated 32-bit multiply
    "div32_emul": 56.0,    # runtime-emulated division
    "fadd_emul": 55.0,     # software float add
    "fmul_emul": 70.0,     # software float multiply
    "lut_query_wram": 2.0,   # index clamp + load
    "lut_query_mram": 6.0,   # + DMA latency amortized over batched queries
}

#: MRAM streaming bandwidth per DPU, bytes/cycle (≈ 700 MB/s at 425 MHz)
DPU_MRAM_BYTES_PER_CYCLE = 1.6
DPU_FREQ_HZ = 425e6
DPU_PIPELINE_SATURATION_THREADS = 11


@dataclasses.dataclass
class DpuCostModel:
    """Analytic single-DPU kernel-time model.

    ``cycles = max(instr_cycles / throughput(threads), mram_bytes / bw)``
    where throughput(t) = min(t, 11) / 11  (fine-grained multithreading:
    one instruction per cycle only once >= 11 tasklets are resident).
    """

    freq_hz: float = DPU_FREQ_HZ
    saturation_threads: int = DPU_PIPELINE_SATURATION_THREADS

    def kernel_seconds(self, instr_cycles: float, mram_bytes: float,
                       n_threads: int) -> float:
        tp = min(n_threads, self.saturation_threads) / self.saturation_threads
        compute = instr_cycles / max(tp, 1e-9)
        memory = mram_bytes / DPU_MRAM_BYTES_PER_CYCLE
        return max(compute, memory) / self.freq_hz

    # -- per-workload instruction estimates (per sample, F features) --------
    #
    # Calibrated against the paper's measured version-to-version speedups
    # (§5.2.1/§5.2.2) rather than summed from DPU_OP_CYCLES: the compiled
    # inner loops also contain loads, address arithmetic and loop control,
    # so the per-feature totals below are the fitted quantities.  Anchors:
    #   bui  ~ custom mul (4 instr, Listing 1d) + load/acc     -> 8
    #   hyb  ~ compiler 16-bit mul (7 instr, Listing 1b) + l/a -> 10
    #   int32~ emulated 32-bit mul + shifts                    -> 14
    #   fp32 ~ software float mul+add                          -> 120
    # giving fp32/int32 = 8.6x ("order of magnitude"), int32/hyb = 1.40
    # (+41%), hyb/bui = 1.25 (+25%).
    LIN_INSTR_PER_FEATURE = {"fp32": 120.0, "int32": 14.0,
                             "hyb": 10.0, "bui": 8.0}

    #: per-sample sigmoid cost.  The Taylor numbers are fitted to the
    #: paper's measured 53x LUT-over-Taylor speedup and the 65% INT32-over-
    #: FP32 reduction (§5.2.2) — the DPU Taylor loop iterates with emulated
    #: high-precision arithmetic, which is why it is this expensive.
    LOG_SIGMOID_CYCLES = {"fp32": 66_000.0, "int32": 24_000.0,
                          "int32_lut_mram": 6.0, "int32_lut_wram": 2.0,
                          "hyb_lut": 2.0, "bui_lut": 2.0}

    @staticmethod
    def lin_instr(version: str, n_features: int) -> float:
        per_feat = DpuCostModel.LIN_INSTR_PER_FEATURE[version]
        overhead = 24.0 if version == "fp32" else 10.0
        # dot product + gradient pass back over features (second pass)
        return 2 * n_features * per_feat + overhead

    @staticmethod
    def log_instr(version: str, n_features: int) -> float:
        base_ver = {"fp32": "fp32", "int32": "int32",
                    "int32_lut_mram": "int32", "int32_lut_wram": "int32",
                    "hyb_lut": "hyb", "bui_lut": "bui"}[version]
        base = DpuCostModel.lin_instr(base_ver, n_features)
        return base + DpuCostModel.LOG_SIGMOID_CYCLES[version]

    @staticmethod
    def dtr_split_evaluate_instr(n_points: int) -> float:
        c = DPU_OP_CYCLES
        return n_points * (c["load"] + c["cmp"] + c["add32"])

    @staticmethod
    def kme_instr(n_points: int, n_features: int, k: int) -> float:
        c = DPU_OP_CYCLES
        per_pt = k * n_features * (c["load"] + c["mul16"] + c["add32"]) \
            + k * c["cmp"] + n_features * c["add32"]
        return n_points * per_pt

    # -- end-to-end modeled time for the scaling benchmarks ------------------

    def workload_seconds(self, workload: str, version: str, n_samples: int,
                         n_features: int, n_cores: int, n_threads: int,
                         k: int = 16) -> float:
        n_pc = -(-n_samples // n_cores)
        if workload == "lin":
            instr = n_pc * self.lin_instr(version, n_features)
            bytes_ = n_pc * n_features * (4 if "32" in version or version == "fp32" else 1)
        elif workload == "log":
            instr = n_pc * self.log_instr(version, n_features)
            bytes_ = n_pc * n_features * (4 if "int32" in version or version == "fp32" else 1)
        elif workload == "dtr":
            instr = self.dtr_split_evaluate_instr(n_pc) * n_features
            bytes_ = n_pc * n_features * 4
        elif workload == "kme":
            instr = self.kme_instr(n_pc, n_features, k)
            bytes_ = n_pc * n_features * 2
        else:
            raise ValueError(workload)
        return self.kernel_seconds(instr, bytes_, n_threads)
