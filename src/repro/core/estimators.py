"""Legacy scikit-learn-style estimator classes (paper §4).

These are deprecation shims kept for one PR: each class is a thin
subclass of the generic :class:`repro.api.PimEstimator` facade bound to
its registered workload — construct new code via
``repro.api.make_estimator(name, version=...)`` instead.  Every
construction emits exactly one :class:`DeprecationWarning`; behaviour is
otherwise identical to the facade (asserted by tests/test_deprecation.py).

sklearn itself is not installable in this offline container, so the
facade implements the fit/predict/score/get_params protocol directly;
it is duck-type compatible with sklearn pipelines.  Every shim accepts
``version`` and the full hyperparameter surface of its workload, so the
sklearn clone round-trip ``cls(**est.get_params())`` reconstructs it.
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..api.estimator import PimEstimator
from ..systems import System


def _warn_legacy(cls_name: str, workload: str) -> None:
    warnings.warn(
        f"{cls_name} is deprecated; use "
        f"repro.api.make_estimator({workload!r}, version=...)",
        DeprecationWarning, stacklevel=3)


class PimLinearRegression(PimEstimator):
    """LIN on the PIM system.  ``version`` in {fp32, int32, hyb, bui}."""

    def __init__(self, version: str = "fp32", n_iters: int = 500,
                 lr: float = 0.1, n_cores: int = 16,
                 pim: Optional[System] = None, **params):
        _warn_legacy("PimLinearRegression", "linreg")
        super().__init__("linreg", version=version, n_cores=n_cores,
                         system=pim, n_iters=n_iters, lr=lr, **params)


class PimLogisticRegression(PimEstimator):
    """LOG on the PIM system.  ``version`` in logreg.VERSIONS."""

    def __init__(self, version: str = "fp32", n_iters: int = 500,
                 lr: float = 5.0, n_cores: int = 16,
                 pim: Optional[System] = None, **params):
        _warn_legacy("PimLogisticRegression", "logreg")
        super().__init__("logreg", version=version, n_cores=n_cores,
                         system=pim, n_iters=n_iters, lr=lr, **params)


class PimDecisionTreeClassifier(PimEstimator):
    """DTR (extremely randomized tree) on the PIM system."""

    def __init__(self, max_depth: int = 10, n_classes: int = 2,
                 seed: int = 0, n_cores: int = 16,
                 pim: Optional[System] = None,
                 version: Optional[str] = None, **params):
        _warn_legacy("PimDecisionTreeClassifier", "dtree")
        super().__init__("dtree", version=version, n_cores=n_cores,
                         system=pim, max_depth=max_depth,
                         n_classes=n_classes, seed=seed, **params)


class PimKMeans(PimEstimator):
    """KME on the PIM system (quantized Lloyd's with restarts)."""

    def __init__(self, n_clusters: int = 16, max_iter: int = 300,
                 tol: float = 1e-4, n_init: int = 1, seed: int = 0,
                 n_cores: int = 16, pim: Optional[System] = None,
                 version: Optional[str] = None, **params):
        _warn_legacy("PimKMeans", "kmeans")
        super().__init__("kmeans", version=version, n_cores=n_cores,
                         system=pim, n_clusters=n_clusters,
                         max_iter=max_iter, tol=tol, n_init=n_init,
                         seed=seed, **params)
