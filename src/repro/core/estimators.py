"""Scikit-learn-style estimator facade (paper §4: "we make our
implementations ... compatible with Scikit-learn ... by deploying them as
Scikit-learn estimator objects").

sklearn itself is not installable in this offline container, so these
estimators implement the fit/predict/score protocol directly; they are
duck-type compatible with sklearn pipelines.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from . import dtree, kmeans, linreg, logreg, metrics
from .pim import PimConfig, PimSystem


def _default_pim(n_cores: int = 16) -> PimSystem:
    return PimSystem(PimConfig(n_cores=n_cores))


class PimLinearRegression:
    """LIN on the PIM system.  ``version`` in {fp32, int32, hyb, bui}."""

    def __init__(self, version: str = "fp32", n_iters: int = 500,
                 lr: float = 0.1, n_cores: int = 16,
                 pim: Optional[PimSystem] = None):
        self.version, self.n_iters, self.lr = version, n_iters, lr
        self.pim = pim or _default_pim(n_cores)
        self.result_ = None

    def fit(self, X, y):
        cfg = linreg.GdConfig(version=self.version, n_iters=self.n_iters,
                              lr=self.lr)
        self.result_ = linreg.train(np.asarray(X), np.asarray(y),
                                    self.pim, cfg)
        self.coef_ = self.result_.w
        self.intercept_ = self.result_.b
        return self

    def predict(self, X):
        return self.result_.predict(np.asarray(X))

    def score(self, X, y):
        """R^2, the sklearn regression convention."""
        y = np.asarray(y, np.float64)
        pred = self.predict(X)
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum())
        return 1.0 - ss_res / max(ss_tot, 1e-12)


class PimLogisticRegression:
    """LOG on the PIM system.  ``version`` in logreg.VERSIONS."""

    def __init__(self, version: str = "fp32", n_iters: int = 500,
                 lr: float = 5.0, n_cores: int = 16,
                 pim: Optional[PimSystem] = None):
        self.version, self.n_iters, self.lr = version, n_iters, lr
        self.pim = pim or _default_pim(n_cores)
        self.result_ = None

    def fit(self, X, y):
        cfg = logreg.LogRegConfig(version=self.version,
                                  n_iters=self.n_iters, lr=self.lr)
        self.result_ = logreg.train(np.asarray(X), np.asarray(y),
                                    self.pim, cfg)
        self.coef_ = self.result_.w
        self.intercept_ = self.result_.b
        return self

    def decision_function(self, X):
        return self.result_.predict(np.asarray(X))

    def predict_proba(self, X):
        z = self.decision_function(X)
        p1 = 1.0 / (1.0 + np.exp(-z))
        return np.stack([1.0 - p1, p1], axis=1)

    def predict(self, X):
        return (self.decision_function(X) > 0.0).astype(np.int32)

    def score(self, X, y):
        return metrics.accuracy(self.predict(X), np.asarray(y) > 0.5)


class PimDecisionTreeClassifier:
    """DTR (extremely randomized tree) on the PIM system."""

    def __init__(self, max_depth: int = 10, n_classes: int = 2,
                 seed: int = 0, n_cores: int = 16,
                 pim: Optional[PimSystem] = None):
        self.cfg = dtree.TreeConfig(max_depth=max_depth,
                                    n_classes=n_classes, seed=seed)
        self.pim = pim or _default_pim(n_cores)
        self.tree_ = None

    def fit(self, X, y):
        self.tree_ = dtree.train(np.asarray(X), np.asarray(y),
                                 self.pim, self.cfg)
        return self

    def predict(self, X):
        return self.tree_.predict(np.asarray(X))

    def score(self, X, y):
        return metrics.accuracy(self.predict(X), np.asarray(y))


class PimKMeans:
    """KME on the PIM system (quantized Lloyd's with restarts)."""

    def __init__(self, n_clusters: int = 16, max_iter: int = 300,
                 tol: float = 1e-4, n_init: int = 1, seed: int = 0,
                 n_cores: int = 16, pim: Optional[PimSystem] = None):
        self.cfg = kmeans.KMeansConfig(k=n_clusters, max_iters=max_iter,
                                       tol=tol, n_init=n_init, seed=seed)
        self.pim = pim or _default_pim(n_cores)
        self.result_ = None

    def fit(self, X):
        self.result_ = kmeans.train(np.asarray(X), self.pim, self.cfg)
        self.cluster_centers_ = self.result_.centroids
        self.inertia_ = self.result_.inertia
        self.labels_ = self.result_.labels
        return self

    def predict(self, X):
        X = np.asarray(X, np.float32)
        C = self.cluster_centers_
        d = -2.0 * X @ C.T + (C * C).sum(1)[None, :]
        return d.argmin(1).astype(np.int32)

    def fit_predict(self, X):
        return self.fit(X).labels_

    def score(self, X):
        """Negative inertia (sklearn convention)."""
        X = np.asarray(X, np.float32)
        C = self.cluster_centers_
        d = ((X[:, None, :] - C[None, :, :]) ** 2).sum(-1)
        return -float(d.min(1).sum())
