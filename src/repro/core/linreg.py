"""Linear regression with gradient descent on the PIM system (paper §3.1).

Four versions, exactly the paper's ladder of optimizations:
  LIN-FP32   32-bit float training data and arithmetic (emulated on DPUs —
             native on TPU, so this doubles as the CPU/GPU-style baseline).
  LIN-INT32  32-bit fixed-point (Q. frac_bits) data + arithmetic.
  LIN-HYB    hybrid precision: 8-bit inputs x 16-bit weights, 16-bit dot
             products, 32-bit gradients.
  LIN-BUI    same numerics as LIN-HYB (paper: "same behavior, since they
             use the same datatypes") + the custom built-in multiply, which
             only changes the instruction count -> modeled by DpuCostModel.

Workload distribution mirrors §3.1: rows are partitioned across PIM cores;
each core computes partial gradients over its resident shard; the host
reduces partials, updates w, and re-broadcasts it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch
from .fixed_point import (_shift_round, fx_dot_hybrid, from_fixed,
                          to_fixed)
from .pim import PimSystem, run_steps

VERSIONS = ("fp32", "int32", "hyb", "bui")


@dataclasses.dataclass
class GdConfig:
    version: str = "fp32"
    n_iters: int = 500
    lr: float = 0.1
    frac_bits: int = 10      # Q format for INT32 data / all fixed-point grads
    x8_frac: int = 7         # Q format of 8-bit inputs (HYB/BUI)
    w16_frac: int = 8        # Q format of 16-bit weights (HYB/BUI)
    record_every: int = 0    # 0 = only final metrics
    minibatch: int = 0       # 0 = full-batch GD (paper default); >0 =
    #                          SGD with per-core minibatches of this size
    #                          (paper §2: "gradient descent or stochastic
    #                          gradient descent")
    seed: int = 0
    #: kernel backend for the dispatch-routed pieces of the per-core
    #: gradient kernel (None = auto-select; repro.kernels.dispatch).
    #: INT32 versions route their Q-format matvec through the
    #: ``fx_matvec`` op; HYB/BUI keep the inline saturating 16-bit
    #: accumulation (a sequential-clip semantic no matmul kernel can
    #: express — DESIGN.md §6.3).
    kernel_backend: Optional[str] = None


@dataclasses.dataclass
class GdResult:
    w: np.ndarray            # float32 [F]
    b: float
    history: list            # [(iter, metric)] if record_every else []
    n_iters: int = 0

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X, np.float32) @ self.w + self.b


# ---------------------------------------------------------------------------
# Per-core kernels (run on every PIM core over its resident shard).
# ---------------------------------------------------------------------------

def _local_grad_fp32(Xc, yc, mask, w, b):
    pred = Xc @ w + b
    err = (pred - yc) * mask
    return {"gw": Xc.T @ err, "gb": jnp.sum(err)}


def make_local_grad_int32(frac_bits: int, backend=None):
    be = dispatch.resolve_backend(backend)

    def _local(Xq, yq, mask, wq, bq):
        # Q-format matvec through the kernel-dispatch layer (op
        # ``fx_matvec``; bit-identical to fixed_point.fx_dot)
        dot = dispatch.launch("fx_matvec", Xq, wq, frac_bits,
                              backend=be) + bq          # Q(f)
        err = (dot - yq) * mask                         # Q(f)
        prod = err[:, None] * Xq.astype(jnp.int32)      # Q(2f)
        gw = jnp.sum(_shift_round(prod, frac_bits), 0)  # Q(f)
        return {"gw": gw, "gb": jnp.sum(err)}
    return _local


def make_local_grad_hyb(x8_frac: int, w16_frac: int, out_frac: int):
    def _local(Xq8, yq, mask, wq16, bq):
        # 16-bit saturating dot product (the paper's stated precision)
        dot = fx_dot_hybrid(Xq8, wq16, x8_frac, w16_frac, out_frac) + bq
        err = (dot - yq) * mask                          # Q(out_frac) int32
        prod = err[:, None] * Xq8.astype(jnp.int32)      # Q(out+x8)
        gw = jnp.sum(_shift_round(prod, x8_frac), 0)     # Q(out_frac)
        return {"gw": gw, "gb": jnp.sum(err)}
    return _local


# ---------------------------------------------------------------------------
# Host-orchestrated training loop (paper §3.1 flow).
# ---------------------------------------------------------------------------

def _quantize_weights(cfg: GdConfig, w: np.ndarray, b: float):
    if cfg.version == "fp32":
        return jnp.asarray(w), jnp.float32(b)
    if cfg.version == "int32":
        return to_fixed(w, cfg.frac_bits), to_fixed(b, cfg.frac_bits)
    return (to_fixed(w, cfg.w16_frac, dtype=jnp.int16),
            to_fixed(b, cfg.frac_bits))


def _grad_to_float(cfg: GdConfig, partial) -> tuple[np.ndarray, float]:
    gw, gb = np.asarray(partial["gw"]), np.asarray(partial["gb"])
    if cfg.version == "fp32":
        return gw.astype(np.float32), float(gb)
    return (np.asarray(from_fixed(jnp.asarray(gw), cfg.frac_bits)),
            float(from_fixed(jnp.asarray(gb), cfg.frac_bits)))


def build_local_grad(cfg: GdConfig) -> Callable:
    """The per-core gradient kernel for ``cfg.version`` (unregistered).

    Exposed separately from the named registration so the scheduler's
    fused gang step can vmap the *same* per-core function over a job
    axis (DESIGN.md §7.3) — fused and serial paths share one kernel
    definition and cannot drift numerically."""
    if cfg.version == "fp32":
        return _local_grad_fp32
    if cfg.version == "int32":
        return make_local_grad_int32(cfg.frac_bits,
                                     dispatch.resolve_backend(
                                         cfg.kernel_backend))
    return make_local_grad_hyb(cfg.x8_frac, cfg.w16_frac, cfg.frac_bits)


def grad_kernel_name(cfg: GdConfig) -> str:
    """Registry name encoding every parameter baked into the kernel."""
    if cfg.version == "fp32":
        return "lin.grad/fp32"
    if cfg.version == "int32":
        be = dispatch.resolve_backend(cfg.kernel_backend)
        return f"lin.grad/int32/f{cfg.frac_bits}/{dispatch.backend_tag(be)}"
    return f"lin.grad/hyb/x{cfg.x8_frac}.w{cfg.w16_frac}.f{cfg.frac_bits}"


def _grad_kernel(pim: PimSystem, cfg: GdConfig):
    """Named per-core gradient kernel for the configured version
    (registered once per PimSystem; reused across fits and sweeps)."""
    return pim.named_kernel(grad_kernel_name(cfg),
                            lambda: build_local_grad(cfg))


def fit_steps(dataset, cfg: Optional[GdConfig] = None,
              eval_fn: Optional[Callable] = None,
              _local_override: Optional[Callable] = None):
    """Generator form of the training loop: one (broadcast -> kernel ->
    reduce -> host update) PIM iteration per ``next()``; the GdResult
    travels on StopIteration.  This is the gang-stepping surface the job
    scheduler interleaves (DESIGN.md §7.3); :func:`fit` drains it."""
    cfg = cfg or GdConfig()
    assert cfg.version in VERSIONS, cfg.version
    pim = dataset.system
    n, f = dataset.n, dataset.n_features
    Xs, ys, mask = dataset.gd_view(cfg.version, cfg.frac_bits, cfg.x8_frac)

    if _local_override is not None:
        local = _local_override
    else:
        local = _grad_kernel(pim, cfg)

    w = np.zeros(f, np.float32)
    b = 0.0
    history = []
    rng = np.random.RandomState(cfg.seed)
    n_pc = Xs.shape[1]
    for it in range(cfg.n_iters):
        wq, bq = _quantize_weights(cfg, w, b)
        wq, bq = pim.broadcast((wq, bq))
        if cfg.minibatch and cfg.minibatch < n_pc:
            # SGD: every core samples the same per-core slice offset
            # (keeps shards aligned; bank-resident data is never moved)
            start = int(rng.randint(0, n_pc - cfg.minibatch + 1))
            sl = (slice(None), slice(start, start + cfg.minibatch))
            args = (Xs[sl], ys[sl], mask[sl])
            n_eff = cfg.minibatch * pim.config.n_cores
        else:
            args = (Xs, ys, mask)
            n_eff = n
        partial = pim.map_reduce(local, args, (wq, bq))
        gw, gb = _grad_to_float(cfg, partial)
        w = w - cfg.lr * (2.0 / n_eff) * gw
        b = b - cfg.lr * (2.0 / n_eff) * gb
        if cfg.record_every and ((it + 1) % cfg.record_every == 0
                                 or it == cfg.n_iters - 1):
            metric = eval_fn(w, b) if eval_fn else None
            history.append((it + 1, metric))
        yield it + 1
    return GdResult(w=w, b=float(b), history=history, n_iters=cfg.n_iters)


def fit(dataset, cfg: Optional[GdConfig] = None,
        eval_fn: Optional[Callable] = None,
        _local_override: Optional[Callable] = None) -> GdResult:
    """Full PIM training loop over a bank-resident PimDataset: iterate
    (kernel -> reduce -> host update -> broadcast) until cfg.n_iters.
    The dataset's quantized view is materialized at most once per
    (version, Q-format) — repeated fits reuse the resident shards."""
    return run_steps(fit_steps(dataset, cfg, eval_fn, _local_override))


def train(X: np.ndarray, y: np.ndarray, pim: PimSystem,
          cfg: Optional[GdConfig] = None,
          eval_fn: Optional[Callable] = None,
          _local_override: Optional[Callable] = None) -> GdResult:
    """Deprecated shim: re-partitions (X, y) on every call.  Prefer
    ``fit(pim.put(X, y), cfg)`` which keeps the shards bank-resident
    across fits (repro.api)."""
    warnings.warn("linreg.train(X, y, pim, ...) is deprecated; use "
                  "linreg.fit(pim.put(X, y), cfg)", DeprecationWarning,
                  stacklevel=2)
    from ..api.dataset import as_dataset
    return fit(as_dataset(X, y, pim), cfg, eval_fn, _local_override)


def train_cpu_baseline(X: np.ndarray, y: np.ndarray, n_iters: int = 500,
                       lr: float = 0.1) -> GdResult:
    """The CPU comparison point (paper §5.4 uses MKL; here: numpy BLAS)."""
    n, f = X.shape
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    w = np.zeros(f, np.float32)
    b = np.float32(0.0)
    for _ in range(n_iters):
        err = X @ w + b - y
        w = w - lr * (2.0 / n) * (X.T @ err)
        b = b - lr * (2.0 / n) * err.sum()
    return GdResult(w=w, b=float(b), history=[], n_iters=n_iters)
