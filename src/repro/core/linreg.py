"""Linear regression with gradient descent on the PIM system (paper §3.1).

Four versions, exactly the paper's ladder of optimizations:
  LIN-FP32   32-bit float training data and arithmetic (emulated on DPUs —
             native on TPU, so this doubles as the CPU/GPU-style baseline).
  LIN-INT32  32-bit fixed-point (Q. frac_bits) data + arithmetic.
  LIN-HYB    hybrid precision: 8-bit inputs x 16-bit weights, 16-bit dot
             products, 32-bit gradients.
  LIN-BUI    same numerics as LIN-HYB (paper: "same behavior, since they
             use the same datatypes") + the custom built-in multiply, which
             only changes the instruction count -> modeled by DpuCostModel.

Workload distribution mirrors §3.1: rows are partitioned across PIM cores;
each core computes partial gradients over its resident shard; the host
reduces partials, updates w, and re-broadcasts it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..elastic.state import pack_rng, unpack_rng
from ..kernels import dispatch
from ..systems import (ChunkPipeline, ChunkTick, System, chunk_schedule,
                       run_steps)
from .fixed_point import (_shift_round, fx_dot_hybrid, from_fixed,
                          mul_round_f32, to_fixed)

VERSIONS = ("fp32", "int32", "hyb", "bui")


@dataclasses.dataclass
class GdConfig:
    version: str = "fp32"
    n_iters: int = 500
    lr: float = 0.1
    frac_bits: int = 10      # Q format for INT32 data / all fixed-point grads
    x8_frac: int = 7         # Q format of 8-bit inputs (HYB/BUI)
    w16_frac: int = 8        # Q format of 16-bit weights (HYB/BUI)
    record_every: int = 0    # 0 = only final metrics
    minibatch: int = 0       # 0 = full-batch GD (paper default); >0 =
    #                          SGD with per-core minibatches of this size
    #                          (paper §2: "gradient descent or stochastic
    #                          gradient descent")
    seed: int = 0
    #: kernel backend for the dispatch-routed pieces of the per-core
    #: gradient kernel (None = auto-select; repro.kernels.dispatch).
    #: INT32 versions route their Q-format matvec through the
    #: ``fx_matvec`` op; HYB/BUI keep the inline saturating 16-bit
    #: accumulation (a sequential-clip semantic no matmul kernel can
    #: express — DESIGN.md §6.3).
    kernel_backend: Optional[str] = None
    #: step fusion (DESIGN.md §9): compile this many consecutive GD
    #: iterations into ONE lax.scan launch — the whole kernel -> reduce
    #: -> update -> re-quantize cycle stays on device between chunk
    #: boundaries.  1 = the host-orchestrated per-step loop.  Works for
    #: minibatch SGD too (DESIGN.md §9.5): the host pre-draws each
    #: chunk's batch offsets from the same rng stream the serial loop
    #: uses and feeds them through the scan as per-step inputs, so the
    #: fused trajectory equals the serial one exactly.  Bit-identical
    #: to the serial loop for the integer versions.  ``record_every``
    #: still works: chunks are clipped so recording points land on
    #: chunk boundaries.
    fuse_steps: int = 1
    #: chunk pipelining (DESIGN.md §14.1): how many fused chunks may be
    #: in flight before the host drains a boundary (record/eval,
    #: snapshot).  2 = double-buffered — chunk N+1 executes while the
    #: host processes boundary N; 1 = the serial dispatch-drain cadence
    #: (with carry donation).  Bit-identical either way: pipelining
    #: reorders host work only.  Ignored unless ``fuse_steps > 1``.
    pipeline_depth: int = 2


@dataclasses.dataclass
class GdResult:
    w: np.ndarray            # float32 [F]
    b: float
    history: list            # [(iter, metric)] if record_every else []
    n_iters: int = 0

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X, np.float32) @ self.w + self.b


# ---------------------------------------------------------------------------
# Per-core kernels (run on every PIM core over its resident shard).
# ---------------------------------------------------------------------------

def _local_grad_fp32(Xc, yc, mask, w, b):
    pred = Xc @ w + b
    err = (pred - yc) * mask
    return {"gw": Xc.T @ err, "gb": jnp.sum(err)}


def make_local_grad_int32(frac_bits: int, backend=None):
    be = dispatch.resolve_backend(backend)

    def _local(Xq, yq, mask, wq, bq):
        # Q-format matvec through the kernel-dispatch layer (op
        # ``fx_matvec``; bit-identical to fixed_point.fx_dot)
        dot = dispatch.launch("fx_matvec", Xq, wq, frac_bits,
                              backend=be) + bq          # Q(f)
        err = (dot - yq) * mask                         # Q(f)
        prod = err[:, None] * Xq.astype(jnp.int32)      # Q(2f)
        gw = jnp.sum(_shift_round(prod, frac_bits), 0)  # Q(f)
        return {"gw": gw, "gb": jnp.sum(err)}
    return _local


def make_local_grad_hyb(x8_frac: int, w16_frac: int, out_frac: int):
    def _local(Xq8, yq, mask, wq16, bq):
        # 16-bit saturating dot product (the paper's stated precision)
        dot = fx_dot_hybrid(Xq8, wq16, x8_frac, w16_frac, out_frac) + bq
        err = (dot - yq) * mask                          # Q(out_frac) int32
        prod = err[:, None] * Xq8.astype(jnp.int32)      # Q(out+x8)
        gw = jnp.sum(_shift_round(prod, x8_frac), 0)     # Q(out_frac)
        return {"gw": gw, "gb": jnp.sum(err)}
    return _local


# ---------------------------------------------------------------------------
# Host-orchestrated training loop (paper §3.1 flow).
# ---------------------------------------------------------------------------

def _quantize_weights(cfg: GdConfig, w: np.ndarray, b: float):
    if cfg.version == "fp32":
        return jnp.asarray(w), jnp.float32(b)
    if cfg.version == "int32":
        return to_fixed(w, cfg.frac_bits), to_fixed(b, cfg.frac_bits)
    return (to_fixed(w, cfg.w16_frac, dtype=jnp.int16),
            to_fixed(b, cfg.frac_bits))


def make_gd_step_fns(quant_cfg: GdConfig):
    """The (prepare, update) closure pair of one GD step.

    ``prepare(carry) -> (wq, bq)`` quantizes the float32 carry for the
    broadcast; ``update(carry, reduced) -> (carry, None)`` dequantizes
    the reduced gradient and applies ``w -= scale_f32 * gw`` — all jnp
    ops, so ONE definition serves the host-orchestrated per-step loop,
    the fused :class:`~repro.core.pim.StepProgram` scan, and (batched
    over a lane axis) the scheduler's fused gangs; the paths cannot
    drift numerically.  ``quant_cfg`` is the weight-quantization config
    (LOG's LUT versions pass their collapsed int32/hyb base).

    Gradients stay on device: the old loop's per-step
    ``np.asarray``/``jnp.asarray`` ping-pong (ex-``_grad_to_float``) is
    gone — host floats materialize only at record/final points.  The
    update runs in float32 (including the bias, previously a float64
    python scalar) so the fused scan — which cannot do host float64 —
    and the serial loop share bit-exact weight trajectories.

    The carry is ``(w, b, s)``: the f32 update scale ``s`` travels IN
    the carry (constant across steps) because ``mul_round_f32`` needs
    it as a traced value inside the scan — see its caveat.
    """
    f = quant_cfg.frac_bits

    def apply(w, b, s, gw, gb):
        # mul_round_f32 pins the two-rounding (multiply, then subtract)
        # sequence: compiled as one scan body XLA CPU would otherwise
        # contract mul+sub into an FMA and the fused chunk would drift
        # ULPs from the serial loop (see fixed_point.mul_round_f32)
        return w - mul_round_f32(s, gw), b - mul_round_f32(s, gb), s

    if quant_cfg.version == "fp32":
        def prepare(carry):
            return carry[0], carry[1]

        def update(carry, reduced):
            w, b, s = carry
            gw = jnp.asarray(reduced["gw"], jnp.float32)
            gb = jnp.asarray(reduced["gb"], jnp.float32)
            return apply(w, b, s, gw, gb), None
        return prepare, update

    def prepare(carry):
        w, b, _ = carry
        return _quantize_weights(quant_cfg, w, b)

    def update(carry, reduced):
        w, b, s = carry
        # host-strategy reduces arrive as promoted numpy int64;
        # jnp.asarray demotes to int32 exactly as the old host path did
        gw = from_fixed(jnp.asarray(reduced["gw"]), f)
        gb = from_fixed(jnp.asarray(reduced["gb"]), f)
        return apply(w, b, s, gw, gb), None
    return prepare, update


def build_local_grad(cfg: GdConfig) -> Callable:
    """The per-core gradient kernel for ``cfg.version`` (unregistered).

    Exposed separately from the named registration so the scheduler's
    fused gang step can vmap the *same* per-core function over a job
    axis (DESIGN.md §7.3) — fused and serial paths share one kernel
    definition and cannot drift numerically."""
    if cfg.version == "fp32":
        return _local_grad_fp32
    if cfg.version == "int32":
        return make_local_grad_int32(cfg.frac_bits,
                                     dispatch.resolve_backend(
                                         cfg.kernel_backend))
    return make_local_grad_hyb(cfg.x8_frac, cfg.w16_frac, cfg.frac_bits)


def grad_kernel_name(cfg: GdConfig) -> str:
    """Registry name encoding every parameter baked into the kernel."""
    if cfg.version == "fp32":
        return "lin.grad/fp32"
    if cfg.version == "int32":
        be = dispatch.resolve_backend(cfg.kernel_backend)
        return f"lin.grad/int32/f{cfg.frac_bits}/{dispatch.backend_tag(be)}"
    return f"lin.grad/hyb/x{cfg.x8_frac}.w{cfg.w16_frac}.f{cfg.frac_bits}"


def _grad_kernel(pim: System, cfg: GdConfig):
    """Named per-core gradient kernel for the configured version
    (registered once per System; reused across fits and sweeps)."""
    return pim.named_kernel(grad_kernel_name(cfg),
                            lambda: build_local_grad(cfg))


def fit_steps(dataset, cfg: Optional[GdConfig] = None,
              eval_fn: Optional[Callable] = None,
              _local_override: Optional[Callable] = None, *,
              state: Optional[dict] = None):
    """Generator form of the training loop; the GdResult travels on
    StopIteration.  This is the gang-stepping surface the job scheduler
    interleaves (DESIGN.md §7.3); :func:`fit` drains it.

    Each ``next()`` advances one *scheduling step* and yields a
    :class:`~repro.systems.base.ChunkTick` — the number of GD iterations
    it covered (1 per host-orchestrated step, up to ``cfg.fuse_steps``
    per fused :class:`~repro.core.pim.StepProgram` chunk — DESIGN.md
    §9) carrying a lazy chunk-boundary snapshot of the carry.  Passing
    such a snapshot back as ``state`` resumes the fit exactly where it
    was preempted: the carry, the history, and the full minibatch rng
    stream restore, so a resumed integer fit is bit-identical to an
    uninterrupted one (DESIGN.md §11.2)."""
    cfg = cfg or GdConfig()
    assert cfg.version in VERSIONS, cfg.version
    pim = dataset.system
    n, f = dataset.n, dataset.n_features
    Xs, ys, mask = dataset.gd_view(cfg.version, cfg.frac_bits, cfg.x8_frac)

    if _local_override is not None:
        local = _local_override
    else:
        local = _grad_kernel(pim, cfg)

    n_pc = Xs.shape[1]
    minibatch = bool(cfg.minibatch and cfg.minibatch < n_pc)
    # per-shard minibatches: n_shards == n_cores on PIM, 1 on a host
    # target (one resident image draws one batch)
    n_eff = cfg.minibatch * pim.n_shards if minibatch else n
    prepare, update = make_gd_step_fns(cfg)

    w = jnp.zeros(f, jnp.float32)
    b = jnp.float32(0.0)
    s = jnp.float32(cfg.lr * (2.0 / n_eff))
    history = []
    rng = np.random.RandomState(cfg.seed)
    it_done = 0
    if state is not None:
        arrays, meta = state["arrays"], state["meta"]
        w = jnp.asarray(arrays["w"], jnp.float32)
        b = jnp.asarray(arrays["b"], jnp.float32)
        s = jnp.asarray(arrays["s"], jnp.float32)
        it_done = int(meta["iters"])
        history = [tuple(h) for h in meta.get("history", [])]
        rng = unpack_rng(arrays, meta) or rng

    def record(it, wv, bv):
        if cfg.record_every and (it % cfg.record_every == 0
                                 or it == cfg.n_iters):
            metric = eval_fn(np.asarray(wv), float(bv)) if eval_fn else None
            history.append((it, metric))

    def _make_snapshot(wv, bv, sv, it, ra, rm):
        """Snapshot closure bound to ONE chunk boundary's state.  Under
        pipelining the live carry has already been dispatched past this
        boundary by drain time, so everything the snapshot serializes is
        captured per boundary (the rng pack eagerly at dispatch — the
        stream advances with the next chunk's draws)."""
        def _snap():
            arrays = {"w": np.asarray(wv, np.float32),
                      "b": np.asarray(bv, np.float32),
                      "s": np.asarray(sv, np.float32)}
            meta = {"iters": int(it),
                    "history": [[int(i), None if m is None else float(m)]
                                for i, m in history]}
            arrays.update(ra)
            meta.update(rm)
            return {"arrays": arrays, "meta": meta}
        return _snap

    def _snapshot():
        ra, rm = pack_rng(rng)
        return _make_snapshot(w, b, s, it_done, ra, rm)()

    if cfg.fuse_steps > 1:
        select = None
        if minibatch:
            # minibatch SGD fuses too (DESIGN.md §9.5): the select hook
            # slices every shard to the step's batch window; the
            # offsets arrive as scan xs, pre-drawn per chunk below from
            # the SAME rng stream the serial loop consumes — the fused
            # trajectory is the serial one, bit for bit
            mb = cfg.minibatch

            def select(shards, off):
                return tuple(
                    jax.lax.dynamic_slice_in_dim(a, off, mb, axis=1)
                    for a in shards)
        program = pim.step_program(
            local, prepare, update,
            name=(f"lin.step/{grad_kernel_name(cfg)}"
                  f"/lr{cfg.lr}/n{n_eff}"
                  + (f"/mb{cfg.minibatch}" if minibatch else "")),
            select=select)
        # Double-buffered chunk pipeline (DESIGN.md §14.1): dispatch
        # chunk N+1, then drain boundary N — record/eval and the
        # snapshot closure read the boundary's own carry while the next
        # chunk executes.  The only host reads are on drained
        # boundaries, so the device never waits on record work.
        pipe = ChunkPipeline(program, max(1, int(cfg.pipeline_depth)))

        def _drain(bnd):
            nonlocal it_done
            it_done, ra, rm = bnd.tag
            bw, bb, bs = bnd.carry
            record(it_done, bw, bb)
            return ChunkTick(bnd.k, _make_snapshot(bw, bb, bs, it_done,
                                                   ra, rm))

        # resume replays identical chunk boundaries: chunk_schedule is a
        # deterministic function of the iteration index (DESIGN.md §11.2)
        it_disp = it_done
        for k in chunk_schedule(cfg.n_iters, cfg.fuse_steps,
                                cfg.record_every, start=it_done):
            xs = None
            if minibatch:
                xs = jnp.asarray(
                    [rng.randint(0, n_pc - cfg.minibatch + 1)
                     for _ in range(k)], jnp.int32)
            it_disp += k
            # rng packed AFTER this chunk's draws: restoring boundary N
            # replays chunk N+1's batch offsets bit-exactly
            (w, b, s), drained = pipe.dispatch(
                (w, b, s), (Xs, ys, mask), k, xs=xs,
                tag=(it_disp, *pack_rng(rng)))
            for bnd in drained:
                yield _drain(bnd)
        for bnd in pipe.flush():
            yield _drain(bnd)
    else:
        for it in range(it_done, cfg.n_iters):
            wq, bq = pim.broadcast(prepare((w, b, s)))
            if minibatch:
                # SGD: every core samples the same per-core slice offset
                # (keeps shards aligned; bank-resident data never moves)
                start = int(rng.randint(0, n_pc - cfg.minibatch + 1))
                sl = (slice(None), slice(start, start + cfg.minibatch))
                args = (Xs[sl], ys[sl], mask[sl])
            else:
                args = (Xs, ys, mask)
            partial = pim.map_reduce(local, args, (wq, bq))
            (w, b, s), _ = update((w, b, s), partial)
            it_done = it + 1
            record(it_done, w, b)
            yield ChunkTick(1, _snapshot)
    return GdResult(w=np.asarray(w, np.float32), b=float(b),
                    history=history, n_iters=cfg.n_iters)


def fit(dataset, cfg: Optional[GdConfig] = None,
        eval_fn: Optional[Callable] = None,
        _local_override: Optional[Callable] = None) -> GdResult:
    """Full PIM training loop over a bank-resident PimDataset: iterate
    (kernel -> reduce -> host update -> broadcast) until cfg.n_iters.
    The dataset's quantized view is materialized at most once per
    (version, Q-format) — repeated fits reuse the resident shards."""
    return run_steps(fit_steps(dataset, cfg, eval_fn, _local_override))


def train(X: np.ndarray, y: np.ndarray, pim: System,
          cfg: Optional[GdConfig] = None,
          eval_fn: Optional[Callable] = None,
          _local_override: Optional[Callable] = None) -> GdResult:
    """Deprecated shim: re-partitions (X, y) on every call.  Prefer
    ``fit(pim.put(X, y), cfg)`` which keeps the shards bank-resident
    across fits (repro.api)."""
    warnings.warn("linreg.train(X, y, pim, ...) is deprecated; use "
                  "linreg.fit(pim.put(X, y), cfg)", DeprecationWarning,
                  stacklevel=2)
    from ..api.dataset import as_dataset
    return fit(as_dataset(X, y, pim), cfg, eval_fn, _local_override)

# The CPU comparison point (paper §5.4) is no longer an ad-hoc numpy
# loop here: run this same workload on repro.systems.HostSystem — the
# processor-centric System target — e.g.
# ``linreg.fit(make_system("host").put(X, y), GdConfig("fp32"))``.
