"""Logistic regression with gradient descent on the PIM system (paper §3.2).

Six versions, exactly the paper's ladder:
  LOG-FP32            float32 + Taylor-series sigmoid (DPUs lack exp)
  LOG-INT32           Q(frac_bits) fixed point + fixed-point Taylor sigmoid
  LOG-INT32-LUT(MRAM) fixed point + LUT sigmoid, LUT resident in DRAM bank
  LOG-INT32-LUT(WRAM) fixed point + LUT sigmoid, LUT in the scratchpad
  LOG-HYB-LUT         8-bit inputs x 16-bit weights + WRAM LUT
  LOG-BUI-LUT         LOG-HYB-LUT numerics + built-in multiply (cost model)

The MRAM/WRAM variants are numerically identical (same table); they differ
in *placement*, which on the DPU is a ~3% effect (§5.2.2) and on TPU maps
to HBM-gather vs VMEM-resident LUT (kernels/lut_activation).  Here the
functional semantics are shared; the placement flag routes the cost model
and (on TPU) kernel selection.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch
from ..systems import (ChunkPipeline, ChunkTick, System, chunk_schedule,
                       run_steps)
from .fixed_point import _shift_round, fx_dot_hybrid
from .linreg import GdConfig, GdResult, make_gd_step_fns
from .lut import SigmoidLut, build_sigmoid_lut, taylor_sigmoid_fixed

VERSIONS = ("fp32", "int32", "int32_lut_mram", "int32_lut_wram",
            "hyb_lut", "bui_lut")


@dataclasses.dataclass
class LogRegConfig(GdConfig):
    version: str = "fp32"
    lr: float = 5.0              # logistic loss needs larger steps (flat
                                 # gradients; validated in quality tests)
    taylor_terms: int = 8
    lut_boundary: int = 20       # paper Fig. 4: boundary 20, 10 frac bits
    lut_frac_bits: int = 10


def _sigmoid_taylor_f32(z: jnp.ndarray, terms: int) -> jnp.ndarray:
    """Float Taylor sigmoid — the paper's LOG-FP32 path on DPUs.

    exp(-|z|) via range-reduced Taylor (m=3 halvings), then reflect.
    """
    a = jnp.minimum(jnp.abs(z), 20.0)
    t = a / 8.0
    acc = jnp.ones_like(t)
    for k in range(terms - 1, 0, -1):
        acc = 1.0 - acc * t / k
    e = acc ** 8  # (exp(-t))**8 = exp(-a)
    pos = 1.0 / (1.0 + e)
    return jnp.where(z < 0, 1.0 - pos, pos)


def _gd_version_of(version: str) -> str:
    return {"fp32": "fp32", "int32": "int32", "int32_lut_mram": "int32",
            "int32_lut_wram": "int32", "hyb_lut": "hyb",
            "bui_lut": "bui"}[version]


def make_local_grad(cfg: LogRegConfig, lut: Optional[SigmoidLut],
                    exact_sigmoid: bool = False):
    """Build the per-core kernel for the configured version.

    The two kernel-dispatch hooks (repro.kernels.dispatch):

      * the INT32 Q-format matvec routes through op ``fx_matvec``;
      * the LUT sigmoid routes through op ``lut_sigmoid`` — but the
        paper's MRAM variant *is* the HBM-gather ref path, so
        ``int32_lut_mram`` pins ``jnp_ref`` while the WRAM/HYB/BUI
        variants follow the configured backend (VMEM kernel on TPU).

    ``exact_sigmoid`` selects the native-transcendental fp32 sigmoid a
    processor-centric :class:`~repro.systems.base.System` provides (the
    paper's MKL baseline, §5.4) instead of the DPU Taylor expansion; it
    only applies to the fp32 version.
    """
    f = cfg.frac_bits
    be = dispatch.resolve_backend(cfg.kernel_backend)
    # MRAM placement == HBM gather == the ref path, by definition
    lut_be = (dispatch.KernelBackend.JNP_REF
              if cfg.version == "int32_lut_mram" else be)

    if cfg.version == "fp32":
        terms = cfg.taylor_terms

        def _local_fp32(Xc, yc, mask, w, b):
            z = Xc @ w + b
            p = (jax.nn.sigmoid(z) if exact_sigmoid
                 else _sigmoid_taylor_f32(z, terms))
            err = (p - yc) * mask
            return {"gw": Xc.T @ err, "gb": jnp.sum(err)}
        return _local_fp32

    if cfg.version == "int32":
        terms = cfg.taylor_terms

        def _local_int32_taylor(Xq, yq, mask, wq, bq):
            z = dispatch.launch("fx_matvec", Xq, wq, f,
                                backend=be) + bq          # Q(f)
            p = taylor_sigmoid_fixed(z, f, terms=terms)   # Q(f)
            err = (p - yq) * mask
            prod = err[:, None] * Xq.astype(jnp.int32)
            gw = jnp.sum(_shift_round(prod, f), 0)
            return {"gw": gw, "gb": jnp.sum(err)}
        return _local_int32_taylor

    if cfg.version in ("int32_lut_mram", "int32_lut_wram"):
        assert lut is not None

        def _local_int32_lut(Xq, yq, mask, wq, bq):
            z = dispatch.launch("fx_matvec", Xq, wq, f,
                                backend=be) + bq          # Q(f)
            p15 = dispatch.launch("lut_sigmoid", z, lut,
                                  backend=lut_be)         # Q(value_frac)
            p = _shift_round(p15, lut.value_frac - f)     # -> Q(f)
            err = (p - yq) * mask
            prod = err[:, None] * Xq.astype(jnp.int32)
            gw = jnp.sum(_shift_round(prod, f), 0)
            return {"gw": gw, "gb": jnp.sum(err)}
        return _local_int32_lut

    # hyb_lut / bui_lut — identical numerics (paper §3.1/§3.2); the
    # saturating 16-bit dot stays inline (sequential clip semantic —
    # DESIGN.md §6.3), the sigmoid is dispatch-routed
    assert lut is not None
    x8, w16 = cfg.x8_frac, cfg.w16_frac

    def _local_hyb_lut(Xq8, yq, mask, wq16, bq):
        z = fx_dot_hybrid(Xq8, wq16, x8, w16, f) + bq     # Q(f), 16-bit acc
        p15 = dispatch.launch("lut_sigmoid", z, lut, backend=lut_be)
        p = _shift_round(p15, lut.value_frac - f)
        err = (p - yq) * mask
        prod = err[:, None] * Xq8.astype(jnp.int32)
        gw = jnp.sum(_shift_round(prod, x8), 0)
        return {"gw": gw, "gb": jnp.sum(err)}
    return _local_hyb_lut


def build_local_grad(cfg: LogRegConfig,
                     exact_sigmoid: bool = False) -> Callable:
    """Per-core kernel for ``cfg.version`` with its LUT built in
    (unregistered) — shared by the serial trainer and the scheduler's
    fused gang step (DESIGN.md §7.3)."""
    lut = (build_sigmoid_lut(cfg.lut_boundary, cfg.lut_frac_bits)
           if "lut" in cfg.version else None)
    return make_local_grad(cfg, lut, exact_sigmoid)


def _exact_sigmoid(system: System, cfg: LogRegConfig) -> bool:
    """fp32 on a processor-centric target uses the exact sigmoid (the
    paper's MKL/cuML baselines); every other combination keeps the
    paper's DPU Taylor expansion."""
    return cfg.version == "fp32" and system.exact_transcendentals


def grad_kernel_name(cfg: LogRegConfig, exact_sigmoid: bool = False) -> str:
    """Registry name encoding every parameter baked into the closure
    (version, Q formats, Taylor terms, LUT geometry, sigmoid flavor) so
    the compiled kernel is reused across fits and never served stale."""
    return (f"log.grad/{cfg.version}"
            + ("x" if exact_sigmoid else "")
            + f"/f{cfg.frac_bits}"
            f".x{cfg.x8_frac}.w{cfg.w16_frac}"
            f".t{cfg.taylor_terms}"
            f".lb{cfg.lut_boundary}.lf{cfg.lut_frac_bits}"
            f"/{dispatch.backend_tag(cfg.kernel_backend)}")


def _grad_kernel(pim: System, cfg: LogRegConfig) -> str:
    """Named per-core kernel.  The sigmoid LUT is built inside the
    builder — pay-once like the kernel, not per fit."""
    exact = _exact_sigmoid(pim, cfg)
    return pim.named_kernel(grad_kernel_name(cfg, exact),
                            lambda: build_local_grad(cfg, exact))


def fit_steps(dataset, cfg: Optional[LogRegConfig] = None,
              eval_fn: Optional[Callable] = None, *,
              state: Optional[dict] = None):
    """Generator form of the LOG loop (GdResult on StopIteration) — the
    gang-stepping surface; :func:`fit` drains it.  Each ``next()``
    yields a :class:`~repro.systems.base.ChunkTick`: the number of GD
    iterations it advanced (1 per host-orchestrated step, up to
    ``cfg.fuse_steps`` per fused :class:`~repro.core.pim.StepProgram`
    chunk — DESIGN.md §9) with a lazy carry snapshot; pass a snapshot
    back as ``state`` to resume bit-exactly at that chunk boundary
    (DESIGN.md §11.2)."""
    cfg = cfg or LogRegConfig()
    assert cfg.version in VERSIONS, cfg.version
    pim = dataset.system
    n, nf = dataset.n, dataset.n_features

    # reuse linreg's weight quantization via the base data version
    base_cfg = dataclasses.replace(cfg, version=_gd_version_of(cfg.version))
    Xs, ys, mask = dataset.gd_view(cfg.version, cfg.frac_bits, cfg.x8_frac)
    local = _grad_kernel(pim, cfg)
    prepare, update = make_gd_step_fns(base_cfg)

    w = jnp.zeros(nf, jnp.float32)
    b = jnp.float32(0.0)
    s = jnp.float32(cfg.lr * (1.0 / n))
    history = []
    it_done = 0
    if state is not None:
        arrays, meta = state["arrays"], state["meta"]
        w = jnp.asarray(arrays["w"], jnp.float32)
        b = jnp.asarray(arrays["b"], jnp.float32)
        s = jnp.asarray(arrays["s"], jnp.float32)
        it_done = int(meta["iters"])
        history = [tuple(h) for h in meta.get("history", [])]

    def record(it, wv, bv):
        if cfg.record_every and (it % cfg.record_every == 0
                                 or it == cfg.n_iters):
            metric = eval_fn(np.asarray(wv), float(bv)) if eval_fn else None
            history.append((it, metric))

    def _make_snapshot(wv, bv, sv, it):
        """Snapshot closure bound to one chunk boundary's carry (the
        live carry races ahead of drained boundaries when pipelined —
        DESIGN.md §14.1)."""
        def _snap():
            return {"arrays": {"w": np.asarray(wv, np.float32),
                               "b": np.asarray(bv, np.float32),
                               "s": np.asarray(sv, np.float32)},
                    "meta": {"iters": int(it),
                             "history": [[int(i),
                                          None if m is None else float(m)]
                                         for i, m in history]}}
        return _snap

    def _snapshot():
        return _make_snapshot(w, b, s, it_done)()

    if cfg.fuse_steps > 1:
        program = pim.step_program(
            local, prepare, update,
            name=(f"log.step/{grad_kernel_name(cfg, _exact_sigmoid(pim, cfg))}"
                  f"/lr{cfg.lr}/n{n}"))
        # double-buffered chunk pipeline — see linreg.fit_steps
        pipe = ChunkPipeline(program, max(1, int(cfg.pipeline_depth)))

        def _drain(bnd):
            nonlocal it_done
            it_done = bnd.tag
            bw, bb, bs = bnd.carry
            record(it_done, bw, bb)
            return ChunkTick(bnd.k, _make_snapshot(bw, bb, bs, it_done))

        it_disp = it_done
        for k in chunk_schedule(cfg.n_iters, cfg.fuse_steps,
                                cfg.record_every, start=it_done):
            it_disp += k
            (w, b, s), drained = pipe.dispatch((w, b, s), (Xs, ys, mask),
                                               k, tag=it_disp)
            for bnd in drained:
                yield _drain(bnd)
        for bnd in pipe.flush():
            yield _drain(bnd)
    else:
        for it in range(it_done, cfg.n_iters):
            wq, bq = pim.broadcast(prepare((w, b, s)))
            partial = pim.map_reduce(local, (Xs, ys, mask), (wq, bq))
            (w, b, s), _ = update((w, b, s), partial)
            it_done = it + 1
            record(it_done, w, b)
            yield ChunkTick(1, _snapshot)
    return GdResult(w=np.asarray(w, np.float32), b=float(b),
                    history=history, n_iters=cfg.n_iters)


def fit(dataset, cfg: Optional[LogRegConfig] = None,
        eval_fn: Optional[Callable] = None) -> GdResult:
    """LOG training over a bank-resident PimDataset.  The data view is
    shared with LIN (same precision ladder), so a LIN fit followed by a
    LOG fit on one dataset still transfers the shards once."""
    return run_steps(fit_steps(dataset, cfg, eval_fn))


def train(X: np.ndarray, y: np.ndarray, pim: System,
          cfg: Optional[LogRegConfig] = None,
          eval_fn: Optional[Callable] = None) -> GdResult:
    """Deprecated shim: re-partitions (X, y) on every call.  Prefer
    ``fit(pim.put(X, y), cfg)`` (repro.api)."""
    warnings.warn("logreg.train(X, y, pim, ...) is deprecated; use "
                  "logreg.fit(pim.put(X, y), cfg)", DeprecationWarning,
                  stacklevel=2)
    from ..api.dataset import as_dataset
    return fit(as_dataset(X, y, pim), cfg, eval_fn)

# The CPU comparison point (float32, *exact* sigmoid — MKL-style) is no
# longer an ad-hoc numpy loop here: fp32 on repro.systems.HostSystem
# selects the exact sigmoid automatically (``exact_transcendentals``),
# e.g. ``logreg.fit(make_system("host").put(X, y), LogRegConfig("fp32"))``.
