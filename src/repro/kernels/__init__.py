"""Kernel tier: the Pallas realizations of the paper's physical-layout
tricks, behind a swappable backend-dispatch layer (DESIGN.md §6).

Layout per family: ``kernel.py`` (Pallas TPU kernel), ``ref.py``
(pure-jnp oracle), ``ops.py`` (public wrapper + dispatch registration).
``pallas_compat.py`` resolves drifted Pallas APIs once for every
family; ``dispatch.py`` is the uniform ``launch(op, *args,
backend=...)`` entry with per-platform auto-selection and ref fallback.
"""
from .dispatch import (BACKEND_ENV_VAR, KernelBackend, available_ops,
                       backend_tag, default_backend, launch,
                       resolve_backend)

__all__ = ["BACKEND_ENV_VAR", "KernelBackend", "available_ops",
           "backend_tag", "default_backend", "launch", "resolve_backend"]
