"""Pallas TPU kernel: int8 x int8 -> int32 tiled matmul with dequant.

TPU adaptation of the paper's hybrid-precision multiply (LIN-HYB / LIN-BUI,
Listing 1): where the DPU replaces emulated 32-bit multiplies with native
8-bit built-ins, the TPU's native fast path is the MXU int8 systolic pass
with int32 accumulation.  Tiling: (bm x bk) x (bk x bn) blocks staged
HBM->VMEM by the BlockSpec machinery, int32 accumulator held in a VMEM
scratch across the K grid dimension.

Block shapes default to MXU-aligned (128, 128, 128); int8 operands allow
(32, 128)-packed tiles, so bk=256 is also profitable on real hardware.
Validated with interpret=True on CPU (see tests/test_kernels_quant_matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int_matmul(a_q: jnp.ndarray, b_q: jnp.ndarray, *, bm: int = 128,
               bn: int = 128, bk: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """int8[M,K] @ int8[K,N] -> int32[M,N] via pl.pallas_call."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    n_k = k // bk

    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_q, b_q)
