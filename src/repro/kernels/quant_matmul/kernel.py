"""Pallas TPU kernel: int8 x int8 -> int32 tiled matmul with dequant.

TPU adaptation of the paper's hybrid-precision multiply (LIN-HYB / LIN-BUI,
Listing 1): where the DPU replaces emulated 32-bit multiplies with native
8-bit built-ins, the TPU's native fast path is the MXU int8 systolic pass
with int32 accumulation.  Tiling: (bm x bk) x (bk x bn) blocks staged
HBM->VMEM by the BlockSpec machinery, int32 accumulator held in a VMEM
scratch across the K grid dimension.

Block shapes default to MXU-aligned (128, 128, 128); int8 operands allow
(32, 128)-packed tiles, so bk=256 is also profitable on real hardware.
Validated with interpret=True on CPU (see tests/test_kernels_quant_matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..pallas_compat import pallas_call, pl, vmem_scratch


def _quant_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int_matmul(a_q: jnp.ndarray, b_q: jnp.ndarray, *, bm: int = 128,
               bn: int = 128, bk: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """int8[M,K] @ int8[K,N] -> int32[M,N] via pl.pallas_call."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    n_k = k // bk

    grid = (m // bm, n // bn, n_k)
    return pallas_call(
        functools.partial(_quant_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[vmem_scratch((bm, bn), jnp.int32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(a_q, b_q)


def _fx_matvec_kernel(x_ref, w_ref, o_ref, *, frac_bits: int):
    x = x_ref[...].astype(jnp.int32)                 # (bn, F)
    w = w_ref[...].astype(jnp.int32)                 # (1, F)
    prod = x * w                                     # Q(2f)
    if frac_bits:
        prod = (prod + (1 << (frac_bits - 1))) >> frac_bits
    o_ref[...] = jnp.sum(prod, axis=1)               # (bn,) Q(f)


@functools.partial(jax.jit, static_argnames=("frac_bits", "block_n",
                                             "interpret"))
def fx_matvec(x_q: jnp.ndarray, w_q: jnp.ndarray, *, frac_bits: int,
              block_n: int = 1024, interpret: bool = False) -> jnp.ndarray:
    """Q-format row-dot: int32[N, F] x int32[F] -> int32[N], each product
    shifted back to Q(frac_bits) with round-to-nearest BEFORE accumulation
    (the paper's 32-bit DPU dot-product ordering; bit-identical to
    ``fixed_point.fx_dot``).  VPU work: rows stream through the grid, the
    weight vector stays pinned — the kernel-tier path of the LIN/LOG
    INT32 versions' matmul."""
    n, f = x_q.shape
    assert w_q.shape == (f,), (x_q.shape, w_q.shape)
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pallas_call(
        functools.partial(_fx_matvec_kernel, frac_bits=frac_bits),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),  # weights pinned
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
    )(x_q, w_q.reshape(1, f))
