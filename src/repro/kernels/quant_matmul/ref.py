"""Pure-jnp oracle for the quantized matmul kernel.

Semantics: C = (A_q @ B_q) * (a_scale * b_scale), accumulated in int32 —
the TPU-native analogue of the paper's hybrid-precision dot product
(LIN-HYB/LIN-BUI: 8-bit multiplies feeding wider accumulators).
"""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(a_q: jnp.ndarray, b_q: jnp.ndarray,
                     a_scale: jnp.ndarray, b_scale: jnp.ndarray,
                     out_dtype=jnp.float32) -> jnp.ndarray:
    """a_q: int8 [M, K]; b_q: int8 [K, N];
    a_scale: [] or [M, 1]; b_scale: [] or [1, N] (per-channel)."""
    acc = jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)


def int_matmul_ref(a_q: jnp.ndarray, b_q: jnp.ndarray) -> jnp.ndarray:
    """Raw int32 accumulator (no dequant), for exactness tests."""
    return jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32),
                   preferred_element_type=jnp.int32)
