"""jit'd public wrappers around the quantized matmul kernel.

``quant_matmul``   : dequantizing int8 matmul (kernel or XLA ref path)
``quant_dense``    : float-in/float-out PIM-style dense layer — quantizes
                     activations on the fly (per-tensor) against int8
                     weights (per-output-channel scales), the direct
                     TPU analogue of LIN-HYB feeding an LM linear layer.

``use_pallas=False`` routes to the pure-jnp oracle; that path is what the
multi-pod dry-run lowers (Mosaic kernels only lower for real TPU targets —
DESIGN.md §6), and XLA fuses it into a single int8 MXU matmul on TPU anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantization import symmetric_quantize
from .kernel import int_matmul
from .ref import int_matmul_ref, quant_matmul_ref


def quant_matmul(a_q, b_q, a_scale, b_scale, *, use_pallas: bool = True,
                 interpret: bool = True, out_dtype=jnp.float32):
    if use_pallas:
        acc = int_matmul(a_q, b_q, interpret=interpret)
    else:
        acc = int_matmul_ref(a_q, b_q)
    return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_dense(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                *, use_pallas: bool = False,
                interpret: bool = True) -> jnp.ndarray:
    """x: float [..., K]; w_q: int8 [K, N]; w_scale: [1, N] per-channel.

    Activations are quantized per-tensor on the fly (symmetric), matmul'd
    in int8 -> int32, and dequantized — matching the paper's quantize-the-
    dataset-once + integer-kernel flow, applied per layer.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    x_q, xp = symmetric_quantize(x2, bits=8)
    out = quant_matmul(x_q, w_q, xp.scale, w_scale,
                       use_pallas=use_pallas, interpret=interpret)
    return out.reshape(*lead, -1).astype(x.dtype)
