"""Dispatchable wrappers around the quantized matmul kernel family.

Ops (registered with :mod:`repro.kernels.dispatch`):

``quant_matmul`` : dequantizing int8 matmul (kernel or XLA ref path)
``int_matmul``   : raw int8 x int8 -> int32 accumulator
``fx_matvec``    : Q-format row-dot with pre-accumulation rounding —
                   the kernel-tier path of the LIN/LOG INT32 versions'
                   matmul (bit-identical to ``fixed_point.fx_dot``)
``quant_dense``  : float-in/float-out PIM-style dense layer — quantizes
                   activations on the fly (per-tensor) against int8
                   weights (per-output-channel scales), the direct
                   TPU analogue of LIN-HYB feeding an LM linear layer.

The ``jnp_ref`` backend routes to the pure-jnp oracles; that path is
what the multi-pod dry-run lowers (Mosaic kernels only lower for real
TPU targets — DESIGN.md §6), and XLA fuses it into a single int8 MXU
matmul on TPU anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantization import symmetric_quantize
from ..dispatch import legacy_launch, register_op
from .kernel import fx_matvec as _fx_matvec_kernel
from .kernel import int_matmul
from .ref import int_matmul_ref, quant_matmul_ref


def quant_matmul(a_q, b_q, a_scale, b_scale, *, backend=None,
                 use_pallas: bool = None, interpret: bool = None,
                 out_dtype=jnp.float32):
    """Dequantizing int8 matmul.  ``backend`` None = auto-select
    (``jnp_ref`` off-TPU; the old default was the interpret kernel —
    pass ``use_pallas=True`` explicitly to force the kernel path)."""
    return legacy_launch("quant_matmul", a_q, b_q, a_scale, b_scale,
                         backend=backend, use_pallas=use_pallas,
                         interpret=interpret, out_dtype=out_dtype)


def fx_matvec(x_q, w_q, frac_bits: int, *, backend=None,
              use_pallas: bool = None, interpret: bool = None,
              block_n: int = 1024):
    """Q(f)[N, F] . Q(f)[F] -> Q(f)[N] with per-product rounding."""
    return legacy_launch("fx_matvec", x_q, w_q, frac_bits,
                         backend=backend, use_pallas=use_pallas,
                         interpret=interpret, block_n=block_n)


def _fx_matvec_ref(x_q, w_q, frac_bits: int, *, block_n: int = 1024):
    from repro.core.fixed_point import fx_dot
    del block_n  # jnp oracle needs no tiling
    return fx_dot(x_q, w_q, frac_bits)


def _fx_matvec_pallas(x_q, w_q, frac_bits: int, *, interpret: bool = True,
                      block_n: int = 1024):
    n = x_q.shape[0]
    bn = min(block_n, max(n, 8))
    n_pad = -(-n // bn) * bn
    if n_pad != n:  # ragged tail: zero rows dot to zero, slice them off
        x_q = jnp.zeros((n_pad, x_q.shape[1]),
                        x_q.dtype).at[:n].set(x_q)
    out = _fx_matvec_kernel(x_q, w_q, frac_bits=frac_bits, block_n=bn,
                            interpret=interpret)
    return out[:n]


def _int_matmul_ref_op(a_q, b_q, *, bm=128, bn=128, bk=128):
    del bm, bn, bk  # jnp oracle needs no tiling
    return int_matmul_ref(a_q, b_q)


def _int_matmul_pallas(a_q, b_q, *, interpret: bool = True, bm=128,
                       bn=128, bk=128):
    return int_matmul(a_q, b_q, bm=bm, bn=bn, bk=bk, interpret=interpret)


def _quant_matmul_pallas(a_q, b_q, a_scale, b_scale, *,
                         interpret: bool = True, out_dtype=jnp.float32):
    acc = int_matmul(a_q, b_q, interpret=interpret)
    return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("backend", "use_pallas",
                                             "interpret"))
def quant_dense(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                *, backend=None, use_pallas: bool = None,
                interpret: bool = None) -> jnp.ndarray:
    """x: float [..., K]; w_q: int8 [K, N]; w_scale: [1, N] per-channel.

    Activations are quantized per-tensor on the fly (symmetric), matmul'd
    in int8 -> int32, and dequantized — matching the paper's quantize-the-
    dataset-once + integer-kernel flow, applied per layer.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    x_q, xp = symmetric_quantize(x2, bits=8)
    out = quant_matmul(x_q, w_q, xp.scale, w_scale, backend=backend,
                       use_pallas=use_pallas, interpret=interpret)
    return out.reshape(*lead, -1).astype(x.dtype)


register_op("int_matmul", family="quant_matmul",
            pallas=_int_matmul_pallas, ref=_int_matmul_ref_op)
register_op("quant_matmul", family="quant_matmul",
            pallas=_quant_matmul_pallas, ref=quant_matmul_ref)
register_op("fx_matvec", family="quant_matmul",
            pallas=_fx_matvec_pallas, ref=_fx_matvec_ref)
