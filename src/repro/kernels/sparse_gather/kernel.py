"""Pallas kernels: sparse embedding gather and scatter-add.

TPU adaptation of the DPU-side sparse row access the EMB workload needs
(DESIGN.md §15): the irregular MRAM row lookup becomes a one-hot matmul
against the shard's placement-map id vector, which the MXU/VPU executes
as dense math — the same trick the kmeans_assign family uses for argmin.
The formulation is shared verbatim with ``ref.py`` so both backends
reduce in the same order (bit-exactness is asserted per dtype by
tests/test_emb.py, including adversarial duplicate-index patterns).

Grid layout:

* ``emb_gather``: lookups stream through the grid in ``block_b`` rows;
  the shard's table and id vector stay pinned (every block needs every
  row — the table IS the working set, exactly the paper's memory-bound
  regime).
* ``emb_scatter_add``: table rows stream through the grid in
  ``block_r`` rows; the batch (idx + update rows) stays pinned and each
  row block absorbs its whole update mass in ONE dot over the full
  batch axis — no cross-grid accumulation, so duplicate indices are
  handled inside a single exact reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..pallas_compat import pallas_call, pl


def _dot(onehot, rows):
    return jax.lax.dot_general(
        onehot, rows, (((1,), (0,)), ((), ())),
        preferred_element_type=rows.dtype)


def _gather_kernel(tab_ref, ids_ref, idx_ref, o_ref):
    tab = tab_ref[...]                                # (R, D) pinned
    ids = ids_ref[...]                                # (1, R) pinned
    idx = idx_ref[...]                                # (bB, 1)
    onehot = (idx == ids).astype(tab.dtype)           # (bB, R)
    o_ref[...] = _dot(onehot, tab)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def emb_gather(table: jnp.ndarray, ids: jnp.ndarray, idx: jnp.ndarray,
               *, block_b: int = 256,
               interpret: bool = False) -> jnp.ndarray:
    """[R, D] table + int32 [R] ids, looked up by int32 [B] idx -> [B, D]."""
    r, d = table.shape
    (b,) = idx.shape
    bb = min(block_b, b)
    assert b % bb == 0, (b, bb)
    return pallas_call(
        _gather_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((r, d), lambda i: (0, 0)),   # table pinned
            pl.BlockSpec((1, r), lambda i: (0, 0)),   # ids pinned
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
    )(table, ids.reshape(1, r), idx.reshape(b, 1))


def _scatter_kernel(tab_ref, ids_ref, idx_ref, upd_ref, o_ref):
    tab = tab_ref[...]                                # (bR, D)
    ids = ids_ref[...]                                # (bR, 1)
    idx = idx_ref[...]                                # (1, B) pinned
    upd = upd_ref[...]                                # (B, D) pinned
    onehot = (ids == idx).astype(tab.dtype)           # (bR, B)
    o_ref[...] = tab + _dot(onehot, upd.astype(tab.dtype))


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def emb_scatter_add(table: jnp.ndarray, ids: jnp.ndarray,
                    idx: jnp.ndarray, upd: jnp.ndarray, *,
                    block_r: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """Segment-sum ``upd`` rows [B, D] into [R, D] table slots keyed by
    global id match; duplicate idx entries accumulate."""
    r, d = table.shape
    (b,) = idx.shape
    assert upd.shape == (b, d), (upd.shape, (b, d))
    br = min(block_r, r)
    assert r % br == 0, (r, br)
    return pallas_call(
        _scatter_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),   # batch ids pinned
            pl.BlockSpec((b, d), lambda i: (0, 0)),   # updates pinned
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), table.dtype),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
    )(table, ids.reshape(r, 1), idx.reshape(1, b), upd)
