"""Pure-jnp oracles for the sparse gather / scatter-add family.

Both ops run against a *shard* of a row-sharded embedding table: the
shard holds rows ``table[r]`` whose global row ids are ``ids[r]``
(``ROW_PAD_ID`` marks padding slots past the vocabulary tail).  Lookups
arrive as global ids ``idx[b]``; a shard answers with zeros for rows it
does not own, so summing the per-shard partials across cores (the
fabric reduce) reconstructs the full gathered rows.

The one-hot matmul formulation is the load-bearing choice:

* ``gather``: each one-hot row has at most one 1 (ids are unique within
  a shard), so the "sum" is a pure selection — exact in every dtype.
* ``scatter_add``: duplicate batch indices land in the SAME one-hot row
  and are summed by a single ``dot_general`` over the whole batch axis,
  i.e. a segment-sum — duplicate-safe with one fixed reduction order
  shared by the Pallas kernel, so ref and kernel stay bit-exact.

``preferred_element_type`` pins the accumulator to the table dtype:
int32 tables accumulate exactly in int32 (the Q-format fixed-point
path); float tables accumulate in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: global-id sentinel for padded table slots (vocab tail rounded up to
#: the shard grid); never matches a real lookup id (those are >= 0).
ROW_PAD_ID = -1
#: lookup-id sentinel for padded batch slots (ragged batch tails);
#: distinct from ROW_PAD_ID so padded lookups cannot hit padded rows.
IDX_PAD = -2


def _onehot_dot(onehot, rows):
    return jax.lax.dot_general(
        onehot, rows, (((1,), (0,)), ((), ())),
        preferred_element_type=rows.dtype)


def emb_gather_ref(table: jnp.ndarray, ids: jnp.ndarray,
                   idx: jnp.ndarray) -> jnp.ndarray:
    """table: [R, D]; ids: int32 [R]; idx: int32 [B] -> [B, D].

    ``out[b] = table[r]`` where ``ids[r] == idx[b]``, else zeros (the
    row lives on another shard, or ``idx[b]`` is an ``IDX_PAD``)."""
    onehot = (idx[:, None] == ids[None, :]).astype(table.dtype)  # (B, R)
    return _onehot_dot(onehot, table)


def emb_scatter_add_ref(table: jnp.ndarray, ids: jnp.ndarray,
                        idx: jnp.ndarray,
                        upd: jnp.ndarray) -> jnp.ndarray:
    """table: [R, D]; ids: int32 [R]; idx: int32 [B]; upd: [B, D]
    -> [R, D] with ``out[r] = table[r] + sum_b [ids[r]==idx[b]] upd[b]``
    (duplicate indices sum — segment-sum semantics)."""
    onehot = (ids[:, None] == idx[None, :]).astype(table.dtype)  # (R, B)
    return table + _onehot_dot(onehot, upd.astype(table.dtype))
