"""Sparse embedding gather / scatter-add kernel family (DESIGN.md §15).

Ops: ``emb_gather`` (row lookup against a shard's placement map) and
``emb_scatter_add`` (duplicate-index-safe batched row update).  Both are
formulated as one-hot matmuls so the Pallas kernels and the jnp oracles
share one reduction order and stay bit-exact — including int32
fixed-point tables, where the accumulation is exact by construction.
"""
from .ops import emb_gather, emb_scatter_add  # noqa: F401
from .ref import IDX_PAD, ROW_PAD_ID  # noqa: F401
