"""Dispatchable wrappers around the sparse gather/scatter-add kernels.

Ops (registered with :mod:`repro.kernels.dispatch`):

``emb_gather``      : shard-local embedding row lookup, zeros for rows
                      the shard does not own — the per-core forward leg
                      of the EMB workload (summed by the fabric reduce).
``emb_scatter_add`` : duplicate-index-safe batched row update (segment
                      sum) — the eager apply and the deferred flush both
                      route through this single op.

The pallas wrappers pad ragged axes (batch for gather, rows for
scatter) with the sentinel ids from :mod:`.ref`, which can never match
a real lookup — padded work contributes exact zeros and is sliced off.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import register_op
from .kernel import emb_gather as _gather_kernel
from .kernel import emb_scatter_add as _scatter_kernel
from .ref import IDX_PAD, ROW_PAD_ID, emb_gather_ref, emb_scatter_add_ref


def _pad_to(x, n, fill):
    if x.shape[0] == n:
        return x
    pad = jnp.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def _emb_gather_ref(table, ids, idx, *, block_b: int = 256):
    del block_b  # jnp oracle needs no tiling
    return emb_gather_ref(table, ids, idx)


def _emb_gather_pallas(table, ids, idx, *, interpret: bool = True,
                       block_b: int = 256):
    b = idx.shape[0]
    if b == 0:  # empty batch: nothing to look up
        return jnp.zeros((0, table.shape[1]), table.dtype)
    bb = min(block_b, b)
    b_pad = -(-b // bb) * bb
    out = _gather_kernel(table, ids, _pad_to(idx, b_pad, IDX_PAD),
                         block_b=bb, interpret=interpret)
    return out[:b]


def _emb_scatter_add_ref(table, ids, idx, upd, *, block_r: int = 256):
    del block_r
    return emb_scatter_add_ref(table, ids, idx, upd)


def _emb_scatter_add_pallas(table, ids, idx, upd, *,
                            interpret: bool = True, block_r: int = 256):
    if idx.shape[0] == 0:  # empty batch: table unchanged (ref adds 0)
        return table + jnp.zeros_like(table)
    r = table.shape[0]
    br = min(block_r, r)
    r_pad = -(-r // br) * br
    out = _scatter_kernel(
        _pad_to(table, r_pad, 0), _pad_to(ids, r_pad, ROW_PAD_ID),
        idx, upd, block_r=br, interpret=interpret)
    return out[:r]


def emb_gather(table, ids, idx, *, backend=None, block_b: int = 256):
    """Shard-local lookup: [R, D] x [B] global ids -> [B, D] partials."""
    from ..dispatch import launch
    return launch("emb_gather", table, ids, idx, backend=backend,
                  block_b=block_b)


def emb_scatter_add(table, ids, idx, upd, *, backend=None,
                    block_r: int = 256):
    """Duplicate-safe batched row update: segment-sum [B, D] into [R, D]."""
    from ..dispatch import launch
    return launch("emb_scatter_add", table, ids, idx, upd,
                  backend=backend, block_r=block_r)


register_op("emb_gather", family="sparse_gather",
            pallas=_emb_gather_pallas, ref=_emb_gather_ref)
register_op("emb_scatter_add", family="sparse_gather",
            pallas=_emb_scatter_add_pallas, ref=_emb_scatter_add_ref)
