"""Pure-jnp oracle for the LUT-activation kernel.

Semantics (paper Fig. 4): fixed-point Q(frac_bits) input, symmetric sigmoid
LUT over [0, boundary), int16 Q(value_frac) entries; negative inputs are
reflected (sigmoid(-x) = 1 - sigmoid(x)).
"""
from __future__ import annotations

import jax.numpy as jnp


def lut_sigmoid_ref(x_q: jnp.ndarray, table: jnp.ndarray,
                    value_frac: int = 15) -> jnp.ndarray:
    """x_q int32 Q(f) of any shape; table int16 [n]; -> int32 Q(value_frac)."""
    xq = x_q.astype(jnp.int32)
    neg = xq < 0
    idx = jnp.minimum(jnp.abs(xq), table.shape[0] - 1)
    v = table[idx].astype(jnp.int32)
    one = jnp.int32(1 << value_frac)
    return jnp.where(neg, one - v, v)


def lut_gather_ref(x: jnp.ndarray, table: jnp.ndarray, x_min: float,
                   x_max: float) -> jnp.ndarray:
    """Float-grid LUT (ActivationLut semantics) for the LM-side kernel."""
    n = table.shape[0]
    t = (x.astype(jnp.float32) - x_min) / (x_max - x_min)
    idx = jnp.clip(jnp.round(t * (n - 1)), 0, n - 1).astype(jnp.int32)
    return table[idx].astype(x.dtype)
