"""Pallas TPU kernel: LUT-based sigmoid with the table pinned in VMEM.

TPU adaptation of the paper's WRAM-resident sigmoid LUT (§3.2, Fig. 4):
  DPU WRAM (64 KB)  ->  VMEM: the 40 KB table (20 x 1024 int16 entries)
  rides along as a full-block input that the BlockSpec machinery keeps
  resident across the whole grid (index_map pins block (0,) for every i).
The "MRAM" variant of the paper corresponds to *not* using this kernel and
letting XLA issue an HBM gather (ops.lut_sigmoid with placement="hbm").

Each grid step processes one (block_rows, lanes) tile of the input: index
clamp, one VMEM gather, reflection for negative inputs — the same three
steps as the DPU kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..pallas_compat import pallas_call, pl


def _lut_sigmoid_kernel(x_ref, lut_ref, o_ref, *, value_frac: int):
    xq = x_ref[...].astype(jnp.int32)
    table = lut_ref[...]
    neg = xq < 0
    idx = jnp.minimum(jnp.abs(xq), table.shape[0] - 1)
    v = jnp.take(table, idx.reshape(-1), axis=0).reshape(xq.shape)
    v = v.astype(jnp.int32)
    one = jnp.int32(1 << value_frac)
    o_ref[...] = jnp.where(neg, one - v, v)


@functools.partial(jax.jit, static_argnames=("value_frac", "block_rows",
                                             "interpret"))
def lut_sigmoid_vmem(x_q: jnp.ndarray, table: jnp.ndarray, *,
                     value_frac: int = 15, block_rows: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """x_q: int32 Q(f) [rows, lanes]; table: int16 [n] -> int32 [rows, lanes].

    The whole table is one VMEM block shared by every grid step; rows are
    tiled so arbitrarily large activations stream through.
    """
    rows, lanes = x_q.shape
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    return pallas_call(
        functools.partial(_lut_sigmoid_kernel, value_frac=value_frac),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
            pl.BlockSpec((table.shape[0],), lambda i: (0,)),  # pinned
        ],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(x_q, table)
