"""Dispatchable LUT sigmoid with WRAM/MRAM-style placement selection
(op ``lut_sigmoid``).

``placement="vmem"``  -> Pallas kernel, table resident in VMEM
                         (paper: LOG-INT32-LUT (WRAM))
``placement="hbm"``   -> XLA gather straight from HBM
                         (paper: LOG-INT32-LUT (MRAM))
Both are numerically identical (asserted in tests), exactly as the paper
observes — placement is a ~3% performance knob on the DPU.  Backend
routing goes through :mod:`repro.kernels.dispatch`: the ``jnp_ref``
backend IS the HBM/MRAM variant, so kernel availability only changes
where the table lives, never the values.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lut import SigmoidLut
from ..dispatch import legacy_launch, register_op
from .kernel import lut_sigmoid_vmem
from .ref import lut_sigmoid_ref


def _sigmoid_pallas(x_q: jnp.ndarray, lut: SigmoidLut, *,
                    interpret: bool = True,
                    block_rows: int = 256) -> jnp.ndarray:
    """VMEM-kernel path: flatten, pad to a (rows, 128) grid, slice back."""
    shape = x_q.shape
    flat = x_q.reshape(-1)
    lanes = 128
    n = flat.shape[0]
    rows = -(-n // lanes)
    br = min(block_rows, max(rows, 1))
    pad_rows = -(-rows // br) * br
    padded = jnp.zeros((pad_rows * lanes,), x_q.dtype).at[:n].set(flat)
    out = lut_sigmoid_vmem(padded.reshape(pad_rows, lanes), lut.table,
                           value_frac=lut.value_frac, block_rows=br,
                           interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)


def _sigmoid_ref(x_q: jnp.ndarray, lut: SigmoidLut, *,
                 block_rows: int = 256) -> jnp.ndarray:
    del block_rows  # jnp oracle needs no tiling
    return lut_sigmoid_ref(x_q, lut.table, lut.value_frac)


def lut_sigmoid(x_q: jnp.ndarray, lut: SigmoidLut, *,
                placement: str = "vmem", backend=None,
                use_pallas: bool = None, interpret: bool = None,
                block_rows: int = 256) -> jnp.ndarray:
    """Fixed-point sigmoid via LUT.  x_q int32 Q(lut.frac_bits), any shape.

    ``placement="hbm"`` forces the XLA gather (MRAM variant); otherwise
    ``backend`` picks the implementation (None = auto-select).
    """
    if placement == "hbm":
        return _sigmoid_ref(x_q, lut)
    # placement="vmem" historically meant "the kernel": keep that
    # meaning when neither backend nor use_pallas says otherwise
    if backend is None and use_pallas is None:
        use_pallas = True
    return legacy_launch("lut_sigmoid", x_q, lut, backend=backend,
                         use_pallas=use_pallas, interpret=interpret,
                         block_rows=block_rows)


register_op("lut_sigmoid", family="lut_activation",
            pallas=_sigmoid_pallas, ref=_sigmoid_ref)
