"""jit'd wrappers: LUT sigmoid with WRAM/MRAM-style placement selection.

``placement="vmem"``  -> Pallas kernel, table resident in VMEM
                         (paper: LOG-INT32-LUT (WRAM))
``placement="hbm"``   -> XLA gather straight from HBM
                         (paper: LOG-INT32-LUT (MRAM))
Both are numerically identical (asserted in tests), exactly as the paper
observes — placement is a ~3% performance knob on the DPU.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lut import SigmoidLut
from .kernel import lut_sigmoid_vmem
from .ref import lut_sigmoid_ref


def lut_sigmoid(x_q: jnp.ndarray, lut: SigmoidLut, *,
                placement: str = "vmem", interpret: bool = True,
                block_rows: int = 256) -> jnp.ndarray:
    """Fixed-point sigmoid via LUT.  x_q int32 Q(lut.frac_bits), any shape."""
    if placement == "hbm":
        return lut_sigmoid_ref(x_q, lut.table, lut.value_frac)
    shape = x_q.shape
    flat = x_q.reshape(-1)
    # pad to a (rows, 128) grid for the kernel
    lanes = 128
    n = flat.shape[0]
    rows = -(-n // lanes)
    pad_rows = -(-rows // min(block_rows, max(rows, 1))) * \
        min(block_rows, max(rows, 1))
    padded = jnp.zeros((pad_rows * lanes,), x_q.dtype).at[:n].set(flat)
    out = lut_sigmoid_vmem(padded.reshape(pad_rows, lanes), lut.table,
                           value_frac=lut.value_frac,
                           block_rows=min(block_rows, pad_rows),
                           interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)
