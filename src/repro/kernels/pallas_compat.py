"""Single-point resolution of drifted Pallas TPU APIs (DESIGN.md §6.1).

The Pallas TPU surface has moved across jax releases:

  * ``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams``
    (and on very old releases compiler params were a ``mosaic_params``
    dict) — the source of the ``AttributeError: CompilerParams`` drift
    that killed every kernel in this repo at once;
  * some builds ship without Pallas at all (no Mosaic backend compiled
    in), in which case the kernels must be skippable rather than fatal.

Every ``kernels/*/kernel.py`` imports **this module only** for the
drift-prone pieces; none of them touch ``pltpu`` attributes directly.
When the next rename lands, it gets fixed here, once.

Nothing here imports the rest of ``repro`` — this is the bottom of the
kernel-layer dependency graph (dispatch.py sits on top).
"""
from __future__ import annotations

from typing import Any, Optional

HAS_PALLAS = True
_IMPORT_ERROR: Optional[Exception] = None

try:  # pragma: no cover - exercised implicitly by every kernel import
    from jax.experimental import pallas as pl  # noqa: F401
except Exception as e:  # pallas not in this jax build
    pl = None  # type: ignore[assignment]
    HAS_PALLAS = False
    _IMPORT_ERROR = e

try:  # the TPU sub-package can be missing even when pallas core exists
    from jax.experimental.pallas import tpu as pltpu  # noqa: F401
except Exception as e:  # pragma: no cover
    pltpu = None  # type: ignore[assignment]
    HAS_PALLAS = False
    if _IMPORT_ERROR is None:
        _IMPORT_ERROR = e

#: the compiler-params class under whichever name this jax spells it
CompilerParams: Optional[type] = None
if pltpu is not None:
    CompilerParams = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)


def pallas_unavailable_reason() -> Optional[str]:
    """Human-readable reason Pallas cannot be used, or None if it can."""
    if HAS_PALLAS:
        return None
    return f"pallas unavailable in this jax build: {_IMPORT_ERROR!r}"


def compiler_params(dimension_semantics=None, **kwargs) -> Optional[Any]:
    """Build a compiler-params object if this jax supports one.

    Returns None when Pallas has no compiler-params class (or when no
    fields were requested); callers pass the result straight to
    ``pallas_call(compiler_params=...)``, where None means "defaults".
    """
    if CompilerParams is None:
        return None
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    if not kwargs:
        return None
    try:
        return CompilerParams(**kwargs)
    except TypeError:
        # field-name drift inside the params class itself: degrade to
        # compiler defaults rather than failing the kernel outright
        return None


def vmem_scratch(shape, dtype):
    """``pltpu.VMEM`` scratch allocation (drift-safe accessor)."""
    if pltpu is None:
        raise RuntimeError(pallas_unavailable_reason())
    return pltpu.VMEM(tuple(shape), dtype)


def pallas_call(kernel_fn, *, grid=None, in_specs=None, out_specs=None,
                out_shape=None, scratch_shapes=None,
                dimension_semantics=None, interpret: bool = False):
    """Drift-resolved ``pl.pallas_call`` wrapper used by every kernel.

    ``dimension_semantics`` is taken as a plain tuple of strings and
    converted into whatever compiler-params object this jax wants; all
    other arguments pass through unchanged.
    """
    if pl is None:
        raise RuntimeError(pallas_unavailable_reason())
    kwargs: dict = {"out_shape": out_shape, "interpret": interpret}
    if grid is not None:
        kwargs["grid"] = grid
    if in_specs is not None:
        kwargs["in_specs"] = in_specs
    if out_specs is not None:
        kwargs["out_specs"] = out_specs
    if scratch_shapes is not None:
        kwargs["scratch_shapes"] = scratch_shapes
    params = compiler_params(dimension_semantics=dimension_semantics)
    if params is not None:
        kwargs["compiler_params"] = params
    return pl.pallas_call(kernel_fn, **kwargs)
