"""Backend dispatch for the kernel tier (DESIGN.md §6.2).

The paper's speedups exist only when the necessary operations and
datatypes are natively supported by the hardware; in this reproduction
the "native" tier is the Pallas kernel layer.  This module makes that
tier a first-class, swappable interface (the kernel/offload boundary
PIM-Opt and the DPU programmability study both call for):

  * :class:`KernelBackend` — where an op runs:
      ``pallas_tpu``       compiled Mosaic kernel (real TPU targets)
      ``pallas_interpret`` the same kernel under the Pallas interpreter
                           (CPU CI / debugging; slow but bit-faithful)
      ``jnp_ref``          the family's pure-jnp oracle in ``ref.py``
                           (lowers anywhere, fuses well under vmap /
                           shard_map — the fallback fast path off-TPU)
  * :func:`resolve_backend` — per-platform auto-selection with an
    ``REPRO_KERNEL_BACKEND`` environment override;
  * :func:`launch` — the uniform entry: ``launch(op, *args,
    backend=..., **kw)`` routes to the family's kernel or ref
    implementation and falls back to ref when Pallas is unavailable.

Every op family registers a (pallas, ref) implementation pair from its
``ops.py`` at import time; :func:`launch` lazily imports the families on
first use, so importing this module costs nothing and cannot cycle.

The trainers (core/kmeans.py, core/dtree.py, core/logreg.py,
core/linreg.py) call :func:`launch` from inside their per-core kernels;
the op name + backend are baked into the ``PimSystem`` named-kernel
registration, so ``ReduceStrategy`` selection and ``TransferStats``
accounting apply unchanged to the kernel-accelerated paths.
"""
from __future__ import annotations

import dataclasses
import enum
import importlib
import os
from typing import Callable, Dict, Optional, Union

import jax

from .pallas_compat import HAS_PALLAS, pallas_unavailable_reason


class KernelBackend(enum.Enum):
    """Where a kernel-family op executes."""

    PALLAS_TPU = "pallas_tpu"
    PALLAS_INTERPRET = "pallas_interpret"
    JNP_REF = "jnp_ref"

    @property
    def is_pallas(self) -> bool:
        return self is not KernelBackend.JNP_REF

    @property
    def interpret(self) -> bool:
        return self is KernelBackend.PALLAS_INTERPRET


BackendLike = Union[None, str, KernelBackend]

#: environment override consulted by :func:`default_backend`
BACKEND_ENV_VAR = "REPRO_KERNEL_BACKEND"


def _platform() -> str:
    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover - no devices at all
        return "cpu"


def default_backend() -> KernelBackend:
    """Auto-select the backend for this process.

    Order: ``REPRO_KERNEL_BACKEND`` env var if set; ``pallas_tpu`` on a
    real TPU; otherwise ``jnp_ref`` (XLA fuses the oracles into the
    platform-native fast path — running the Pallas *interpreter* in a
    hot loop would be strictly slower; it remains an explicit opt-in
    for parity testing and kernel debugging).
    """
    env = os.environ.get(BACKEND_ENV_VAR)
    if env:
        return resolve_backend(env)
    if HAS_PALLAS and _platform() == "tpu":
        return KernelBackend.PALLAS_TPU
    return KernelBackend.JNP_REF


def resolve_backend(spec: BackendLike = None) -> KernelBackend:
    """Coerce None/string/enum to a usable :class:`KernelBackend`.

    A Pallas backend silently degrades to ``jnp_ref`` when this jax
    build has no Pallas at all — the ref oracles are semantically
    identical (asserted by the parity tests), so degrading is safe.
    """
    if spec is None:
        be = default_backend()
    elif isinstance(spec, KernelBackend):
        be = spec
    elif isinstance(spec, str):
        try:
            be = KernelBackend(spec.lower())
        except ValueError:
            raise ValueError(
                f"unknown kernel backend {spec!r}; known: "
                f"{[b.value for b in KernelBackend]}") from None
    else:
        raise TypeError(f"backend must be None, str or KernelBackend, "
                        f"got {type(spec).__name__}")
    if be.is_pallas and not HAS_PALLAS:
        return KernelBackend.JNP_REF
    return be


def legacy_backend(backend: BackendLike, use_pallas: Optional[bool],
                   interpret: Optional[bool]) -> KernelBackend:
    """Map the pre-dispatch ``(use_pallas, interpret)`` flag pair onto a
    backend.  ``backend`` wins when given; ``use_pallas=None`` defers to
    auto-selection.  Kept so existing callers/tests/benchmarks keep
    their meaning while the dispatch layer is the single router."""
    if backend is not None:
        return resolve_backend(backend)
    if use_pallas is None:
        return default_backend()
    if not use_pallas:
        return KernelBackend.JNP_REF
    if interpret is None or interpret:
        return resolve_backend(KernelBackend.PALLAS_INTERPRET)
    return resolve_backend(KernelBackend.PALLAS_TPU)


# ---------------------------------------------------------------------------
# Op registry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelOp:
    """One dispatchable op: a Pallas implementation + its jnp oracle.

    ``pallas`` is called as ``pallas(*args, interpret=bool, **kw)``;
    ``ref`` as ``ref(*args, **kw)`` (adapters registered by each family
    drop pallas-only tuning kwargs such as block sizes).
    """

    name: str
    family: str
    pallas: Callable
    ref: Callable


_OPS: Dict[str, KernelOp] = {}

#: kernel families auto-imported on first launch()/get_op() call; each
#: family's ops.py calls register_op at import time.
_FAMILIES = ("kmeans_assign", "gini_split", "lut_activation",
             "quant_matmul", "flash_attention", "sparse_gather")
_registered = False

#: per-op launch counters (diagnostics + the trainer-routing tests)
launch_counts: Dict[str, int] = {}


def register_op(name: str, *, family: str, pallas: Callable,
                ref: Callable) -> None:
    _OPS[name] = KernelOp(name=name, family=family, pallas=pallas, ref=ref)


def _ensure_registered() -> None:
    global _registered
    if _registered:
        return
    _registered = True
    for fam in _FAMILIES:
        importlib.import_module(f"repro.kernels.{fam}.ops")


def get_op(name: str) -> KernelOp:
    _ensure_registered()
    try:
        return _OPS[name]
    except KeyError:
        raise KeyError(f"unknown kernel op {name!r}; known: "
                       f"{sorted(_OPS)}") from None


def available_ops() -> tuple:
    _ensure_registered()
    return tuple(sorted(_OPS))


def launch(op: str, *args, backend: BackendLike = None, **kwargs):
    """Run kernel-family op ``op`` on ``backend`` (auto-selected when
    None).  Jnp-ref fallback engages when Pallas is unavailable."""
    entry = get_op(op)
    be = resolve_backend(backend)
    launch_counts[op] = launch_counts.get(op, 0) + 1
    if be is KernelBackend.JNP_REF:
        return entry.ref(*args, **kwargs)
    return entry.pallas(*args, interpret=be.interpret, **kwargs)


def legacy_launch(op: str, *args, backend: BackendLike = None,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None, **kwargs):
    """:func:`launch` with the pre-dispatch ``(use_pallas, interpret)``
    flag pair mapped onto a backend.  The single router behind every
    family's public ``ops.py`` wrapper — the wrappers and
    :func:`launch` share one code path (including ragged-shape
    padding), so they cannot diverge."""
    return launch(op, *args,
                  backend=legacy_backend(backend, use_pallas, interpret),
                  **kwargs)


def backend_tag(backend: BackendLike = None) -> str:
    """Short backend label for PimSystem kernel names (``be=jnp_ref``)."""
    return f"be={resolve_backend(backend).value}"


__all__ = [
    "KernelBackend", "BACKEND_ENV_VAR", "default_backend",
    "resolve_backend", "legacy_backend", "register_op", "get_op",
    "available_ops", "launch", "legacy_launch", "launch_counts",
    "backend_tag", "HAS_PALLAS", "pallas_unavailable_reason",
]
