"""Pure-jnp oracle for the K-Means assign/accumulate kernel.

Semantics (paper §3.4): for each quantized point find the nearest centroid
(squared L2, integer arithmetic), then produce per-cluster coordinate sums
and counts — the per-PIM-core part of one Lloyd iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x_q: jnp.ndarray, c_q: jnp.ndarray):
    """x_q int16 [N, F]; c_q int16 [K, F]
    -> (labels int32 [N], sums int32 [K, F], counts int32 [K])."""
    x = x_q.astype(jnp.int32)
    c = c_q.astype(jnp.int32)
    cross = jax.lax.dot_general(x, c.T, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
    cnorm = jnp.sum(c * c, axis=1)
    dist = cnorm[None, :] - 2 * cross          # ||x||^2 omitted (argmin-inv)
    labels = jnp.argmin(dist, axis=1).astype(jnp.int32)
    k = c_q.shape[0]
    onehot = (labels[:, None] == jnp.arange(k)[None, :]).astype(jnp.int32)
    sums = jax.lax.dot_general(onehot.T, x, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)
    counts = jnp.sum(onehot, axis=0)
    return labels, sums, counts
