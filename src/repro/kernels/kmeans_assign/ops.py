"""Dispatchable wrapper for the K-Means assign kernel (op ``kmeans_assign``).

``assign_and_accumulate`` routes between the Pallas kernel and the pure
jnp oracle through the :mod:`repro.kernels.dispatch` backend layer; on
the kernel path it pads N to a block multiple and corrects the
padding's contribution afterwards.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import legacy_launch, register_op
from .kernel import kmeans_assign
from .ref import kmeans_assign_ref


def _assign_pallas(x_q: jnp.ndarray, c_q: jnp.ndarray, *,
                   interpret: bool = True, block_n: int = 1024):
    """Kernel path: pads N to a block multiple, runs the kernel, and
    corrects the padding's contribution (padding rows are zeros -> they
    all land in the one cluster minimizing -2*0.c + ||c||^2, contribute
    zero to ``sums``, and are subtracted from that cluster's count)."""
    n = x_q.shape[0]
    bn = min(block_n, max(n, 8))
    n_pad = -(-n // bn) * bn
    if n_pad != n:
        xp = jnp.zeros((n_pad, x_q.shape[1]), x_q.dtype).at[:n].set(x_q)
    else:
        xp = x_q
    labels, sums, counts = kmeans_assign(xp, c_q, block_n=bn,
                                         interpret=interpret)
    if n_pad != n:
        c = c_q.astype(jnp.int32)
        pad_label = jnp.argmin(jnp.sum(c * c, axis=1)).astype(jnp.int32)
        n_fake = n_pad - n
        counts = counts.at[pad_label].add(-n_fake)
        labels = labels[:n]
    return labels, sums, counts


def assign_and_accumulate(x_q: jnp.ndarray, c_q: jnp.ndarray, *,
                          backend=None, use_pallas: bool = None,
                          interpret: bool = None, block_n: int = 1024):
    """x_q int16 [N, F]; c_q int16 [K, F] ->
    (labels int32 [N], sums int32 [K, F], counts int32 [K]).

    ``backend`` picks the implementation (None = auto-select).  The
    legacy ``use_pallas``/``interpret`` flags keep their meaning when
    set explicitly; leaving everything unset now auto-selects
    (``jnp_ref`` off-TPU — the old default was the interpret kernel).
    """
    return legacy_launch("kmeans_assign", x_q, c_q, backend=backend,
                         use_pallas=use_pallas, interpret=interpret,
                         block_n=block_n)


def _assign_ref(x_q, c_q, *, block_n: int = 1024):
    del block_n  # jnp oracle needs no tiling
    return kmeans_assign_ref(x_q, c_q)


register_op("kmeans_assign", family="kmeans_assign",
            pallas=_assign_pallas, ref=_assign_ref)
