"""jit'd wrapper for the K-Means assign kernel with ref fallback + padding."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import kmeans_assign
from .ref import kmeans_assign_ref


def assign_and_accumulate(x_q: jnp.ndarray, c_q: jnp.ndarray, *,
                          use_pallas: bool = True, interpret: bool = True,
                          block_n: int = 1024):
    """Pads N to a block multiple, runs the kernel, and corrects the
    padding's contribution (padding rows are zeros -> they land in whichever
    cluster minimizes -2*0.c + ||c||^2; we subtract them from that cluster).
    """
    n = x_q.shape[0]
    if not use_pallas:
        return kmeans_assign_ref(x_q, c_q)
    bn = min(block_n, max(n, 8))
    n_pad = -(-n // bn) * bn
    if n_pad != n:
        xp = jnp.zeros((n_pad, x_q.shape[1]), x_q.dtype).at[:n].set(x_q)
    else:
        xp = x_q
    labels, sums, counts = kmeans_assign(xp, c_q, block_n=bn,
                                         interpret=interpret)
    if n_pad != n:
        c = c_q.astype(jnp.int32)
        pad_label = jnp.argmin(jnp.sum(c * c, axis=1)).astype(jnp.int32)
        n_fake = n_pad - n
        counts = counts.at[pad_label].add(-n_fake)
        labels = labels[:n]
    return labels, sums, counts
