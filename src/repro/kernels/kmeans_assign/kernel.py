"""Pallas TPU kernel: K-Means assignment + accumulation (paper §3.4).

TPU adaptation: the DPU loops over points computing 16-bit multiplies; the
MXU-native formulation is  argmin_k(||c_k||^2 - 2 x.c_k)  — an int16 x int16
-> int32 matmul per (points-block x centroids) tile, followed by a one-hot
matmul that accumulates per-cluster coordinate sums on-chip.  Centroids
(K x F) stay pinned in VMEM across the whole grid; point blocks stream
HBM->VMEM, which is the same streaming-bank access pattern the paper
engineers for the DPU (Recommendation #6).

Outputs ``sums``/``counts`` map every grid step to block (0, 0) and are
accumulated in place across the sequential grid (revisiting semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..pallas_compat import pallas_call, pl


def _kmeans_kernel(x_ref, c_ref, labels_ref, sums_ref, counts_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...].astype(jnp.int32)            # (bn, F)
    c = c_ref[...].astype(jnp.int32)            # (K, F)
    cross = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.int32)
    cnorm = jnp.sum(c * c, axis=1)
    dist = cnorm[None, :] - 2 * cross           # (bn, K)
    labels = jnp.argmin(dist, axis=1).astype(jnp.int32)
    labels_ref[...] = labels

    k = c.shape[0]
    onehot = (labels[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)).astype(jnp.int32)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)       # (K, F)
    counts_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x_q: jnp.ndarray, c_q: jnp.ndarray, *,
                  block_n: int = 1024, interpret: bool = False):
    """x_q int16 [N, F]; c_q int16 [K, F] ->
    (labels int32 [N], sums int32 [K, F], counts int32 [K])."""
    n, f = x_q.shape
    k, f2 = c_q.shape
    assert f == f2
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pallas_call(
        _kmeans_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),   # centroids pinned
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),   # accumulated in place
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((k, f), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        dimension_semantics=("arbitrary",),
        interpret=interpret,
    )(x_q, c_q)
