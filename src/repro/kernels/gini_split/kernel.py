"""Pallas TPU kernel: decision-tree split-evaluate (paper §3.3, Fig. 5).

TPU adaptation of the paper's streaming layout: the DPU version reorders
feature values so each leaf is contiguous and streams MRAM->WRAM.  On TPU
the same property — "every byte fetched from HBM is used by exactly one
streaming pass" — is achieved by tiling points into (block_n x F) VMEM
blocks and turning both per-leaf threshold selection and per-(leaf,class)
count scatter into **one-hot matmuls** (MXU work, no data-dependent
scatter, which Mosaic does not support):

  t[i, f]      = onehot_leaf[i, :] @ thresholds[:, f]
  counts[s, f] = onehot_seg[:, s].T @ below[:, f]        s = leaf*C + class

Thresholds and the count accumulators stay pinned in VMEM across the grid;
point blocks stream — the direct analogue of the DPU's DMA streaming.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..pallas_compat import pallas_call, pl


def _gini_kernel(x_ref, seg_ref, leaf_ref, th_ref, counts_ref, totals_ref,
                 *, n_slots: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        totals_ref[...] = jnp.zeros_like(totals_ref)

    x = x_ref[...]                                   # (bn, F) f32
    seg = seg_ref[...]                               # (bn,) int32 leaf*C+y
    leaf = leaf_ref[...]                             # (bn,) int32
    th = th_ref[...]                                 # (L, F) f32

    n_leaves = th.shape[0]
    oh_leaf = (leaf[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, n_leaves), 1)).astype(jnp.float32)
    t = jax.lax.dot_general(oh_leaf, th, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    below = (x <= t).astype(jnp.int32)               # (bn, F)

    oh_seg = (seg[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, n_slots), 1)).astype(jnp.int32)
    counts_ref[...] += jax.lax.dot_general(
        oh_seg, below, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)            # (n_slots, F)
    totals_ref[...] += jnp.sum(oh_seg, axis=0)


@functools.partial(jax.jit, static_argnames=("n_classes", "block_n",
                                             "interpret"))
def gini_counts(x: jnp.ndarray, y: jnp.ndarray, leaf: jnp.ndarray,
                thresholds: jnp.ndarray, *, n_classes: int,
                block_n: int = 1024, interpret: bool = False):
    """x f32 [N, F]; y/leaf int32 [N]; thresholds f32 [L, F].
    N must be a block multiple and leaf in [0, L) (ops.py pads/validates).
    -> (below int32 [L, C, F], total int32 [L, C])."""
    n, f = x.shape
    n_leaves = thresholds.shape[0]
    n_slots = n_leaves * n_classes
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    seg = leaf * n_classes + y
    counts, totals = pallas_call(
        functools.partial(_gini_kernel, n_slots=n_slots),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_leaves, f), lambda i: (0, 0)),  # pinned
        ],
        out_specs=[
            pl.BlockSpec((n_slots, f), lambda i: (0, 0)),   # accumulated
            pl.BlockSpec((n_slots,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_slots, f), jnp.int32),
            jax.ShapeDtypeStruct((n_slots,), jnp.int32),
        ],
        dimension_semantics=("arbitrary",),
        interpret=interpret,
    )(x, seg, leaf, thresholds)
    return (counts.reshape(n_leaves, n_classes, f),
            totals.reshape(n_leaves, n_classes))
