"""Pure-jnp oracle for the Gini split-evaluate kernel (paper §3.3).

Semantics: given points (x, class y, leaf id), one candidate threshold per
(leaf, feature), produce per-(leaf, class, feature) below-threshold counts
and per-(leaf, class) totals — the per-PIM-core part of split-evaluate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gini_counts_ref(x: jnp.ndarray, y: jnp.ndarray, leaf: jnp.ndarray,
                    thresholds: jnp.ndarray, n_classes: int):
    """x f32 [N, F]; y int32 [N]; leaf int32 [N] in [0, L);
    thresholds f32 [L, F] -> (below int32 [L, C, F], total int32 [L, C])."""
    n_leaves = thresholds.shape[0]
    t = thresholds[leaf]                            # (N, F)
    below = (x <= t).astype(jnp.int32)              # (N, F)
    seg = leaf * n_classes + y
    counts = jax.ops.segment_sum(below, seg,
                                 num_segments=n_leaves * n_classes)
    totals = jax.ops.segment_sum(jnp.ones_like(seg), seg,
                                 num_segments=n_leaves * n_classes)
    return (counts.reshape(n_leaves, n_classes, -1),
            totals.reshape(n_leaves, n_classes))
