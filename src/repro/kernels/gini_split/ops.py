"""jit'd wrapper for split-evaluate with padding + ref fallback.

The host remaps frontier leaf ids to a compact [0, L) range before calling
(keeping the one-hot matmuls small); padding rows are routed to a spill
leaf slot that is sliced off afterwards.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import gini_counts
from .ref import gini_counts_ref


def split_evaluate(x, y, leaf, thresholds, n_classes: int, *,
                   use_pallas: bool = True, interpret: bool = True,
                   block_n: int = 1024):
    """Returns (below [L, C, F], total [L, C]) over valid rows only."""
    if not use_pallas:
        return gini_counts_ref(x, y, leaf, thresholds, n_classes)
    n = x.shape[0]
    n_leaves = thresholds.shape[0]
    bn = min(block_n, max(n, 8))
    n_pad = -(-n // bn) * bn
    if n_pad != n:
        pad = n_pad - n
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
        # spill slot: one extra leaf row with very-negative thresholds
        # (never <=).  Finite sentinel: the kernel's one-hot matmul would
        # turn 0 * -inf into NaN.
        leaf = jnp.concatenate(
            [leaf, jnp.full((pad,), n_leaves, leaf.dtype)])
        thresholds = jnp.concatenate(
            [thresholds,
             jnp.full((1, x.shape[1]), -1e30, thresholds.dtype)])
    below, total = gini_counts(x, y, leaf, thresholds, n_classes=n_classes,
                               block_n=bn, interpret=interpret)
    return below[:n_leaves], total[:n_leaves]
