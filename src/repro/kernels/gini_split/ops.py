"""Dispatchable wrapper for split-evaluate (op ``gini_split``).

The host remaps frontier leaf ids to a compact [0, L) range before
calling (keeping the one-hot matmuls small); on the kernel path padding
rows are routed to a spill leaf slot that is sliced off afterwards.
Backend routing goes through :mod:`repro.kernels.dispatch`.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import legacy_launch, register_op
from .kernel import gini_counts
from .ref import gini_counts_ref


def _split_pallas(x, y, leaf, thresholds, n_classes: int, *,
                  interpret: bool = True, block_n: int = 1024):
    """Kernel path with ragged-tail padding.  Returns counts over valid
    rows only: padding rows carry a spill leaf whose very-negative
    (finite: 0 * -inf would NaN the one-hot matmul) thresholds force
    below=0, and the spill row is sliced off."""
    n = x.shape[0]
    n_leaves = thresholds.shape[0]
    bn = min(block_n, max(n, 8))
    n_pad = -(-n // bn) * bn
    if n_pad != n:
        pad = n_pad - n
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
        leaf = jnp.concatenate(
            [leaf, jnp.full((pad,), n_leaves, leaf.dtype)])
        thresholds = jnp.concatenate(
            [thresholds,
             jnp.full((1, x.shape[1]), -1e30, thresholds.dtype)])
    below, total = gini_counts(x, y, leaf, thresholds, n_classes=n_classes,
                               block_n=bn, interpret=interpret)
    return below[:n_leaves], total[:n_leaves]


def _split_ref(x, y, leaf, thresholds, n_classes: int, *,
               block_n: int = 1024):
    del block_n  # jnp oracle needs no tiling
    return gini_counts_ref(x, y, leaf, thresholds, n_classes)


def split_evaluate(x, y, leaf, thresholds, n_classes: int, *,
                   backend=None, use_pallas: bool = None,
                   interpret: bool = None, block_n: int = 1024):
    """Returns (below [L, C, F], total [L, C]) over valid rows only.

    ``backend`` picks the implementation (None = auto-select).  The
    legacy ``use_pallas``/``interpret`` flags keep their meaning when
    set explicitly; leaving everything unset now auto-selects
    (``jnp_ref`` off-TPU — the old default was the interpret kernel).
    """
    return legacy_launch("gini_split", x, y, leaf, thresholds, n_classes,
                         backend=backend, use_pallas=use_pallas,
                         interpret=interpret, block_n=block_n)


register_op("gini_split", family="gini_split",
            pallas=_split_pallas, ref=_split_ref)
