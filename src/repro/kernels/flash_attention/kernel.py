"""Pallas TPU kernel: blocked causal attention with online softmax (fwd).

VMEM tiling: (bq x d) query blocks stay resident while (bk x d) key/value
blocks stream through the sequential kv grid axis; running max / sum /
accumulator live in VMEM scratch (the classic flash pattern re-tiled for
the MXU: all three matmuls are 128-aligned by default).

Causality is enforced two ways: (1) whole kv blocks strictly above the
diagonal are skipped via pl.when (no MXU work issued — same trick as the
paper's "skip what you can decide cheaply on the host"), and (2) the
diagonal block applies an element mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..pallas_compat import pallas_call, pl, vmem_scratch

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, n_kv: int, bq: int, bk: int,
                  q_offset: int, window: int):
    """window: 0 = unbounded; >0 = sliding-window attention (hymba SWA):
    query at absolute position p attends kv in (p - window, p]."""
    i = pl.program_id(1)   # q block
    j = pl.program_id(2)   # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_first = i * bq + q_offset          # absolute position of first q row
    block_needed = (not causal) or (j * bk <= q_first + bq - 1)
    if window:
        # kv block entirely below the EARLIEST query's window start -> skip
        in_window = (j + 1) * bk - 1 > q_first - window
        block_needed = jnp.logical_and(block_needed, in_window) \
            if causal else in_window

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal or window:
            qpos = q_first + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            mask = qpos >= kpos if causal else (qpos == qpos)
            if window:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "bq", "bk", "q_offset", "window", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    q_offset: int = 0, window: int = 0,
                    interpret: bool = False) -> jnp.ndarray:
    """q [BH, Sq, D]; k,v [BH, Skv, D] -> [BH, Sq, D] (heads pre-flattened).

    ``q_offset`` positions q rows at absolute offset within the kv sequence
    (decode: Skv - Sq).  ``window`` > 0 enables sliding-window attention
    with out-of-window kv blocks skipped entirely (no MXU work issued).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_kv = skv // bk
    scale = 1.0 / (d ** 0.5)
    grid = (bh, sq // bq, n_kv)
    return pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          n_kv=n_kv, bq=bq, bk=bk, q_offset=q_offset,
                          window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            vmem_scratch((bq,), jnp.float32),
            vmem_scratch((bq,), jnp.float32),
            vmem_scratch((bq, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v)
