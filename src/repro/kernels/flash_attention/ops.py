"""jit'd wrapper: batched/GQA attention with kernel or XLA-ref routing."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, q_offset: int = 0, window: int = 0,
        use_pallas: bool = False, interpret: bool = True, bq: int = 128,
        bk: int = 128) -> jnp.ndarray:
    """q [B, Hq, Sq, D]; k,v [B, Hkv, Skv, D] (GQA: Hq multiple of Hkv).
    ``window`` > 0: sliding-window attention."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq != hkv:
        assert hq % hkv == 0, (hq, hkv)
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                             window=window)
    out = flash_attention(q.reshape(b * hq, sq, d),
                          k.reshape(b * hq, skv, d),
                          v.reshape(b * hq, skv, d),
                          causal=causal, q_offset=q_offset, window=window,
                          bq=bq, bk=bk, interpret=interpret)
    return out.reshape(b, hq, sq, d)
