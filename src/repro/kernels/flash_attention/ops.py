"""Dispatchable wrapper: batched/GQA attention (op ``mha``)."""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import legacy_launch, register_op
from .kernel import flash_attention
from .ref import attention_ref


def _gqa_repeat(q, k, v):
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        assert hq % hkv == 0, (hq, hkv)
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return k, v


def _mha_pallas(q, k, v, *, causal: bool = True, q_offset: int = 0,
                window: int = 0, interpret: bool = True, bq: int = 128,
                bk: int = 128) -> jnp.ndarray:
    k, v = _gqa_repeat(q, k, v)
    b, hq, sq, d = q.shape
    _, _, skv, _ = k.shape
    out = flash_attention(q.reshape(b * hq, sq, d),
                          k.reshape(b * hq, skv, d),
                          v.reshape(b * hq, skv, d),
                          causal=causal, q_offset=q_offset, window=window,
                          bq=bq, bk=bk, interpret=interpret)
    return out.reshape(b, hq, sq, d)


def _mha_ref(q, k, v, *, causal: bool = True, q_offset: int = 0,
             window: int = 0, bq: int = 128, bk: int = 128) -> jnp.ndarray:
    del bq, bk  # jnp oracle needs no tiling
    k, v = _gqa_repeat(q, k, v)
    return attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                         window=window)


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, q_offset: int = 0, window: int = 0,
        backend=None, use_pallas: bool = None, interpret: bool = None,
        bq: int = 128, bk: int = 128) -> jnp.ndarray:
    """q [B, Hq, Sq, D]; k,v [B, Hkv, Skv, D] (GQA: Hq multiple of Hkv).
    ``window`` > 0: sliding-window attention.  ``backend`` picks the
    implementation (None = auto-select); ``use_pallas``/``interpret``
    keep their legacy meaning, except that the historical default was
    the ref path — an unspecified backend only selects Pallas on TPU.
    """
    return legacy_launch("mha", q, k, v, backend=backend,
                         use_pallas=use_pallas, interpret=interpret,
                         causal=causal, q_offset=q_offset, window=window,
                         bq=bq, bk=bk)


register_op("mha", family="flash_attention",
            pallas=_mha_pallas, ref=_mha_ref)
