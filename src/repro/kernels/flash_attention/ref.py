"""Pure-jnp oracle: softmax attention (causal / full), f32 accumulation.

This is also the path the LM stack uses for *training* (XLA fuses it well
and provides the backward pass); the Pallas kernel accelerates serving
prefill — see DESIGN.md §6.
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, scale: float | None = None,
                  q_offset: int = 0, window: int = 0) -> jnp.ndarray:
    """q [B, H, Sq, D]; k,v [B, H, Skv, D] -> [B, H, Sq, D].

    ``q_offset``: absolute position of q[0] (for decode: Skv - Sq).
    ``window`` > 0: sliding-window mask (qpos - kpos < window).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal or window:
        sq, skv = q.shape[2], k.shape[2]
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        mask = qpos >= kpos if causal else jnp.ones((sq, skv), bool)
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
