"""Version-compatibility shims for the jax surface we depend on.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` to the
top-level namespace (and renamed its replication-check kwarg from
``check_rep`` to ``check_vma``) across releases.  Every call site in this
repo goes through :func:`shard_map` below so the rest of the codebase can
be written against the modern spelling and still run on older jax.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm  # noqa: PLC0415
    return sm


_SHARD_MAP = _resolve_shard_map()
try:
    _SHARD_MAP_KWARGS = frozenset(
        inspect.signature(_SHARD_MAP).parameters)
except (TypeError, ValueError):  # pragma: no cover — exotic wrappers
    _SHARD_MAP_KWARGS = frozenset()


def pcast(x, axis_names, *, to: str = "varying"):
    """``jax.lax.pcast`` where available, identity otherwise.

    pcast only exists alongside shard_map's varying-axes (VMA) type
    system; older jax (check_rep era) has no VMA typing, so marking a
    value as varying is a no-op there.
    """
    fn = getattr(jax.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axis_names, to=to)


def axis_size(axis_name) -> int:
    """Size of a mapped mesh axis, inside shard_map/pmap tracing.

    ``jax.lax.axis_size`` only exists on newer jax; the portable fallback
    is the classic ``psum(1, axis)`` constant-folding trick.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f=None, /, **kwargs: Any):
    """``jax.shard_map`` with kwarg translation across jax versions.

    Accepts the modern ``check_vma=`` spelling and rewrites it to
    ``check_rep=`` when the underlying jax only knows the old name (and
    vice versa).  Usable exactly like the real thing, including the
    ``shard_map(mesh=..., in_specs=...)(f)`` partial form.
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_KWARGS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_KWARGS \
            and "check_vma" in _SHARD_MAP_KWARGS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    if f is None:
        return lambda fn: _SHARD_MAP(fn, **kwargs)
    return _SHARD_MAP(f, **kwargs)
