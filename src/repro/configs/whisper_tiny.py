"""whisper-tiny [audio] — encoder-decoder; conv frontend is a STUB:
input_specs() provides precomputed frame embeddings (1500 frames of 30 s
audio).  Sinusoidal positions allow the assigned decoder lengths.
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51_865,
    encoder_layers=4, encoder_seq=1500,
    activation="gelu", rope_fraction=0.0,  # learned-free sinusoidal pos
    source="arXiv:2212.04356; unverified",
)
