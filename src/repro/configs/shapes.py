"""Assigned input shapes (4 per architecture -> 40 dry-run cells).

``train_*``  lower ``train_step`` (forward+backward+update)
``prefill_*`` lower ``prefill`` (forward, KV-cache write)
``decode_*`` / ``long_*`` lower ``serve_step`` (1 new token, KV cache of
seq_len) — per the assignment, NOT train_step.

``long_500k`` requires sub-quadratic attention: runs for ssm/hybrid
(recurrent state / SWA+SSM), skipped for pure full-attention archs
(recorded in DESIGN.md §4 and in the dry-run output).
"""
from __future__ import annotations

import dataclasses

from .base import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"
    microbatches: int = 1      # grad-accum steps (train only)


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

#: per-(arch-family) default microbatch counts for train_4k so the
#: activations fit 16 GB/chip on the 256-chip mesh (validated by the
#: dry-run memory_analysis; revisited during §Perf).
TRAIN_MICROBATCHES = {
    "dbrx-132b": 16, "qwen2.5-32b": 8, "llama-3.2-vision-11b": 8,
    "granite-3-8b": 4, "qwen3-8b": 4, "stablelm-12b": 4,
    "qwen2-moe-a2.7b": 4, "xlstm-350m": 2, "hymba-1.5b": 2,
    "whisper-tiny": 1,
}


def shape_for(arch: ArchConfig, shape_name: str) -> InputShape:
    s = SHAPES[shape_name]
    if s.kind == "train":
        s = dataclasses.replace(
            s, microbatches=TRAIN_MICROBATCHES.get(arch.name, 4))
    return s


def supports(arch: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-not) — the long_500k / decode skip rules."""
    if shape_name == "long_500k":
        if arch.family in ("ssm", "hybrid"):
            return True, ""
        return False, ("pure full-attention architecture: 512k-token "
                       "decode cache is quadratic-cost; skipped per "
                       "assignment (DESIGN.md §4)")
    return True, ""


def all_cells():
    """Every (arch_id, shape_name) cell, with skip annotations."""
    from .base import ARCH_IDS, get_config
    cells = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, reason = supports(cfg, s)
            cells.append((a, s, ok, reason))
    return cells
