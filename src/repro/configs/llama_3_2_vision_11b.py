"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (assignment rule). [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128_256,
    cross_attn_every=5,
    vision_tokens=1601, vision_dim=4096,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
