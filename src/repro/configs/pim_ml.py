"""The paper's own workload configs (LIN/LOG/DTR/KME on the PIM system).

These are not LM architectures; they parameterize core/{linreg,logreg,
dtree,kmeans} for the benchmark harness (Table 3 dataset sizes)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PimWorkloadConfig:
    workload: str          # lin | log | dtr | kme
    versions: tuple
    n_features: int = 16
    strong_scaling_samples: int = 6_291_456
    weak_scaling_per_core: int = 2_048
    quality_samples: int = 8_192


LIN = PimWorkloadConfig("lin", ("fp32", "int32", "hyb", "bui"))
LOG = PimWorkloadConfig(
    "log", ("fp32", "int32", "int32_lut_mram", "int32_lut_wram",
            "hyb_lut", "bui_lut"))
DTR = PimWorkloadConfig("dtr", ("fp32",),
                        strong_scaling_samples=153_600_000,
                        weak_scaling_per_core=600_000,
                        quality_samples=600_000)
KME = PimWorkloadConfig("kme", ("int16",),
                        strong_scaling_samples=25_600_000,
                        weak_scaling_per_core=100_000,
                        quality_samples=100_000)
ALL = {"lin": LIN, "log": LOG, "dtr": DTR, "kme": KME}
