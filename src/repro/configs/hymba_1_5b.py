"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every block,
128 meta tokens, sliding-window attention with 3 global layers
(first/middle/last).  [arXiv:2411.13676; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32_001,
    head_dim=64, ssm_state=16, ssm_proj_factor=2.0,
    meta_tokens=128,
    sliding_window=1024, global_attn_every=1,  # marker: 3 global layers
    rope_theta=10_000.0,
    source="arXiv:2411.13676; hf",
)
