"""Architecture config schema + registry (``--arch <id>``)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_IDS = (
    "dbrx-132b", "qwen2-moe-a2.7b", "xlstm-350m", "llama-3.2-vision-11b",
    "granite-3-8b", "qwen2.5-32b", "qwen3-8b", "stablelm-12b",
    "hymba-1.5b", "whisper-tiny",
)

VOCAB_PAD = 128  # vocab padded to a multiple (model-axis sharding)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | vlm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention flavors
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_fraction: float = 1.0
    rope_theta: float = 500_000.0
    sliding_window: int = 0             # 0 = full attention
    global_attn_every: int = 0          # hymba: 1-in-N layers full attn

    # moe
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "gather"        # "gather" | "dense" (§Perf)
    moe_groups: int = 1                 # == dp degree for local routing

    # ssm / hybrid
    ssm_state: int = 0
    ssm_proj_factor: float = 2.0
    slstm_every: int = 0                # xlstm: 1-in-N layers sLSTM
    meta_tokens: int = 0                # hymba

    # vlm
    cross_attn_every: int = 0           # 1-in-N layers cross-attn
    vision_tokens: int = 0
    vision_dim: int = 0

    # audio enc-dec
    encoder_layers: int = 0
    encoder_seq: int = 0

    # numerics / the paper's techniques as first-class switches
    dtype: str = "bfloat16"
    quantize_dense: bool = False        # LIN-HYB analogue (int8 linears)
    lut_activations: bool = False       # LOG-LUT analogue
    activation: str = "silu"

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "full"                 # "full" | "none"
    fsdp: bool = False                  # weight sharding over data axes
    tp_dense: bool = True               # False: replicate backbone weights
    #                                     (pure DP+ZeRO; small ssm models)
    kv_cache_bits: int = 16             # 8: int8 KV cache (paper technique
    #                                     on the decode memory bound, §Perf)
    source: str = ""                    # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // VOCAB_PAD) * VOCAB_PAD

    def layer_pattern(self) -> Tuple[str, ...]:
        """Per-layer block types; the trainer scans over the repeating unit."""
        if self.family == "moe":
            return ("moe",) * self.n_layers
        if self.family == "ssm":
            if self.slstm_every:
                unit = ["mlstm"] * (self.slstm_every - 1) + ["slstm"]
                reps = self.n_layers // self.slstm_every
                assert reps * self.slstm_every == self.n_layers
                return tuple(unit) * reps
            return ("mlstm",) * self.n_layers
        if self.family == "vlm":
            e = self.cross_attn_every
            unit = ["attn"] * (e - 1) + ["cross"]
            reps = self.n_layers // e
            assert reps * e == self.n_layers
            return tuple(unit) * reps
        if self.family == "hybrid":
            return ("hymba",) * self.n_layers
        return ("attn",) * self.n_layers

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer sliding window (0 = full)."""
        if not self.sliding_window:
            return (0,) * self.n_layers
        wins = []
        for i in range(self.n_layers):
            is_global = (self.global_attn_every and
                         (i == 0 or i == self.n_layers - 1
                          or i == self.n_layers // 2))
            wins.append(0 if is_global else self.sliding_window)
        return tuple(wins)

    def reduced(self, **overrides) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=self._reduced_layers(),
            d_model=128,
            n_heads=4, n_kv_heads=2,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            dtype="float32",
            remat="none",
        )
        if self.n_experts:
            # dropless capacity so prefill/decode == teacher-forced forward
            # exactly (capacity drops are batch-composition-dependent in
            # the full configs — an accepted MoE property)
            base.update(n_experts=4, n_experts_per_tok=2, moe_d_ff=64,
                        shared_expert_d_ff=64 if self.shared_expert_d_ff
                        else 0, moe_capacity_factor=8.0)
        if self.family == "vlm":
            base.update(cross_attn_every=self.cross_attn_every,
                        vision_tokens=16, vision_dim=64)
        if self.family == "audio":
            base.update(encoder_layers=2, encoder_seq=32,
                        n_heads=4, n_kv_heads=4)
        if self.family == "hybrid":
            base.update(n_heads=5, n_kv_heads=1, meta_tokens=8,
                        sliding_window=self.sliding_window and 32,
                        ssm_state=8)
        if self.family == "ssm":
            base.update(ssm_state=min(self.ssm_state, 8) or 0,
                        n_heads=4, n_kv_heads=4)
        base.update(overrides)
        return dataclasses.replace(self, **base)

    def _reduced_layers(self) -> int:
        if self.family == "vlm":
            return self.cross_attn_every          # one unit
        if self.family == "ssm" and self.slstm_every:
            return self.slstm_every
        return 2


def get_config(arch_id: str) -> ArchConfig:
    """Load ``repro/configs/<id>.py`` (dashes/dots -> underscores)."""
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
