"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (7:1), matrix-memory recurrence.
[arXiv:2405.04517; unverified]  d_ff=0: the mLSTM block's x2 up-projection
replaces the FFN (xLSTM block design)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    slstm_every=8,           # xLSTM[7:1]: every 8th block is sLSTM
    ssm_proj_factor=2.0, ssm_state=0,
    source="arXiv:2405.04517; unverified",
)
