"""dbrx-132b [moe] — 16 experts top-4, fine-grained MoE.
[hf:databricks/dbrx-base; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100_352,
    n_experts=16, n_experts_per_tok=4, moe_d_ff=10752,
    moe_groups=16,
    rope_theta=500_000.0,
    fsdp=True,  # 264 GB of bf16 weights: replicated-over-data won't fit
    source="hf:databricks/dbrx-base; unverified",
)
