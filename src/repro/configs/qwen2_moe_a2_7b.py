"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151_936,
    n_experts=60, n_experts_per_tok=4, moe_d_ff=1408,
    shared_expert_d_ff=5632,  # 4 shared experts fused: 4 x 1408
    qkv_bias=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
