"""Batched serving engine: slot-based continuous batching.

A fixed pool of B slots shares one static-shape KV cache bundle.  Requests
queue up; free slots are filled via prefill, then all active slots decode
in lockstep (one ``serve_step`` per token across the batch).  Finished
sequences (EOS or max tokens) free their slot for the next request —
the standard continuous-batching pattern with JAX-friendly static shapes.

Simplification vs. vLLM-class engines: slot caches are contiguous per-slot
regions rather than paged blocks; a paged allocator is a §Perf note, not a
correctness requirement at this scale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # int32 [len]
    max_new_tokens: int = 32
    eos_id: int = -1             # -1: never
    # filled by the engine:
    output: Optional[list] = None
    done: bool = False


class ServeEngine:
    """model: models.api.Model; decode batch = number of slots."""

    def __init__(self, model, params, *, n_slots: int = 4,
                 max_seq: int = 256, greedy: bool = True):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c))

    def run(self, requests: List[Request]) -> List[Request]:
        """Processes all requests to completion; returns them with
        ``output`` filled."""
        pending = list(requests)
        for r in pending:
            r.output = []
        # simple scheduling: waves of up to n_slots concurrent requests
        active: List[Request] = []
        caches = [None] * self.n_slots
        tokens = np.zeros((self.n_slots, 1), np.int32)
        remaining = np.zeros(self.n_slots, np.int32)

        while pending or active:
            # fill free slots (prefill one request at a time; a production
            # engine would batch same-length prefills)
            while pending and len(active) < self.n_slots:
                req = pending.pop(0)
                slot = len(active)
                prompt = jnp.asarray(req.prompt[None])
                logits, cache = self.model.prefill(
                    self.params, {"tokens": prompt}, max_seq=self.max_seq)
                tok = self._pick(logits[:, -1])
                req.output.append(int(tok[0]))
                caches[slot] = cache
                tokens[slot, 0] = int(tok[0])
                remaining[slot] = req.max_new_tokens - 1
                active.append(req)

            if not active:
                break
            # lockstep decode across active slots (slot-batched decode is
            # exercised with n_slots=1..B; batched-cache stacking is the
            # natural extension on TPU)
            for slot, req in list(enumerate(active)):
                logits, caches[slot] = self._decode(
                    self.params, jnp.asarray(tokens[slot: slot + 1]),
                    caches[slot])
                tok = int(self._pick(logits[:, -1])[0])
                req.output.append(tok)
                tokens[slot, 0] = tok
                remaining[slot] -= 1
                if remaining[slot] <= 0 or tok == req.eos_id:
                    req.done = True
            # compact finished slots
            keep = [i for i, r in enumerate(active) if not r.done]
            active = [active[i] for i in keep]
            caches = [caches[i] for i in keep] + \
                [None] * (self.n_slots - len(keep))
            tokens = np.concatenate(
                [tokens[keep], np.zeros((self.n_slots - len(keep), 1),
                                        np.int32)])
            remaining = np.concatenate(
                [remaining[keep],
                 np.zeros(self.n_slots - len(keep), np.int32)])
        return requests

    def _pick(self, logits: jnp.ndarray) -> np.ndarray:
        v = self.model.cfg.vocab_size
        return np.asarray(jnp.argmax(logits[..., :v], axis=-1),
                          np.int32)
