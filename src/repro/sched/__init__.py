"""Multi-tenant PIM job scheduling (DESIGN.md §7).

The subsystem that turns the workload-session API into a concurrent
training service: :class:`BankAllocator` carves the cores axis into
rank-aligned :class:`PimSlice` views (the UPMEM rank-allocation model,
paper §2.2); :class:`PimScheduler` queues jobs by priority, admits them
by capacity, gang-steps all running fits round-robin on one host
thread, and fuses eligible GD sweeps into one batched kernel launch per
step (:mod:`repro.sched.gang`); :mod:`repro.sched.manifest` is the
declarative front end the ``repro.launch.pim_jobs`` CLI drives.

Elastic job runtime (DESIGN.md §11, :mod:`repro.elastic`): jobs
checkpoint their trainer carry at chunk boundaries, preempt and resume
across leases/schedulers/Systems, survive injected faults via
supervised retry, and a killed queue restarts from its durable
``queue.json`` + per-job snapshots (``pim_jobs --resume``).
"""
from .allocator import (DEFAULT_RANK_SIZE, PLACEMENT_POLICIES, BankAllocator,
                        BankLease, FragmentationStats, PimSlice,
                        default_rank_size)
from .gang import FUSABLE_WORKLOADS, FusedGdSweep, fuse_key, plan_fusion
from .manifest import (dataset_shape, job_report, load_manifest,
                       run_manifest, serve_manifests, submit_manifest)
from .scheduler import JobHandle, JobState, PimScheduler, SloViolation

__all__ = [
    "BankAllocator", "BankLease", "DEFAULT_RANK_SIZE",
    "FUSABLE_WORKLOADS", "FragmentationStats", "FusedGdSweep",
    "JobHandle", "JobState", "PLACEMENT_POLICIES", "PimScheduler",
    "PimSlice", "SloViolation", "dataset_shape",
    "default_rank_size", "fuse_key", "job_report", "load_manifest",
    "plan_fusion", "run_manifest", "serve_manifests", "submit_manifest",
]
