"""Fused gang stepping: K same-shape GD jobs in one kernel launch.

Gang stepping (DESIGN.md §7.3) has two tiers.  The *round-robin* tier —
handled by the scheduler itself — advances each running job's
``fit_steps`` generator one iteration per turn, so K concurrent jobs
interleave on one host thread.  This module implements the *fused* tier:
gradient-descent jobs (LIN/LOG) that share a dataset, version, and every
shape-determining hyperparameter differ only in their host-side update
(the learning rate), so their per-core gradient kernels can be ``vmap``-ed
over a job axis and the whole gang advances with ONE ``map_reduce``
launch per step.  An 8-point learning-rate sweep becomes one batched
dispatch instead of eight — the host<->PIM command overhead the paper
identifies as the serial bottleneck is paid once per step, not once per
job per step.

The fused kernel wraps the *same* per-core function the serial trainers
register (``linreg.build_local_grad`` / ``logreg.build_local_grad``), so
fused and unfused fits cannot drift numerically; for the integer
versions they are bit-identical (asserted by tests/test_sched.py).

Step fusion composes with lane fusion (DESIGN.md §9.3): when the gang's
specs carry ``fuse_steps > 1``, the lane-batched kernel is driven by a
:class:`~repro.core.pim.StepProgram` — K jobs × k iterations advance in
ONE ``lax.scan`` launch, with the ``(K, F)`` lane weights as the donated
carry and a per-lane active mask freezing cancelled lanes on device.

A new workload opts into fusion by (a) exposing a GD-shaped config via
``Workload._config`` and (b) being added to :data:`FUSABLE_WORKLOADS`
with its per-core kernel builder and host update scale — see DESIGN.md
§7.3 for the walkthrough.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..api.registry import FitResult, TrainerSpec, Workload
from ..core import linreg, logreg
from ..core.fixed_point import from_fixed, mul_round_f32
from ..core.linreg import GdResult, _quantize_weights
from ..core.logreg import _gd_version_of


@dataclasses.dataclass(frozen=True)
class _GdFamily:
    """How one workload plugs into the fused step."""

    build_local: Callable          # cfg -> per-core kernel
    kernel_name: Callable          # cfg -> registry name
    grad_scale: Callable           # n_samples -> host update scale
    base_version: Callable         # version -> weight-quantization version


#: workloads eligible for fusion; the registry name of the workload maps
#: to its GD family adapter.  LIN's update uses the 2/n MSE gradient
#: scale, LOG's the 1/n logistic scale (mirroring their fit loops).
FUSABLE_WORKLOADS = {
    "linreg": _GdFamily(
        build_local=linreg.build_local_grad,
        kernel_name=linreg.grad_kernel_name,
        grad_scale=lambda n: 2.0 / n,
        base_version=lambda v: v),
    "logreg": _GdFamily(
        build_local=logreg.build_local_grad,
        kernel_name=logreg.grad_kernel_name,
        grad_scale=lambda n: 1.0 / n,
        base_version=_gd_version_of),
}

#: spec params that may differ between fused lanes: the learning rate is
#: the sweep axis (host-side update only); the seed never reaches the
#: device for full-batch GD.
_LANE_LOCAL_PARAMS = ("lr", "seed")


def fuse_key(workload: Workload, spec: TrainerSpec):
    """Hashable fusion-eligibility key, or None when ``spec`` cannot fuse.

    Jobs fuse iff their keys are equal: same workload, version, and every
    shape/kernel-determining hyperparameter.  Minibatch SGD and history
    recording are excluded — per-lane minibatch offsets would need
    per-lane shard slices (no longer one batched launch) and history
    hooks run per lane anyway.
    """
    if workload.name not in FUSABLE_WORKLOADS:
        return None
    p = dict(spec.params)
    if p.get("minibatch") or p.get("record_every"):
        return None
    shared = tuple(sorted((k, v) for k, v in p.items()
                          if k not in _LANE_LOCAL_PARAMS))
    return (workload.name, spec.version, shared)


class FusedGdSweep:
    """K gradient-descent jobs advanced by one batched launch per step.

    Weights live host-side per lane, exactly as in the serial loop; per
    step the lanes' quantized weights are stacked to ``(K, F)``,
    broadcast once, and the vmapped per-core kernel produces per-lane
    gradients ``{"gw": (K, F), "gb": (K,)}`` in a single ``map_reduce``.
    """

    def __init__(self, workload: Workload, specs: Sequence[TrainerSpec],
                 dataset):
        keys = {fuse_key(workload, s) for s in specs}
        if len(keys) != 1 or None in keys:
            raise ValueError(
                f"specs are not fusable together (keys {keys}); fuse "
                f"only jobs with identical fuse_key")
        self.workload = workload
        self.specs = list(specs)
        self.dataset = dataset
        self.pim = dataset.system
        family = FUSABLE_WORKLOADS[workload.name]
        self.cfgs = [workload._config(s) for s in self.specs]
        cfg0 = self.cfgs[0]
        # weight quantization runs at the collapsed data precision, as in
        # logreg.fit (LUT variants quantize like their int32/hyb base)
        self.base_cfgs = [
            dataclasses.replace(c, version=family.base_version(c.version))
            for c in self.cfgs]
        self.scale = family.grad_scale(dataset.n)
        self.n_iters = cfg0.n_iters
        self.it = 0
        self.k = len(self.specs)
        f = dataset.n_features
        self.w = [np.zeros(f, np.float32) for _ in self.specs]
        # float32 lane biases: the serial trainers accumulate the bias in
        # float32 (a scan carry cannot hold host float64), and bit parity
        # with them requires the gang to match precision
        self.b = np.zeros(self.k, np.float32)
        self.active = [True] * self.k
        #: per-lane float32 update scale, rounded from the float64
        #: product exactly as the serial trainers round theirs
        self._lane_scale = np.asarray(
            [c.lr * self.scale for c in self.cfgs], np.float32)

        self.view = dataset.gd_view(cfg0.version, cfg0.frac_bits,
                                    cfg0.x8_frac)
        local = family.build_local(cfg0)

        def fused(Xc, yc, mc, Wq, Bq):
            return jax.vmap(lambda w, b: local(Xc, yc, mc, w, b))(Wq, Bq)

        self.kernel = self.pim.named_kernel(
            f"sched.fused/K{self.k}/{family.kernel_name(cfg0)}",
            lambda: fused)

        # step fusion x lane fusion: drive the batched kernel from a
        # StepProgram so one launch advances all K lanes k iterations
        self.fuse_steps = max(1, int(getattr(cfg0, "fuse_steps", 1)))
        self._program = None
        self._carry = None      # device-resident lane state between chunks
        if self.fuse_steps > 1:
            prepare, update = self._make_lane_step_fns()
            lrs = ",".join(repr(c.lr) for c in self.cfgs)
            self._program = self.pim.step_program(
                self.kernel, prepare, update,
                name=(f"sched.fusedstep/K{self.k}"
                      f"/{family.kernel_name(cfg0)}/lr{lrs}"
                      f"/n{dataset.n}"))

    @property
    def done(self) -> bool:
        return self.it >= self.n_iters or not any(self.active)

    def _quantize_lanes(self):
        """Batched lane quantization: the serial trainer's own
        ``_quantize_weights`` applied once to the stacked ``(K, F)`` /
        ``(K,)`` lane arrays (it is purely elementwise, so each lane's
        bits equal a serial fit's).  Batching is what makes fusion pay:
        the host-side dispatch cost per step stays O(1) in K — K eager
        per-lane quantize calls would eat the batched-launch saving."""
        return _quantize_weights(self.base_cfgs[0], np.stack(self.w),
                                 np.asarray(self.b, np.float32))

    def _grads_to_float(self, partial):
        """Batched inverse of the lane quantization (elementwise, so
        per-lane rows are bit-identical to the serial trainers'
        device-side dequantize in ``linreg.make_gd_step_fns``)."""
        cfg = self.base_cfgs[0]
        if cfg.version == "fp32":
            return (np.asarray(partial["gw"], np.float32),
                    np.asarray(partial["gb"], np.float32))
        return (np.asarray(from_fixed(jnp.asarray(partial["gw"]),
                                      cfg.frac_bits)),
                np.asarray(from_fixed(jnp.asarray(partial["gb"]),
                                      cfg.frac_bits)))

    def _make_lane_step_fns(self):
        """Lane-batched (prepare, update) for the StepProgram scan —
        per-lane rows bit-identical to the serial trainers' step fns
        (same elementwise quantize, dequantize, barrier'd f32 update)."""
        cfg = self.base_cfgs[0]
        f = cfg.frac_bits
        fp32 = cfg.version == "fp32"

        def prepare(carry):
            W, B, _, _ = carry
            return _quantize_weights(cfg, W, B)

        def update(carry, reduced):
            # ``ls`` (per-lane f32 scale) rides in the carry so
            # mul_round_f32 sees a traced value (see its caveat)
            W, B, act, ls = carry
            if fp32:
                GW = jnp.asarray(reduced["gw"], jnp.float32)
                GB = jnp.asarray(reduced["gb"], jnp.float32)
            else:
                GW = from_fixed(jnp.asarray(reduced["gw"]), f)
                GB = from_fixed(jnp.asarray(reduced["gb"]), f)
            # two-rounding update pinned against FMA contraction, per
            # lane exactly as the serial trainers round (fixed_point.
            # mul_round_f32)
            dW = mul_round_f32(ls[:, None], GW)
            dB = mul_round_f32(ls, GB)
            W = jnp.where(act[:, None], W - dW, W)
            B = jnp.where(act, B - dB, B)
            return (W, B, act, ls), None
        return prepare, update

    def _sync_carry(self) -> None:
        """Adopt the device-resident chunk carry into the host lane
        state (inactive lanes were frozen on device, so adopting every
        row is equivalent to the serial path's skip)."""
        if self._carry is None:
            return
        W = np.asarray(self._carry[0], np.float32)
        self.w = [W[i] for i in range(self.k)]
        self.b = np.asarray(self._carry[1], np.float32)

    def step(self) -> bool:
        """Advance every active lane one GD iteration — or, with
        ``fuse_steps`` set, one whole scan chunk of iterations in a
        single launch; True when done."""
        if self.done:
            return True
        Xs, ys, mask = self.view
        if self._program is not None:
            k = min(self.fuse_steps, self.n_iters - self.it)
            if self._carry is None:
                # built from host state once (and again after a lane
                # cancellation changes the active mask); between chunks
                # the lane weights stay device-resident — no per-chunk
                # host round-trip, that is the point of the engine
                self._carry = (jnp.asarray(np.stack(self.w)),
                               jnp.asarray(self.b),
                               jnp.asarray(self.active),
                               jnp.asarray(self._lane_scale))
            self._carry, _ = self._program.run(self._carry,
                                               (Xs, ys, mask), k)
            self.it += k
            if self.done:
                self._sync_carry()
                self._carry = None
            return self.done
        Wq, Bq = self.pim.broadcast(self._quantize_lanes())
        partial = self.pim.map_reduce(self.kernel, (Xs, ys, mask),
                                      (Wq, Bq))
        gw_all, gb_all = self._grads_to_float(partial)
        for i in range(self.k):
            if not self.active[i]:
                continue
            self.w[i] = self.w[i] - self._lane_scale[i] * gw_all[i]
            self.b[i] = self.b[i] - self._lane_scale[i] * gb_all[i]
        self.it += 1
        return self.done

    def deactivate(self, lane: int) -> None:
        """Stop updating a cancelled lane (the batched kernel still
        computes its gradient — one launch is all-or-nothing — but the
        lane's host state freezes and it reports no result)."""
        self.active[lane] = False
        if self._carry is not None:
            # pull the surviving state back and rebuild the carry next
            # chunk so the new active mask reaches the device
            self._sync_carry()
            self._carry = None

    def lane_state(self, lane: int) -> dict:
        """One lane's chunk-boundary snapshot — the same
        ``{"arrays", "meta"}`` schema the serial GD trainers emit from
        their ``ChunkTick``s (DESIGN.md §11.2), so a preempted gang
        lane resumes as an ordinary single job via
        ``fit_steps(state=...)``.  Gang lanes are bit-identical to
        serial fits, so the resumed trajectory is too.  Call after
        :meth:`deactivate` (which syncs any device-resident carry) or
        between steps; fused specs never record history or draw
        minibatches, so the snapshot carries neither."""
        self._sync_carry()
        return {"arrays": {"w": np.asarray(self.w[lane], np.float32),
                           "b": np.asarray(self.b[lane], np.float32),
                           "s": np.asarray(self._lane_scale[lane],
                                           np.float32)},
                "meta": {"iters": int(self.it), "history": []}}

    def result(self, lane: int) -> Optional[FitResult]:
        if not self.active[lane]:
            return None
        r = GdResult(w=self.w[lane], b=float(self.b[lane]), history=[],
                     n_iters=self.it)
        return FitResult(self.specs[lane], r,
                         {"coef_": r.w, "intercept_": r.b})


def plan_fusion(workload: Workload, specs: Sequence[TrainerSpec]
                ) -> List[List[int]]:
    """Partition spec indices into fusable gangs (singletons stay solo).

    Grouping preserves submission order inside each gang; specs whose
    ``fuse_key`` is None each get their own group.
    """
    groups: dict = {}
    order: List[List[int]] = []
    for i, spec in enumerate(specs):
        key = fuse_key(workload, spec)
        if key is None:
            order.append([i])
            continue
        if key not in groups:
            groups[key] = []
            order.append(groups[key])
        groups[key].append(i)
    return order
