"""Job manifests: declarative YAML/JSON input for the training service.

A manifest names one PIM system, a set of synthetic datasets, and the
jobs/sweeps to run over them; :func:`run_manifest` builds the
:class:`~repro.sched.scheduler.PimScheduler`, submits everything, drains
the queue, and returns the handles.  This is the programmatic core of
the ``repro.launch.pim_jobs`` CLI (DESIGN.md §7.4).

Schema (all sections optional except ``jobs``/``sweeps`` — at least one)::

    system:   {kind: pim|host|gpu-model, cores: 64, rank_size: 16,
               reduce: fabric, backfill: false,
               placement: first_fit|contention,
               policy: fifo|deadline}
    slo:      {max_modeled_seconds: X}   # admission control (§14.3)
    priority: N     # spool-lane priority in serve mode (§14.4): higher
                    # admits first within a scan; default 0
    datasets: {name: {kind: linear|classification|blobs|recsys,
                      samples: N, features: F, seed: S, ...}}
    jobs:     [{workload: linreg, version: int32, dataset: name,
                cores: 16, priority: 0, params: {lr: 0.1, ...},
                deadline_seconds: X, max_modeled_seconds: X}]
    sweeps:   [{workload: linreg, dataset: name, grid: {lr: [...]},
                fused: true, cores: 16, params: {...}}]

YAML input needs PyYAML; JSON always works (a ``.json`` manifest or any
file whose text parses as JSON).

Service mode (DESIGN.md §14.4): :func:`submit_manifest` admits one
manifest onto an existing — possibly serving — scheduler, so new
manifests land mid-flight while earlier ones still drain;
:func:`serve_manifests` is the long-running spool-directory watcher
behind ``pim_jobs --serve``.  Admission control happens *before*
anything is queued: a manifest whose modeled makespan lower bound
exceeds its ``slo.max_modeled_seconds`` (or the service default) is
rejected whole with :class:`~repro.sched.scheduler.SloViolation` —
a first-class outcome the callers report, never a crash.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..data.synthetic import (make_blobs, make_classification,
                              make_linear_dataset, make_recsys)
from ..systems import System, make_system
from .scheduler import JobHandle, PimScheduler, SloViolation, _SingleRun


def load_manifest(path: str) -> dict:
    """Parse a YAML or JSON manifest file into a dict."""
    with open(path) as fh:
        text = fh.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        try:
            import yaml
        except ImportError:
            raise ValueError(
                f"{path} is not JSON and PyYAML is unavailable in this "
                f"environment; rewrite the manifest as JSON") from None
        doc = yaml.safe_load(text)
    if not isinstance(doc, dict):
        raise ValueError(f"manifest {path} must be a mapping, "
                         f"got {type(doc).__name__}")
    return doc


def dataset_shape(spec: dict) -> Tuple[int, int]:
    """(samples, features) a ``datasets:`` entry would materialize —
    the shape-only view :meth:`PimScheduler.capacity_estimate` prices
    manifests from without building any arrays."""
    return int(spec.get("samples", 4096)), int(spec.get("features", 16))


def build_dataset(spec: dict) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Materialize one ``datasets:`` entry as host (X, y) arrays."""
    spec = dict(spec)
    kind = spec.pop("kind", "linear")
    n = int(spec.pop("samples", 4096))
    f = int(spec.pop("features", 16))
    seed = int(spec.pop("seed", 0))
    if kind == "linear":
        X, y, _ = make_linear_dataset(n, f, seed=seed, **spec)
        return X, y
    if kind == "classification":
        X, y = make_classification(n, f, seed=seed, **spec)
        return X, y
    if kind == "blobs":
        X, _, _ = make_blobs(n, f, seed=seed, **spec)
        return X, None
    if kind == "recsys":
        # EMB input (DESIGN.md §15): Zipf-skewed (user, item, rating)
        # triples; `features` does not apply (the pair width is 2)
        return make_recsys(n, seed=seed, **spec)
    raise ValueError(f"unknown dataset kind {kind!r}; "
                     f"known: linear, classification, blobs, recsys")


def build_system(spec: Optional[dict]) -> Tuple[System, dict]:
    """``system:`` entry -> (System, scheduler kwargs).

    ``kind: pim | host | gpu-model`` selects the execution target
    (default pim — DESIGN.md §10); the remaining keys fill its config."""
    spec = dict(spec or {})
    kind = str(spec.pop("kind", "pim"))
    kwargs = dict(n_cores=int(spec.pop("cores", 64)),
                  n_threads=int(spec.pop("threads", 16)),
                  reduce=spec.pop("reduce", "fabric"))
    backend = spec.pop("backend", None)
    if backend is not None:
        if kind != "pim":
            raise ValueError(
                f"system backend: {backend!r} only applies to kind: pim "
                f"(a {kind!r} target always runs single-image)")
        kwargs["backend"] = backend
    sched_kw = {}
    if "rank_size" in spec:
        sched_kw["rank_size"] = int(spec.pop("rank_size"))
    if "backfill" in spec:
        sched_kw["backfill"] = bool(spec.pop("backfill"))
    if "placement" in spec:
        sched_kw["placement"] = str(spec.pop("placement"))
    if "policy" in spec:
        sched_kw["policy"] = str(spec.pop("policy"))
    if spec:
        raise ValueError(f"unknown system keys {sorted(spec)}")
    return make_system(kind, **kwargs), sched_kw


def submit_manifest(scheduler: PimScheduler, doc: dict, *,
                    max_modeled_seconds: Optional[float] = None,
                    ) -> List[JobHandle]:
    """Admission-check one manifest and submit its jobs/sweeps onto an
    existing scheduler — the mid-flight entry point of serve mode
    (DESIGN.md §14.4): the scheduler may already be draining earlier
    manifests when this one lands.

    SLO admission control (§14.3) runs *first*: when the manifest's
    ``slo.max_modeled_seconds`` (or the ``max_modeled_seconds`` service
    default — the manifest's own knob wins) is set and the
    :meth:`~PimScheduler.capacity_estimate` makespan lower bound
    exceeds it, the whole manifest is rejected with
    :class:`SloViolation` and *nothing* is queued — no partial
    admission.  Per-job entries may additionally carry
    ``deadline_seconds`` / ``max_modeled_seconds``, forwarded to
    :meth:`~PimScheduler.submit` (a per-job SLO rejection comes back as
    a FAILED handle, not an exception).

    Returns the new handles in manifest order (jobs first, then sweep
    points in grid order).
    """
    slo = doc.get("slo") or {}
    bound = slo.get("max_modeled_seconds", max_modeled_seconds)
    if bound is not None:
        est = scheduler.capacity_estimate(doc)["makespan_lower_bound"]
        if est > float(bound):
            scheduler.metrics.counter(
                "sched.manifest_slo_rejections").inc()
            raise SloViolation(
                f"manifest: modeled makespan lower bound {est:.4g}s "
                f"exceeds max_modeled_seconds={float(bound):.4g}")

    datasets: Dict[str, tuple] = {
        name: build_dataset(spec)
        for name, spec in (doc.get("datasets") or {}).items()}

    def _data(entry: dict):
        name = entry.get("dataset")
        if name is None:
            if len(datasets) == 1:
                return next(iter(datasets.values()))
            raise ValueError(f"job {entry} names no dataset and the "
                             f"manifest defines {len(datasets)}")
        try:
            return datasets[name]
        except KeyError:
            raise ValueError(f"job references unknown dataset {name!r}; "
                             f"known: {sorted(datasets)}") from None

    handles: List[JobHandle] = []
    for entry in doc.get("jobs") or []:
        handles.append(scheduler.submit(
            entry["workload"], _data(entry),
            version=entry.get("version"),
            n_cores=entry.get("cores"),
            priority=int(entry.get("priority", 0)),
            name=entry.get("name"),
            deadline_seconds=entry.get("deadline_seconds"),
            max_modeled_seconds=entry.get("max_modeled_seconds"),
            **(entry.get("params") or {})))
    for entry in doc.get("sweeps") or []:
        handles.extend(scheduler.sweep(
            entry["workload"], _data(entry), entry["grid"],
            version=entry.get("version"),
            n_cores=entry.get("cores"),
            fused=bool(entry.get("fused", True)),
            priority=int(entry.get("priority", 0)),
            **(entry.get("params") or {})))
    if not handles:
        raise ValueError("manifest defines no jobs or sweeps")
    return handles


def run_manifest(doc: dict, drain: bool = True, *,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1,
                 resume: bool = False,
                 retry_budget: int = 0,
                 max_modeled_seconds: Optional[float] = None,
                 ) -> Tuple[PimScheduler, List[JobHandle]]:
    """Build the scheduler, submit every job and sweep, optionally drain.

    Returns the scheduler and the handles in manifest order (jobs first,
    then sweep points in grid order).

    Elastic knobs (DESIGN.md §11): ``checkpoint_dir`` makes the run
    crash-survivable — per-job chunk-boundary checkpoints every
    ``checkpoint_every`` scheduling steps plus an atomic ``queue.json``
    record of every job's state.  ``resume=True`` replays a previous
    (possibly killed) run from that directory: finished jobs are marked
    restored without re-running; unfinished jobs continue from their
    last durable snapshot (fingerprint-validated, migration-checked).
    ``retry_budget`` is the per-job supervised-retry default.

    ``max_modeled_seconds`` is the service-default admission SLO
    (§14.3, overridable by the manifest's own ``slo`` section); a
    rejected manifest raises :class:`SloViolation` before anything is
    built or queued.
    """
    system, sched_kw = build_system(doc.get("system"))
    scheduler = PimScheduler(system,
                             checkpoint_dir=checkpoint_dir,
                             checkpoint_every=checkpoint_every,
                             default_retry_budget=retry_budget,
                             **sched_kw)
    handles = submit_manifest(scheduler, doc,
                              max_modeled_seconds=max_modeled_seconds)
    if resume and checkpoint_dir is not None:
        _restore_jobs(scheduler, handles, checkpoint_dir)
    if drain:
        scheduler.drain()
    return scheduler, handles


#: manifest filename suffixes the spool watcher picks up
_SPOOL_SUFFIXES = (".json", ".yaml", ".yml")


def _write_status(path: str, record: dict) -> None:
    """Atomic ``<manifest>.status.json`` sidecar: the spool watcher's
    durable accepted/rejected verdict (also its already-processed
    marker across restarts — the manifest file itself is never
    touched)."""
    tmp = path + ".status.json.tmp"
    with open(tmp, "w") as fh:
        json.dump(record, fh, indent=1)
    os.replace(tmp, path + ".status.json")


def serve_manifests(scheduler: PimScheduler, spool_dir: str, *,
                    poll_interval: float = 0.2,
                    idle_timeout: Optional[float] = 10.0,
                    max_modeled_seconds: Optional[float] = None,
                    handles: Optional[List[JobHandle]] = None,
                    ) -> List[dict]:
    """Long-running service front end (DESIGN.md §14.4): watch
    ``spool_dir`` for manifest files and admit each onto the serving
    scheduler as it appears — new manifests land mid-flight while
    earlier ones drain in the background.

    Each manifest file (``.json``/``.yaml``/``.yml``) is processed once
    and answered with an atomic ``<name>.status.json`` sidecar:
    ``accepted`` with its job count, or ``rejected`` with the reason —
    an SLO violation or a malformed manifest fails *that manifest*,
    never the service.

    Ordering: within one scan, new manifests admit by ``(-priority,
    name)`` — a top-level ``priority:`` integer in the manifest jumps
    the FIFO name order (the spool-side priority lane; per-job
    ``priority:`` entries still order execution *inside* the
    scheduler).  Unmarked manifests default to priority 0.

    Restart resilience (DESIGN.md §11.5): the sidecar doubles as the
    durable processed marker, so a restarted watcher *replays* the
    recorded verdict of an already-answered manifest — the record
    returns (tagged ``"replayed": true``) without re-admitting or
    re-running anything, mirroring how ``--resume`` replays finished
    jobs from ``queue.json``.

    Returns when the spool has produced no new manifest and the
    scheduler has been idle (nothing queued or running) for
    ``idle_timeout`` seconds (None = watch forever), with one record
    per processed manifest.  ``handles`` — when given — collects every
    accepted manifest's handles in place.  Starts the serve loop if the
    scheduler is not already serving; the caller owns ``shutdown()``.
    """
    if not scheduler.serving:
        scheduler.serve()
    records: List[dict] = []
    seen: set = set()
    idle_since = time.monotonic()
    while True:
        progressed = False
        try:
            names = sorted(os.listdir(spool_dir))
        except FileNotFoundError:
            names = []
        fresh: list = []
        for name in names:
            if (not name.endswith(_SPOOL_SUFFIXES)
                    or name.endswith(".status.json")):
                continue   # not a manifest / our own answer sidecar
            path = os.path.join(spool_dir, name)
            if path in seen:
                continue
            seen.add(path)
            if os.path.exists(path + ".status.json"):
                # restarted watcher: replay the durable verdict instead
                # of re-running the manifest (§11.5 crash recovery)
                try:
                    with open(path + ".status.json") as fh:
                        old = json.load(fh)
                except (OSError, json.JSONDecodeError):
                    old = {"path": path, "state": "unknown"}
                old["replayed"] = True
                records.append(old)
                continue
            # peek the manifest-level priority; a load failure is a
            # per-manifest verdict, deferred to the admission step
            try:
                doc: object = load_manifest(path)
            except (ValueError, KeyError) as err:
                doc = err
            try:
                prio = int(doc.get("priority", 0)) if isinstance(
                    doc, dict) else 0
            except (TypeError, ValueError):
                prio = 0
            fresh.append((-prio, name, path, doc))
        # the priority lane: per scan, higher `priority:` manifests
        # admit first, name order breaking ties
        for nprio, _name, path, doc in sorted(fresh,
                                              key=lambda t: t[:2]):
            progressed = True
            try:
                if isinstance(doc, Exception):
                    raise doc
                new = submit_manifest(
                    scheduler, doc,
                    max_modeled_seconds=max_modeled_seconds)
                record = {"path": path, "state": "accepted",
                          "jobs": len(new), "priority": -nprio}
                if handles is not None:
                    handles.extend(new)
            except (SloViolation, ValueError, KeyError) as err:
                record = {"path": path, "state": "rejected",
                          "reason": f"{type(err).__name__}: {err}"}
            records.append(record)
            _write_status(path, record)
        if progressed or not scheduler.idle:
            idle_since = time.monotonic()
        elif (idle_timeout is not None
                and time.monotonic() - idle_since >= idle_timeout):
            return records
        time.sleep(poll_interval)


def _restore_jobs(scheduler: PimScheduler, handles: List[JobHandle],
                  checkpoint_dir: str) -> None:
    """Reconcile freshly-submitted manifest jobs against a killed run's
    ``queue.json`` + per-job checkpoints (crash recovery, DESIGN.md
    §11.5): finished records short-circuit via ``mark_restored`` (the
    manifest completes without redoing their work); everything else
    resumes from its last durable snapshot when one exists.  Jobs are
    matched by name — manifest names are stable across runs."""
    from .. import elastic

    queue_path = os.path.join(checkpoint_dir, "queue.json")
    records: Dict[str, dict] = {}
    if os.path.exists(queue_path):
        with open(queue_path) as fh:
            records = {r["name"]: r
                       for r in json.load(fh).get("jobs", [])}
    for h in handles:
        rec = records.get(h.name)
        if rec is not None and rec.get("state") == "done":
            scheduler.mark_restored(h, iters=int(rec.get("iters", 0)),
                                    steps=int(rec.get("steps", 0)))
            continue
        if not isinstance(scheduler._find_run(h), _SingleRun):
            continue    # fused gang members restart with their gang
        job_dir = elastic.job_dir(checkpoint_dir, h.name)
        if elastic.has_checkpoint(job_dir):
            snapshot, envelope = elastic.load_snapshot(job_dir)
            scheduler.attach_resume_state(h, snapshot, envelope)


def job_report(handles: List[JobHandle]) -> List[dict]:
    """JSON-serializable per-job rows for the CLI / bench output."""
    rows = []
    for h in handles:
        row = {
            "id": h.id,
            "name": h.name,
            "workload": h.workload.name,
            "version": h.spec.version,
            "state": h.state.value,
            "priority": h.priority,
            "cores": h.n_cores,
            "steps": h.steps,
            "iters": h.iters,
            "fused": h.fused,
            "modeled_dpu_seconds": h.modeled_seconds,
            # drift accounting (DESIGN.md §13.5): measured chunk wall
            # time next to the cost-model pricing; ratio None when the
            # model never priced this job (non-PIM target)
            "measured_seconds": h.measured_seconds,
            "drift_ratio": h.drift_ratio,
        }
        if h.recoveries:
            row["recoveries"] = h.recoveries
        if h.preemptions:
            row["preemptions"] = h.preemptions
        if h.straggler_flags:
            row["straggler_flags"] = h.straggler_flags
        if h.restored:
            row["restored"] = True
        if h.gpu is not None:
            row["modeled_gpu_seconds"] = h.gpu.modeled_seconds
        if h.transfer is not None:
            row["cpu_to_pim_bytes"] = h.transfer.cpu_to_pim
            row["pim_to_cpu_bytes"] = h.transfer.pim_to_cpu
            row["kernel_launches"] = h.transfer.kernel_launches
        if h.error is not None:
            row["error"] = f"{type(h.error).__name__}: {h.error}"
        rows.append(row)
    return rows
