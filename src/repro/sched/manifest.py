"""Job manifests: declarative YAML/JSON input for the training service.

A manifest names one PIM system, a set of synthetic datasets, and the
jobs/sweeps to run over them; :func:`run_manifest` builds the
:class:`~repro.sched.scheduler.PimScheduler`, submits everything, drains
the queue, and returns the handles.  This is the programmatic core of
the ``repro.launch.pim_jobs`` CLI (DESIGN.md §7.4).

Schema (all sections optional except ``jobs``/``sweeps`` — at least one)::

    system:   {kind: pim|host|gpu-model, cores: 64, rank_size: 16,
               reduce: fabric, backfill: false,
               placement: first_fit|contention}
    datasets: {name: {kind: linear|classification|blobs,
                      samples: N, features: F, seed: S, ...}}
    jobs:     [{workload: linreg, version: int32, dataset: name,
                cores: 16, priority: 0, params: {lr: 0.1, ...}}]
    sweeps:   [{workload: linreg, dataset: name, grid: {lr: [...]},
                fused: true, cores: 16, params: {...}}]

YAML input needs PyYAML; JSON always works (a ``.json`` manifest or any
file whose text parses as JSON).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..data.synthetic import (make_blobs, make_classification,
                              make_linear_dataset)
from ..systems import System, make_system
from .scheduler import JobHandle, PimScheduler, _SingleRun


def load_manifest(path: str) -> dict:
    """Parse a YAML or JSON manifest file into a dict."""
    with open(path) as fh:
        text = fh.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        try:
            import yaml
        except ImportError:
            raise ValueError(
                f"{path} is not JSON and PyYAML is unavailable in this "
                f"environment; rewrite the manifest as JSON") from None
        doc = yaml.safe_load(text)
    if not isinstance(doc, dict):
        raise ValueError(f"manifest {path} must be a mapping, "
                         f"got {type(doc).__name__}")
    return doc


def dataset_shape(spec: dict) -> Tuple[int, int]:
    """(samples, features) a ``datasets:`` entry would materialize —
    the shape-only view :meth:`PimScheduler.capacity_estimate` prices
    manifests from without building any arrays."""
    return int(spec.get("samples", 4096)), int(spec.get("features", 16))


def build_dataset(spec: dict) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Materialize one ``datasets:`` entry as host (X, y) arrays."""
    spec = dict(spec)
    kind = spec.pop("kind", "linear")
    n = int(spec.pop("samples", 4096))
    f = int(spec.pop("features", 16))
    seed = int(spec.pop("seed", 0))
    if kind == "linear":
        X, y, _ = make_linear_dataset(n, f, seed=seed, **spec)
        return X, y
    if kind == "classification":
        X, y = make_classification(n, f, seed=seed, **spec)
        return X, y
    if kind == "blobs":
        X, _, _ = make_blobs(n, f, seed=seed, **spec)
        return X, None
    raise ValueError(f"unknown dataset kind {kind!r}; "
                     f"known: linear, classification, blobs")


def build_system(spec: Optional[dict]) -> Tuple[System, dict]:
    """``system:`` entry -> (System, scheduler kwargs).

    ``kind: pim | host | gpu-model`` selects the execution target
    (default pim — DESIGN.md §10); the remaining keys fill its config."""
    spec = dict(spec or {})
    kind = str(spec.pop("kind", "pim"))
    kwargs = dict(n_cores=int(spec.pop("cores", 64)),
                  n_threads=int(spec.pop("threads", 16)),
                  reduce=spec.pop("reduce", "fabric"))
    backend = spec.pop("backend", None)
    if backend is not None:
        if kind != "pim":
            raise ValueError(
                f"system backend: {backend!r} only applies to kind: pim "
                f"(a {kind!r} target always runs single-image)")
        kwargs["backend"] = backend
    sched_kw = {}
    if "rank_size" in spec:
        sched_kw["rank_size"] = int(spec.pop("rank_size"))
    if "backfill" in spec:
        sched_kw["backfill"] = bool(spec.pop("backfill"))
    if "placement" in spec:
        sched_kw["placement"] = str(spec.pop("placement"))
    if spec:
        raise ValueError(f"unknown system keys {sorted(spec)}")
    return make_system(kind, **kwargs), sched_kw


def run_manifest(doc: dict, drain: bool = True, *,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1,
                 resume: bool = False,
                 retry_budget: int = 0,
                 ) -> Tuple[PimScheduler, List[JobHandle]]:
    """Build the scheduler, submit every job and sweep, optionally drain.

    Returns the scheduler and the handles in manifest order (jobs first,
    then sweep points in grid order).

    Elastic knobs (DESIGN.md §11): ``checkpoint_dir`` makes the run
    crash-survivable — per-job chunk-boundary checkpoints every
    ``checkpoint_every`` scheduling steps plus an atomic ``queue.json``
    record of every job's state.  ``resume=True`` replays a previous
    (possibly killed) run from that directory: finished jobs are marked
    restored without re-running; unfinished jobs continue from their
    last durable snapshot (fingerprint-validated, migration-checked).
    ``retry_budget`` is the per-job supervised-retry default.
    """
    system, sched_kw = build_system(doc.get("system"))
    scheduler = PimScheduler(system,
                             checkpoint_dir=checkpoint_dir,
                             checkpoint_every=checkpoint_every,
                             default_retry_budget=retry_budget,
                             **sched_kw)
    datasets: Dict[str, tuple] = {
        name: build_dataset(spec)
        for name, spec in (doc.get("datasets") or {}).items()}

    def _data(entry: dict):
        name = entry.get("dataset")
        if name is None:
            if len(datasets) == 1:
                return next(iter(datasets.values()))
            raise ValueError(f"job {entry} names no dataset and the "
                             f"manifest defines {len(datasets)}")
        try:
            return datasets[name]
        except KeyError:
            raise ValueError(f"job references unknown dataset {name!r}; "
                             f"known: {sorted(datasets)}") from None

    handles: List[JobHandle] = []
    for entry in doc.get("jobs") or []:
        handles.append(scheduler.submit(
            entry["workload"], _data(entry),
            version=entry.get("version"),
            n_cores=entry.get("cores"),
            priority=int(entry.get("priority", 0)),
            name=entry.get("name"),
            **(entry.get("params") or {})))
    for entry in doc.get("sweeps") or []:
        handles.extend(scheduler.sweep(
            entry["workload"], _data(entry), entry["grid"],
            version=entry.get("version"),
            n_cores=entry.get("cores"),
            fused=bool(entry.get("fused", True)),
            priority=int(entry.get("priority", 0)),
            **(entry.get("params") or {})))
    if not handles:
        raise ValueError("manifest defines no jobs or sweeps")
    if resume and checkpoint_dir is not None:
        _restore_jobs(scheduler, handles, checkpoint_dir)
    if drain:
        scheduler.drain()
    return scheduler, handles


def _restore_jobs(scheduler: PimScheduler, handles: List[JobHandle],
                  checkpoint_dir: str) -> None:
    """Reconcile freshly-submitted manifest jobs against a killed run's
    ``queue.json`` + per-job checkpoints (crash recovery, DESIGN.md
    §11.5): finished records short-circuit via ``mark_restored`` (the
    manifest completes without redoing their work); everything else
    resumes from its last durable snapshot when one exists.  Jobs are
    matched by name — manifest names are stable across runs."""
    from .. import elastic

    queue_path = os.path.join(checkpoint_dir, "queue.json")
    records: Dict[str, dict] = {}
    if os.path.exists(queue_path):
        with open(queue_path) as fh:
            records = {r["name"]: r
                       for r in json.load(fh).get("jobs", [])}
    for h in handles:
        rec = records.get(h.name)
        if rec is not None and rec.get("state") == "done":
            scheduler.mark_restored(h, iters=int(rec.get("iters", 0)),
                                    steps=int(rec.get("steps", 0)))
            continue
        if not isinstance(scheduler._find_run(h), _SingleRun):
            continue    # fused gang members restart with their gang
        job_dir = elastic.job_dir(checkpoint_dir, h.name)
        if elastic.has_checkpoint(job_dir):
            snapshot, envelope = elastic.load_snapshot(job_dir)
            scheduler.attach_resume_state(h, snapshot, envelope)


def job_report(handles: List[JobHandle]) -> List[dict]:
    """JSON-serializable per-job rows for the CLI / bench output."""
    rows = []
    for h in handles:
        row = {
            "id": h.id,
            "name": h.name,
            "workload": h.workload.name,
            "version": h.spec.version,
            "state": h.state.value,
            "priority": h.priority,
            "cores": h.n_cores,
            "steps": h.steps,
            "iters": h.iters,
            "fused": h.fused,
            "modeled_dpu_seconds": h.modeled_seconds,
            # drift accounting (DESIGN.md §13.5): measured chunk wall
            # time next to the cost-model pricing; ratio None when the
            # model never priced this job (non-PIM target)
            "measured_seconds": h.measured_seconds,
            "drift_ratio": h.drift_ratio,
        }
        if h.recoveries:
            row["recoveries"] = h.recoveries
        if h.preemptions:
            row["preemptions"] = h.preemptions
        if h.straggler_flags:
            row["straggler_flags"] = h.straggler_flags
        if h.restored:
            row["restored"] = True
        if h.gpu is not None:
            row["modeled_gpu_seconds"] = h.gpu.modeled_seconds
        if h.transfer is not None:
            row["cpu_to_pim_bytes"] = h.transfer.cpu_to_pim
            row["pim_to_cpu_bytes"] = h.transfer.pim_to_cpu
            row["kernel_launches"] = h.transfer.kernel_launches
        if h.error is not None:
            row["error"] = f"{type(h.error).__name__}: {h.error}"
        rows.append(row)
    return rows
