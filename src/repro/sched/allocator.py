"""Bank allocation: carving the cores axis into rank-aligned slices.

The paper's UPMEM runtime hands workloads *ranks* of 64 DPUs (§2.2); the
2500+ cores are a pool many jobs share.  :class:`BankAllocator` models
that: the 1-D ``cores`` axis of a :class:`~repro.core.pim.PimSystem` is
carved into rank-aligned extents with first-fit allocation, reclaim with
free-extent coalescing, and fragmentation stats (DESIGN.md §7.1).

:class:`PimSlice` is the execution view of a lease: a sub-``PimSystem``
scoped to the leased cores.  ``shard_rows``/``map_reduce``/``broadcast``
re-scope automatically because the slice *is* a PimSystem with
``n_cores = lease.n_cores`` (and, under the shard_map backend, a mesh
over exactly the leased devices) — existing trainers run unmodified on a
fraction of the machine.  Slice ``TransferStats`` are slice-local and
mirror every increment into the parent system's counters, so global
accounting keeps working while per-job deltas stay attributable.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..systems import PimSystem, TransferStats
from ..systems.base import _MirrorStats

#: UPMEM hands workloads DPUs in ranks of 64 (paper §2.2).
DEFAULT_RANK_SIZE = 64


def default_rank_size(n_cores: int) -> int:
    """The auto-selected rank: the largest divisor of ``n_cores`` not
    exceeding the UPMEM rank of 64.  This is what "default 64, clamped
    to the machine" means for core counts that are not multiples of 64
    (96 -> 48, 100 -> 50, 2556 -> 36): the carving stays rank-aligned
    without the caller having to pick a rank by hand."""
    if n_cores <= 0:
        raise ValueError(f"n_cores must be positive, got {n_cores}")
    for rank in range(min(DEFAULT_RANK_SIZE, n_cores), 0, -1):
        if n_cores % rank == 0:
            return rank
    return 1  # pragma: no cover — rank 1 always divides


@dataclasses.dataclass(frozen=True)
class BankLease:
    """A granted, rank-aligned extent of the cores axis."""

    start: int
    n_cores: int

    @property
    def stop(self) -> int:
        return self.start + self.n_cores


@dataclasses.dataclass(frozen=True)
class FragmentationStats:
    """Allocator occupancy snapshot (DESIGN.md §7.1)."""

    total_cores: int
    free_cores: int
    n_leases: int
    n_free_extents: int
    largest_free_extent: int
    #: 1 - largest_free/free: 0 = one contiguous hole, ->1 = shattered
    external_fragmentation: float

    @property
    def used_cores(self) -> int:
        return self.total_cores - self.free_cores


class BankAllocator:
    """First-fit allocator over a 1-D core axis with rank granularity.

    Invariants (asserted by tests/test_sched.py):
      * every lease is rank-aligned: ``start`` and ``n_cores`` are
        multiples of ``rank_size`` (requests round UP to whole ranks,
        mirroring UPMEM's rank-granular DPU allocation);
      * live leases never overlap;
      * free extents are kept sorted and coalesced, so releasing every
        lease always restores one maximal extent ``[0, n_cores)``.
    """

    def __init__(self, n_cores: int,
                 rank_size: Optional[int] = None):
        if n_cores <= 0:
            raise ValueError(f"n_cores must be positive, got {n_cores}")
        if rank_size is None:
            rank_size = default_rank_size(n_cores)
        else:
            rank_size = min(rank_size, n_cores)
            if rank_size <= 0 or n_cores % rank_size:
                raise ValueError(
                    f"rank_size {rank_size} must be positive and divide "
                    f"n_cores {n_cores} (rank-aligned carving)")
        self.n_cores = n_cores
        self.rank_size = rank_size
        self._free: List[tuple] = [(0, n_cores)]   # sorted (start, size)
        self._leases: dict[int, BankLease] = {}

    def align(self, n_cores: Optional[int]) -> int:
        """Round a request up to whole ranks (None = one rank)."""
        if n_cores is None:
            return self.rank_size
        if n_cores <= 0:
            raise ValueError(f"requested n_cores must be positive, "
                             f"got {n_cores}")
        ranks = -(-n_cores // self.rank_size)
        return ranks * self.rank_size

    def allocate(self, n_cores: Optional[int] = None) -> Optional[BankLease]:
        """First-fit a rank-aligned lease; None when nothing fits.

        Requests larger than the whole machine raise — they could never
        be satisfied and would livelock any admission loop."""
        size = self.align(n_cores)
        if size > self.n_cores:
            raise ValueError(
                f"request for {size} cores (rank-aligned) exceeds the "
                f"machine ({self.n_cores} cores)")
        for i, (start, extent) in enumerate(self._free):
            if extent >= size:
                lease = BankLease(start, size)
                if extent == size:
                    del self._free[i]
                else:
                    self._free[i] = (start + size, extent - size)
                self._leases[lease.start] = lease
                return lease
        return None

    def release(self, lease: BankLease) -> None:
        """Reclaim a lease, coalescing adjacent free extents."""
        if self._leases.pop(lease.start, None) != lease:
            raise ValueError(f"lease {lease} is not live in this allocator")
        self._free.append((lease.start, lease.n_cores))
        self._free.sort()
        merged: List[tuple] = []
        for start, size in self._free:
            if merged and merged[-1][0] + merged[-1][1] == start:
                merged[-1] = (merged[-1][0], merged[-1][1] + size)
            else:
                merged.append((start, size))
        self._free = merged

    @property
    def free_cores(self) -> int:
        return sum(size for _, size in self._free)

    @property
    def leases(self) -> tuple:
        return tuple(self._leases.values())

    def fragmentation(self) -> FragmentationStats:
        free = self.free_cores
        largest = max((size for _, size in self._free), default=0)
        return FragmentationStats(
            total_cores=self.n_cores,
            free_cores=free,
            n_leases=len(self._leases),
            n_free_extents=len(self._free),
            largest_free_extent=largest,
            external_fragmentation=(1.0 - largest / free) if free else 0.0)


# ---------------------------------------------------------------------------
# Slice view.
# ---------------------------------------------------------------------------

# _MirrorStats moved to repro.systems.base so every System's slice view
# (PimSlice here, HostSlice/GpuModelSlice in repro/systems) shares one
# mirroring implementation; re-exported above for compatibility.


class PimSlice(PimSystem):
    """A rank-aligned sub-view of a parent :class:`PimSystem`.

    The slice is itself a PimSystem whose ``n_cores`` is the lease size,
    so every execution-surface method (``put``/``shard_rows``/
    ``map_reduce``/``broadcast``/named kernels) is automatically scoped
    to the slice and existing trainers run on it unmodified.  Under the
    shard_map backend the slice's mesh covers exactly the leased devices
    ``[lease.start, lease.stop)`` of the parent mesh; under the vmap
    semantic backend the scoping is in the shard shapes and byte
    accounting (there is only one physical device either way).

    Under the vmap backend slices share the parent's named-kernel
    registry and jit cache (compiled steps are mesh-free, and kernel
    names encode every closure parameter, so sharing is safe and a
    K-job sweep compiles each kernel once); shard_map slices keep
    private caches because their mesh is baked into the compiled
    closures.  Slice ``TransferStats`` mirror into the parent's (see
    :class:`_MirrorStats`).
    """

    def __init__(self, parent: PimSystem, lease: BankLease):
        if lease.stop > parent.config.n_cores:
            raise ValueError(f"lease {lease} exceeds the parent system "
                             f"({parent.config.n_cores} cores)")
        self.parent = parent
        self.lease = lease
        devices = None
        if parent._mesh is not None:
            devices = list(
                parent._mesh.devices.ravel()[lease.start:lease.stop])
        cfg = dataclasses.replace(parent.config, n_cores=lease.n_cores)
        super().__init__(cfg, devices=devices)
        self.stats = _MirrorStats(parent.stats)
        if self._mesh is None:
            # vmap semantic backend: compiled steps are mesh-free pure
            # functions of their arguments, so slices share the parent's
            # kernel registry and jit cache — K same-shape jobs compile
            # each kernel once, not K times.  (shard_map slices keep
            # private caches: their mesh is baked into the closures.)
            self._kernels = parent._kernels
            self._kernel_gen = parent._kernel_gen
            self._jit_cache = parent._jit_cache
