"""Bank allocation: carving the cores axis into rank-aligned slices.

The paper's UPMEM runtime hands workloads *ranks* of 64 DPUs (§2.2); the
2500+ cores are a pool many jobs share.  :class:`BankAllocator` models
that: the 1-D ``cores`` axis of a :class:`~repro.core.pim.PimSystem` is
carved into rank-aligned extents with first-fit allocation, reclaim with
free-extent coalescing, and fragmentation stats (DESIGN.md §7.1).

:class:`PimSlice` is the execution view of a lease: a sub-``PimSystem``
scoped to the leased cores.  ``shard_rows``/``map_reduce``/``broadcast``
re-scope automatically because the slice *is* a PimSystem with
``n_cores = lease.n_cores`` (and, under the shard_map backend, a mesh
over exactly the leased devices) — existing trainers run unmodified on a
fraction of the machine.  Slice ``TransferStats`` are slice-local and
mirror every increment into the parent system's counters, so global
accounting keeps working while per-job deltas stay attributable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..obs.trace import TRACER
from ..systems import PimSystem, TransferStats
from ..systems.base import _MirrorStats
from ..systems.topology import (DEFAULT_DPUS_PER_RANK, PimTopology,
                                default_rank_size)

#: UPMEM hands workloads DPUs in ranks of 64 (paper §2.2).
#: (``default_rank_size`` moved to repro.systems.topology so the cost
#: model's rank tree and the allocator's carving granularity share one
#: definition; re-exported here for compatibility.)
DEFAULT_RANK_SIZE = DEFAULT_DPUS_PER_RANK

#: placement policies (DESIGN.md §12.4): "first_fit" is the historical
#: lowest-address scan; "contention" scores every rank-aligned
#: candidate by predicted channel contention with live leases.
PLACEMENT_POLICIES = ("first_fit", "contention")


@dataclasses.dataclass(frozen=True)
class BankLease:
    """A granted, rank-aligned extent of the cores axis.

    Carries its topology shadow (which physical ranks and memory
    channels the extent touches — DESIGN.md §12.4) so placement can
    score candidates against live leases and the scheduler can report
    rank-straddling tenancy without re-deriving geometry."""

    start: int
    n_cores: int
    #: physical ranks / memory channels this extent touches (filled by
    #: the allocator from its topology; empty for hand-built leases).
    ranks: tuple = ()
    channels: tuple = ()

    @property
    def stop(self) -> int:
        return self.start + self.n_cores

    @property
    def rank_straddling(self) -> bool:
        return len(self.ranks) > 1


@dataclasses.dataclass(frozen=True)
class FragmentationStats:
    """Allocator occupancy snapshot (DESIGN.md §7.1, §12.4)."""

    total_cores: int
    free_cores: int
    n_leases: int
    n_free_extents: int
    largest_free_extent: int
    #: 1 - largest_free/free: 0 = one contiguous hole, ->1 = shattered
    external_fragmentation: float
    #: per-memory-channel occupancy, channel index -> fraction of that
    #: channel's cores currently leased (DESIGN.md §12.4)
    per_channel_occupancy: tuple = ()
    #: live leases spanning more than one physical rank
    rank_straddling_leases: int = 0

    @property
    def used_cores(self) -> int:
        return self.total_cores - self.free_cores


class BankAllocator:
    """Topology-aware allocator over a 1-D core axis with rank granularity.

    Invariants (asserted by tests/test_sched.py and
    tests/test_topology.py):
      * every lease is rank-aligned: ``start`` and ``n_cores`` are
        multiples of ``rank_size`` (requests round UP to whole ranks,
        mirroring UPMEM's rank-granular DPU allocation);
      * live leases never overlap;
      * free extents are kept sorted and coalesced, so releasing every
        lease always restores one maximal extent ``[0, n_cores)``;
      * every lease's ``ranks``/``channels`` footprint is exactly what
        ``topology.footprint(start, n_cores)`` derives from its extent.

    ``placement`` picks the policy (DESIGN.md §12.4):
      "first_fit"   lowest-address extent that fits (historical
                    behavior, the default);
      "contention"  among ALL rank-aligned candidate positions, take
                    the one minimizing (predicted channel contention
                    with live leases, channels spanned, ranks spanned,
                    start) — rank-local beats rank-straddling, quiet
                    channels beat busy ones, and the tuple's final
                    ``start`` term keeps the choice deterministic.
    """

    def __init__(self, n_cores: int,
                 rank_size: Optional[int] = None,
                 topology: Optional[PimTopology] = None,
                 placement: str = "first_fit",
                 trace_track: Optional[str] = None):
        if n_cores <= 0:
            raise ValueError(f"n_cores must be positive, got {n_cores}")
        if rank_size is None:
            rank_size = default_rank_size(n_cores)
        else:
            rank_size = min(rank_size, n_cores)
            if rank_size <= 0 or n_cores % rank_size:
                raise ValueError(
                    f"rank_size {rank_size} must be positive and divide "
                    f"n_cores {n_cores} (rank-aligned carving)")
        if placement not in PLACEMENT_POLICIES:
            raise ValueError(f"unknown placement {placement!r}; "
                             f"known: {PLACEMENT_POLICIES}")
        self.n_cores = n_cores
        self.rank_size = rank_size
        if topology is None:
            # the allocation rank IS the physical rank unless told
            # otherwise — carving granularity and the cost model's rank
            # tree stay in agreement
            topology = PimTopology.for_cores(n_cores,
                                             dpus_per_rank=rank_size)
        self.topology = topology
        self.placement = placement
        #: trace timeline for channel-occupancy counter events (e.g.
        #: ``channels:pim`` from the scheduler); None = no emission
        self.trace_track = trace_track
        self._free: List[tuple] = [(0, n_cores)]   # sorted (start, size)
        self._leases: dict[int, BankLease] = {}

    def _trace_occupancy(self, lease: BankLease) -> None:
        """Sample the occupancy of the channels a lease touches onto
        the allocator's trace track (one counter series per channel —
        the per-memory-channel rows of the Chrome timeline)."""
        if not TRACER.enabled or self.trace_track is None:
            return
        occ = self.channel_occupancy()
        for ch in (lease.channels or tuple(sorted(occ))):
            TRACER.counter(f"channel{ch}.occupancy", occ.get(ch, 0.0),
                           track=self.trace_track)

    def align(self, n_cores: Optional[int]) -> int:
        """Round a request up to whole ranks (None = one rank)."""
        if n_cores is None:
            return self.rank_size
        if n_cores <= 0:
            raise ValueError(f"requested n_cores must be positive, "
                             f"got {n_cores}")
        ranks = -(-n_cores // self.rank_size)
        return ranks * self.rank_size

    def _make_lease(self, start: int, size: int) -> BankLease:
        fp = self.topology.footprint(start, size)
        return BankLease(start, size, ranks=fp.ranks, channels=fp.channels)

    def _take(self, extent_index: int, start: int, size: int) -> BankLease:
        """Carve ``[start, start+size)`` out of free extent
        ``extent_index`` (splitting it into up to two remainders) and
        grant the lease."""
        ext_start, ext_size = self._free[extent_index]
        assert ext_start <= start and start + size <= ext_start + ext_size
        remainders = []
        if start > ext_start:
            remainders.append((ext_start, start - ext_start))
        tail = (ext_start + ext_size) - (start + size)
        if tail:
            remainders.append((start + size, tail))
        self._free[extent_index:extent_index + 1] = remainders
        lease = self._make_lease(start, size)
        self._leases[lease.start] = lease
        self._trace_occupancy(lease)
        return lease

    def _contention_score(self, start: int, size: int) -> tuple:
        """Placement score of a candidate (lower is better): predicted
        channel contention with live leases (how many lease-channel
        tenancies the candidate would share a channel with), then
        channels spanned, ranks spanned, and start for determinism."""
        fp = self.topology.footprint(start, size)
        live: Dict[int, int] = {}
        for lease in self._leases.values():
            for ch in lease.channels:
                live[ch] = live.get(ch, 0) + 1
        contention = sum(live.get(ch, 0) for ch in fp.channels)
        return (contention, len(fp.channels), len(fp.ranks), start)

    def allocate(self, n_cores: Optional[int] = None) -> Optional[BankLease]:
        """Grant a rank-aligned lease by the configured placement
        policy; None when nothing fits.

        Requests larger than the whole machine raise — they could never
        be satisfied and would livelock any admission loop."""
        size = self.align(n_cores)
        if size > self.n_cores:
            raise ValueError(
                f"request for {size} cores (rank-aligned) exceeds the "
                f"machine ({self.n_cores} cores)")
        if self.placement == "first_fit":
            for i, (start, extent) in enumerate(self._free):
                if extent >= size:
                    return self._take(i, start, size)
            return None
        # contention-aware: every rank-aligned start inside every free
        # extent is a candidate; pick the best-scoring one
        best = None
        for i, (start, extent) in enumerate(self._free):
            for j in range((extent - size) // self.rank_size + 1):
                cand = start + j * self.rank_size
                score = self._contention_score(cand, size)
                if best is None or score < best[0]:
                    best = (score, i, cand)
        if best is None:
            return None
        _, extent_index, start = best
        return self._take(extent_index, start, size)

    def release(self, lease: BankLease) -> None:
        """Reclaim a lease, coalescing adjacent free extents."""
        if self._leases.pop(lease.start, None) != lease:
            raise ValueError(f"lease {lease} is not live in this allocator")
        self._free.append((lease.start, lease.n_cores))
        self._free.sort()
        merged: List[tuple] = []
        for start, size in self._free:
            if merged and merged[-1][0] + merged[-1][1] == start:
                merged[-1] = (merged[-1][0], merged[-1][1] + size)
            else:
                merged.append((start, size))
        self._free = merged
        self._trace_occupancy(lease)

    @property
    def free_cores(self) -> int:
        return sum(size for _, size in self._free)

    @property
    def leases(self) -> tuple:
        return tuple(self._leases.values())

    def channel_occupancy(self) -> Dict[int, float]:
        """Per-memory-channel occupancy: channel index -> fraction of
        that channel's cores currently under lease."""
        topo = self.topology
        leased = {ch: 0 for ch in range(topo.n_channels)}
        for lease in self._leases.values():
            for rank in lease.ranks:
                cores = topo.rank_cores(rank, lease.start, lease.n_cores)
                leased[rank // topo.ranks_per_channel] += cores
        out = {}
        for ch in range(topo.n_channels):
            ch_cores = min(topo.cores_per_channel,
                           self.n_cores - ch * topo.cores_per_channel)
            out[ch] = leased[ch] / ch_cores if ch_cores else 0.0
        return out

    def fragmentation(self) -> FragmentationStats:
        free = self.free_cores
        largest = max((size for _, size in self._free), default=0)
        occ = self.channel_occupancy()
        return FragmentationStats(
            total_cores=self.n_cores,
            free_cores=free,
            n_leases=len(self._leases),
            n_free_extents=len(self._free),
            largest_free_extent=largest,
            external_fragmentation=(1.0 - largest / free) if free else 0.0,
            per_channel_occupancy=tuple(occ[ch]
                                        for ch in sorted(occ)),
            rank_straddling_leases=sum(
                1 for lease in self._leases.values()
                if lease.rank_straddling))


# ---------------------------------------------------------------------------
# Slice view.
# ---------------------------------------------------------------------------

# _MirrorStats moved to repro.systems.base so every System's slice view
# (PimSlice here, HostSlice/GpuModelSlice in repro/systems) shares one
# mirroring implementation; re-exported above for compatibility.


class PimSlice(PimSystem):
    """A rank-aligned sub-view of a parent :class:`PimSystem`.

    The slice is itself a PimSystem whose ``n_cores`` is the lease size,
    so every execution-surface method (``put``/``shard_rows``/
    ``map_reduce``/``broadcast``/named kernels) is automatically scoped
    to the slice and existing trainers run on it unmodified.  Under the
    shard_map backend the slice's mesh covers exactly the leased devices
    ``[lease.start, lease.stop)`` of the parent mesh; under the vmap
    semantic backend the scoping is in the shard shapes and byte
    accounting (there is only one physical device either way).

    Under the vmap backend slices share the parent's named-kernel
    registry and jit cache (compiled steps are mesh-free, and kernel
    names encode every closure parameter, so sharing is safe and a
    K-job sweep compiles each kernel once); shard_map slices keep
    private caches because their mesh is baked into the compiled
    closures.  Slice ``TransferStats`` mirror into the parent's (see
    :class:`_MirrorStats`).
    """

    def __init__(self, parent: PimSystem, lease: BankLease):
        if lease.stop > parent.config.n_cores:
            raise ValueError(f"lease {lease} exceeds the parent system "
                             f"({parent.config.n_cores} cores)")
        self.parent = parent
        self.lease = lease
        devices = None
        if parent._mesh is not None:
            devices = list(
                parent._mesh.devices.ravel()[lease.start:lease.stop])
        cfg = dataclasses.replace(parent.config, n_cores=lease.n_cores)
        super().__init__(cfg, devices=devices)
        self.stats = _MirrorStats(parent.stats)
        if self._mesh is None:
            # vmap semantic backend: compiled steps are mesh-free pure
            # functions of their arguments, so slices share the parent's
            # kernel registry and jit cache — K same-shape jobs compile
            # each kernel once, not K times.  (shard_map slices keep
            # private caches: their mesh is baked into the closures.)
            self._kernels = parent._kernels
            self._kernel_gen = parent._kernel_gen
            self._jit_cache = parent._jit_cache
