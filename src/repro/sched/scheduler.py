"""Multi-tenant PIM training-job scheduler (DESIGN.md §7.2).

``PimScheduler`` layers job management on the unified workload API: it
owns a :class:`~repro.sched.allocator.BankAllocator` per parent
:class:`~repro.systems.base.System` (a single PimSystem, or a mixed
``{"pim": ..., "host": ...}`` machine — DESIGN.md §10.3), admits queued
jobs when rank-aligned capacity exists, runs each admitted job on its
own slice (``System.slice``: a
:class:`~repro.sched.allocator.PimSlice` core extent on PIM, a
thread-pool lane scope on a host target), and gang-steps all running
jobs round-robin — one trainer iteration per job per turn — so K
concurrent fits interleave on a single host thread, exactly the way the
UPMEM host serially orchestrates many tenants' rank allocations
(paper §2.2).

Lifecycle: ``QUEUED -> RUNNING -> DONE | FAILED | CANCELLED`` plus the
non-terminal ``PREEMPTED`` detour (DESIGN.md §11): a running job can be
paused at a chunk boundary — its trainer carry snapshotted via the
``ChunkTick`` it last yielded, its lease released — and later resumed
on a fresh lease, a different scheduler, or a different execution
System (migration subject to the elastic compatibility matrix).
Preemption powers priority eviction (``preemptive=True``), allocator
defragmentation (:meth:`PimScheduler.defragment`), and explicit
:meth:`JobHandle.preempt` / :meth:`PimScheduler.resume`.  Failure
is isolated per job: an exception inside one job's step marks that job
FAILED (the exception object rides on the handle) and never unwinds the
drain loop or the other tenants — and jobs with a retry budget are
instead restored from their last in-memory snapshot and continue
(supervised retry, fault-injectable via ``REPRO_INJECT_FAULT`` —
repro/elastic/fault.py).

Accounting: every job records the ``TransferStats`` delta of its slice
(attributable bytes even though jobs interleave — snapshot/delta, see
TransferStats), its step count, and modeled seconds from the
:class:`~repro.systems.topology.HierarchicalCostModel` (steps x
per-iteration kernel + rank-serialized transfer legs — DESIGN.md §12).

Fused gangs: ``sweep(..., fused=True)`` routes same-``fuse_key`` GD jobs
through :class:`~repro.sched.gang.FusedGdSweep` — one slice, one shared
dataset, one batched kernel launch per step for the whole gang.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import json
import math
import os
import threading
import time
from typing import List, Mapping, Optional, Union

from ..api.dataset import PimDataset
from ..api.registry import FitResult, TrainerSpec, Workload, get_workload
from ..elastic import (InjectedFault, check_migration, injector_from_env,
                       job_fingerprint, snapshot_iters)
from ..elastic import checkpoint as elastic_ckpt
from ..obs.metrics import DRIFT_BUCKETS, Histogram, MetricsRegistry
from ..obs.trace import TRACER
from ..systems import (ChunkTick, HierarchicalCostModel, PimTopology,
                       System, TransferStats)
from ..train.fault_tolerance import StragglerMonitor
from .allocator import BankAllocator, BankLease, FragmentationStats, PimSlice
from .gang import FusedGdSweep, plan_fusion


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    #: paused at a chunk boundary, carry snapshotted, lease released;
    #: non-terminal — ``scheduler.resume(handle)`` continues the fit
    PREEMPTED = "preempted"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"

    @property
    def terminal(self) -> bool:
        return self in (JobState.DONE, JobState.FAILED,
                        JobState.CANCELLED)


#: cost-model routing: workload registry name -> (model workload key,
#: version selector).  Unknown workloads simply skip cycle accounting.
_COST_KEYS = {"linreg": "lin", "logreg": "log", "dtree": "dtr",
              "kmeans": "kme", "emb": "emb"}
_COST_VERSIONS = {"dtree": "fp32", "kmeans": "int16"}


def _cost_k(params: dict) -> int:
    """The cost model's free ``k`` knob: cluster count for KME,
    minibatch size for EMB, inert (16) elsewhere."""
    return params.get("n_clusters", params.get("batch", 16))


class SloViolation(RuntimeError):
    """A modeled-time SLO rejected work at admission (DESIGN.md §14.3):
    the cost model priced a job (or a whole manifest's makespan bound)
    above ``max_modeled_seconds``.  Admission control answers *before*
    anything runs, so the rejection is a first-class outcome — it rides
    on ``JobHandle.error`` / the manifest report, never a crash."""


class JobHandle:
    """Caller-facing view of one submitted training job.

    Fields filled in as the job progresses: ``state``, ``steps``
    (scheduling turns taken — with step fusion one turn drains a whole
    ``lax.scan`` chunk), ``iters`` (trainer iterations covered: the
    ``fit_steps`` generators yield how many iterations each turn
    advanced, 1 unfused, up to ``fuse_steps`` fused — DESIGN.md §9.3),
    ``result`` (FitResult on DONE), ``error`` (the exception on FAILED),
    ``transfer`` (the job's attributable TransferStats delta; for fused
    jobs this is the whole gang's delta — they share one slice),
    ``modeled_seconds`` (HierarchicalCostModel step pricing — per-DPU
    kernel plus rank-serialized transfer legs, DESIGN.md §12 — summed
    per iteration),
    and ``lease`` (the core extent while running).

    Elastic accounting (DESIGN.md §11): ``snapshot`` is the last
    materialized chunk-boundary state (the retry/resume source) and
    ``snapshot_kind`` the System kind it was taken on (the migration
    matrix validates against it); ``retry_budget``/``recoveries`` track
    supervised retry, ``preemptions`` counts preempt/resume cycles,
    ``straggler_flags`` the scheduler's per-chunk wall-time outliers,
    ``gpu`` the slice-scoped roofline delta on a gpu-model target, and
    ``restored`` marks a finished job replayed from a crash-surviving
    queue record without re-running.
    """

    def __init__(self, job_id: int, workload: Workload, spec: TrainerSpec,
                 priority: int, n_cores: int, name: Optional[str] = None):
        self.id = job_id
        self.workload = workload
        self.spec = spec
        self.priority = priority
        self.n_cores = n_cores
        self.name = name or f"job{job_id}:{workload.name}/{spec.version}"
        self.target = "pim"     # execution target on a mixed machine
        self.state = JobState.QUEUED
        self.steps = 0
        self.iters = 0
        self.result: Optional[FitResult] = None
        self.error: Optional[BaseException] = None
        self.transfer: Optional[TransferStats] = None
        self.modeled_seconds = 0.0
        #: wall seconds of the scheduling chunks this job was live in
        #: (gang members each see the full shared-chunk time); paired
        #: with ``modeled_seconds`` it yields the drift ratio
        self.measured_seconds = 0.0
        #: per-chunk measured/modeled wall-time ratios (DESIGN.md §13.5)
        self.drift = Histogram(DRIFT_BUCKETS)
        self.lease: Optional[BankLease] = None
        self.fused = False
        self.retry_budget = 0
        self.recoveries = 0
        self.preemptions = 0
        self.straggler_flags = 0
        self.snapshot: Optional[dict] = None
        self.snapshot_kind: Optional[str] = None
        self.fingerprint: Optional[str] = None
        self.gpu = None
        self.restored = False
        #: service-mode latency accounting (time.monotonic seconds,
        #: DESIGN.md §14.2): queue latency = started_at - submitted_at,
        #: completion latency = finished_at - submitted_at.  started_at
        #: is the *first* admission (preempt/resume cycles keep it);
        #: finished_at is stamped at the terminal transition.
        self.submitted_at = time.monotonic()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        #: absolute monotonic deadline under the "deadline" policy
        #: (submit's ``deadline_seconds`` added to ``submitted_at``)
        self.deadline: Optional[float] = None
        self.deadline_missed = False
        self._cancel_requested = False
        self._preempt_requested = False

    @property
    def done(self) -> bool:
        return self.state.terminal

    def cancel(self) -> None:
        """Request cancellation: queued/preempted jobs cancel
        immediately, running jobs at their next gang-step boundary."""
        if not self.done:
            self._cancel_requested = True
            if self.state in (JobState.QUEUED, JobState.PREEMPTED):
                self.state = JobState.CANCELLED

    def preempt(self) -> None:
        """Request preemption at the next chunk boundary: the trainer
        carry is snapshotted, the lease released, and the handle parks
        in PREEMPTED until :meth:`PimScheduler.resume` — on the same
        scheduler, a fresh one, or a different execution target
        (migration per the elastic compatibility matrix, DESIGN.md
        §11.3).  Only meaningful on a RUNNING job; non-resumable
        workloads lose their progress and restart on resume."""
        if self.state is JobState.RUNNING:
            self._preempt_requested = True

    @property
    def queue_latency(self) -> Optional[float]:
        """Seconds from submission to first admission; None while
        queued (or when the job was rejected before ever running)."""
        if self.started_at is None:
            return None
        return self.started_at - self.submitted_at

    @property
    def completion_latency(self) -> Optional[float]:
        """Seconds from submission to the terminal transition; None
        until the job settles."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def drift_ratio(self) -> Optional[float]:
        """Whole-job measured/modeled wall-time ratio — the PR 7
        calibration as a continuously monitored invariant (DESIGN.md
        §13.5).  None when the cost model never priced this job
        (non-PIM target, unknown workload): absence, not a guess."""
        if self.modeled_seconds <= 0.0:
            return None
        return self.measured_seconds / self.modeled_seconds

    def metrics(self) -> dict:
        """The job's telemetry as one JSON-serializable record: progress
        counters, drift accounting, elastic counters, and — when the
        lifecycle settled them — the attributable TransferStats /
        modeled-GPU deltas of its slice."""
        out = {
            "state": self.state.value,
            "target": self.target,
            "steps": self.steps,
            "iters": self.iters,
            "modeled_seconds": self.modeled_seconds,
            "measured_seconds": self.measured_seconds,
            "drift_ratio": self.drift_ratio,
            "drift": self.drift.to_dict(),
            "preemptions": self.preemptions,
            "recoveries": self.recoveries,
            "straggler_flags": self.straggler_flags,
            "queue_latency": self.queue_latency,
            "completion_latency": self.completion_latency,
            "deadline_missed": self.deadline_missed,
        }
        if self.transfer is not None:
            out["transfer"] = dataclasses.asdict(self.transfer)
        if self.gpu is not None:
            out["gpu_model"] = dataclasses.asdict(self.gpu)
        return out

    def __repr__(self) -> str:
        return (f"JobHandle({self.name!r}, {self.state.value}, "
                f"steps={self.steps}, cores={self.n_cores})")


def _modeled_step_seconds(handle: JobHandle, dataset: PimDataset,
                          slice_: System) -> float:
    """Modeled seconds for one training iteration of this job on its
    slice: per-DPU kernel time plus the rank-serialized broadcast/gather
    legs of the slice's own rank tree
    (:meth:`HierarchicalCostModel.step_seconds` — DESIGN.md §12).  0.0
    for workloads outside the paper's cost model, and for jobs running
    on a non-PIM target — DPU cycle accounting is meaningless there."""
    if getattr(slice_, "kind", None) != "pim":
        return 0.0
    wl_key = _COST_KEYS.get(handle.workload.name)
    if wl_key is None:
        return 0.0
    version = _COST_VERSIONS.get(handle.workload.name, handle.spec.version)
    model = HierarchicalCostModel(slice_.topology)
    return model.step_seconds(
        wl_key, version, dataset.n, dataset.n_features,
        n_cores=slice_.config.n_cores, n_threads=slice_.config.n_threads,
        k=_cost_k(handle.spec.params))


def _estimate_job_seconds(workload_name: str, spec: TrainerSpec, data,
                          n_cores: int, system: System) -> float:
    """Submission-time whole-job estimate (iters x step_seconds) from
    the host data shapes alone — the backfill ordering key and the
    ``capacity_estimate`` unit.  0.0 when the cost model cannot price
    the job (unknown workload/version, non-PIM target): such jobs keep
    their plain submission order."""
    if getattr(system, "kind", None) != "pim":
        return 0.0
    wl_key = _COST_KEYS.get(workload_name)
    if wl_key is None:
        return 0.0
    version = _COST_VERSIONS.get(workload_name, spec.version)
    X = data[0]
    n = int(X.shape[0])
    n_features = int(X.shape[1]) if getattr(X, "ndim", 1) > 1 else 1
    topo = getattr(system, "topology", None)
    if topo is None or n_cores > topo.n_cores:
        topo = PimTopology.for_cores(max(n_cores, 1))
    model = HierarchicalCostModel(topo)
    try:
        return model.job_seconds(
            wl_key, version, n, n_features,
            n_iters=int(spec.params.get("n_iters", 100)),
            n_cores=n_cores, n_threads=system.config.n_threads,
            k=_cost_k(spec.params))
    except (KeyError, ValueError):
        return 0.0


# ---------------------------------------------------------------------------
# Runnables: one admitted queue entry (a single job or a fused gang).
# ---------------------------------------------------------------------------

class _Runnable:
    """Base: owns a lease + slice + dataset and advances by one step."""

    def __init__(self, jobs: List[JobHandle], data, priority: int,
                 seq: int, n_cores: int, target: str = "pim"):
        self.jobs = jobs
        self.data = data
        self.priority = priority
        self.seq = seq
        self.n_cores = n_cores
        self.target = target
        #: trace/track label: the job name, or the gang spelled as one
        self.label = (jobs[0].name if len(jobs) == 1
                      else f"gang[{len(jobs)}]:{jobs[0].name}")
        self.lease: Optional[BankLease] = None
        self.slice: Optional[System] = None
        #: modeled whole-job seconds (backfill ordering key; 0.0 when
        #: the cost model cannot price the job)
        self.est_seconds = 0.0
        #: earliest member deadline (EDF admission key under the
        #: "deadline" policy; None sorts last)
        self.deadline: Optional[float] = None
        self._snapshot: Optional[TransferStats] = None
        self._gpu_snapshot = None

    @property
    def live_jobs(self) -> List[JobHandle]:
        return [j for j in self.jobs if not j.done]

    def start(self, system: System, lease: BankLease) -> None:
        self.lease = lease
        # the system hands out its own slice type: PimSlice over a core
        # extent, HostSlice over thread-pool lanes (DESIGN.md §10.3)
        self.slice = system.slice(lease)
        self._snapshot = self.slice.stats.snapshot()
        gpu = getattr(self.slice, "gpu", None)
        self._gpu_snapshot = gpu.snapshot() if gpu is not None else None
        X, y = self.data
        self.dataset = self.slice.put(X, y)
        for job in self.jobs:
            if job.state in (JobState.QUEUED, JobState.PREEMPTED):
                job.state = JobState.RUNNING
                job.lease = lease
                job.n_cores = lease.n_cores
                if job.started_at is None:
                    job.started_at = time.monotonic()

    def _transfer_delta(self) -> TransferStats:
        return self.slice.stats.delta(self._snapshot)

    def _account(self, job: JobHandle) -> None:
        """Settle per-job accounting at a lifecycle boundary: the
        slice's TransferStats delta, and — on a gpu-model target — the
        slice-scoped roofline delta (satellite: per-job modeled-GPU
        attribution via GpuModelReport.delta)."""
        job.transfer = self._transfer_delta()
        if self._gpu_snapshot is not None:
            job.gpu = self.slice.gpu.delta(self._gpu_snapshot)

    def advance(self, sched: "Optional[PimScheduler]" = None) -> bool:
        """One gang step; True when the runnable is finished."""
        raise NotImplementedError


class _SingleRun(_Runnable):
    """One job advanced via its workload's ``fit_steps`` generator.

    The elastic unit of the scheduler (DESIGN.md §11): each yielded
    :class:`~repro.systems.base.ChunkTick` carries a lazy snapshot of
    the trainer carry, so the run can be preempted at any chunk
    boundary, checkpointed on a cadence, retried after a fault from its
    last snapshot, or recreated on another scheduler/System from a
    ``resume_state``."""

    def __init__(self, *args, resume_state: Optional[dict] = None,
                 **kwargs):
        super().__init__(*args, **kwargs)
        self._resume_state = resume_state
        self._last_tick: Optional[ChunkTick] = None

    def _make_gen(self, job: JobHandle, state: Optional[dict]):
        # only pass state= when resuming: legacy/third-party workloads
        # predating the elastic API keep working un-resumed
        if state is None:
            return job.workload.fit_steps(self.dataset, job.spec)
        return job.workload.fit_steps(self.dataset, job.spec, state=state)

    def start(self, system: System, lease: BankLease) -> None:
        super().start(system, lease)
        job = self.jobs[0]
        self.gen = self._make_gen(job, self._resume_state)
        self._last_tick = None
        self._step_seconds = _modeled_step_seconds(job, self.dataset,
                                                   self.slice)

    def _materialize(self, job: JobHandle) -> bool:
        """Snapshot the last chunk boundary onto the handle; False when
        the workload never yielded a resumable tick."""
        tick = self._last_tick
        if tick is None or not tick.resumable:
            return False
        job.snapshot = tick.snapshot()
        job.snapshot_kind = getattr(self.slice, "kind", "pim")
        return True

    def _preempt(self, job: JobHandle,
                 sched: "Optional[PimScheduler]") -> bool:
        job._preempt_requested = False
        self._materialize(job)
        self.gen.close()
        job.state = JobState.PREEMPTED
        job.preemptions += 1
        self._account(job)
        if TRACER.enabled:
            TRACER.instant("preempt", track=f"job:{job.name}",
                           cat="elastic", steps=job.steps, iters=job.iters)
        if sched is not None:
            sched.metrics.counter("sched.preemptions").inc()
            sched._persist_job(job)
        return True

    def _fail_or_retry(self, job: JobHandle, err: BaseException,
                       sched: "Optional[PimScheduler]") -> bool:
        """Supervised retry (train.fault_tolerance semantics applied to
        the scheduler): restore from the job's last snapshot while the
        retry budget lasts; otherwise FAILED."""
        if (job.retry_budget - job.recoveries > 0
                and not job._cancel_requested):
            job.recoveries += 1
            job.error = err          # last fault survives for forensics
            self.gen.close()
            job.iters = snapshot_iters(job.snapshot)
            self.gen = self._make_gen(job, job.snapshot)
            self._last_tick = None
            if TRACER.enabled:
                TRACER.instant("retry", track=f"job:{job.name}",
                               cat="elastic", recoveries=job.recoveries,
                               error=type(err).__name__)
            if sched is not None:
                sched.metrics.counter("sched.retries").inc()
            return False
        job.error = err
        job.state = JobState.FAILED
        self._account(job)
        if TRACER.enabled:
            TRACER.instant("fail", track=f"job:{job.name}", cat="elastic",
                           error=type(err).__name__)
        return True

    def advance(self, sched: "Optional[PimScheduler]" = None) -> bool:
        job = self.jobs[0]
        if job._cancel_requested:
            self.gen.close()
            job.state = JobState.CANCELLED
            self._account(job)
            return True
        if job._preempt_requested:
            return self._preempt(job, sched)
        try:
            if (sched is not None and sched.injector is not None
                    and sched.injector(job.name, job.steps + 1)):
                raise InjectedFault(
                    f"injected fault: job {job.name!r} step "
                    f"{job.steps + 1}")
            advanced = next(self.gen)
        except StopIteration as stop:
            job.result = stop.value
            job.state = JobState.DONE
            self._account(job)
            return True
        except Exception as err:  # noqa: BLE001 — isolation by design
            return self._fail_or_retry(job, err, sched)
        # generators yield the iteration count each turn covered (a
        # fused chunk drains several); tolerate legacy generators that
        # yield something else by charging one iteration
        tick = advanced if isinstance(advanced, ChunkTick) else None
        advanced = advanced if isinstance(advanced, int) and advanced > 0 \
            else 1
        job.steps += 1
        job.iters += advanced
        job.modeled_seconds += advanced * self._step_seconds
        self._last_tick = tick
        if (sched is not None and sched.checkpoint_dir is not None
                and job.steps % max(1, sched.checkpoint_every) == 0
                and self._materialize(job)):
            sched._persist_job(job)
        return False


class _FusedRun(_Runnable):
    """A fused GD gang: one slice, one dataset, one launch per step."""

    def start(self, system: System, lease: BankLease) -> None:
        super().start(system, lease)
        workload = self.jobs[0].workload
        self.gang = FusedGdSweep(workload,
                                 [j.spec for j in self.jobs],
                                 self.dataset)
        self._step_seconds = [
            _modeled_step_seconds(j, self.dataset, self.slice)
            for j in self.jobs]
        for job in self.jobs:
            job.fused = True

    def _finish(self) -> None:
        delta = self._transfer_delta()
        for lane, job in enumerate(self.jobs):
            if job.done or job.state is JobState.PREEMPTED:
                continue
            job.transfer = delta
            result = self.gang.result(lane)
            if result is None:
                job.state = JobState.CANCELLED
            else:
                job.result = result
                job.state = JobState.DONE

    def advance(self, sched: "Optional[PimScheduler]" = None) -> bool:
        for lane, job in enumerate(self.jobs):
            if job._cancel_requested and self.gang.active[lane]:
                self.gang.deactivate(lane)
                job.state = JobState.CANCELLED
                job.transfer = self._transfer_delta()
            elif job._preempt_requested and self.gang.active[lane]:
                # a fused lane leaves its gang: carry synced out via
                # lane_state, lane deactivated; resume() re-enters as an
                # ordinary _SingleRun (gang membership is not restored)
                job._preempt_requested = False
                self.gang.deactivate(lane)
                job.snapshot = self.gang.lane_state(lane)
                job.snapshot_kind = getattr(self.slice, "kind", "pim")
                job.state = JobState.PREEMPTED
                job.preemptions += 1
                self._account(job)
                if TRACER.enabled:
                    TRACER.instant("preempt", track=f"job:{job.name}",
                                   cat="elastic", steps=job.steps,
                                   fused=True)
                if sched is not None:
                    sched.metrics.counter("sched.preemptions").inc()
                    sched._persist_job(job)
        it_before = self.gang.it
        try:
            finished = self.gang.step()
        except Exception as err:  # noqa: BLE001 — the gang shares a launch
            delta = self._transfer_delta()
            for job in self.live_jobs:
                if job.state is JobState.PREEMPTED:
                    continue     # already safely off the gang
                job.error = err
                job.state = JobState.FAILED
                job.transfer = delta
            return True
        advanced = self.gang.it - it_before
        if advanced:                     # a launch actually happened
            for lane, job in enumerate(self.jobs):
                if self.gang.active[lane]:
                    job.steps += 1       # one turn, maybe a whole chunk
                    job.iters += advanced
                    job.modeled_seconds += (advanced
                                            * self._step_seconds[lane])
        if finished:
            self._finish()
        return finished


# ---------------------------------------------------------------------------
# The scheduler.
# ---------------------------------------------------------------------------

class PimScheduler:
    """FIFO+priority scheduler of training jobs over one or more Systems.

    ``system`` is a single :class:`~repro.systems.base.System` (the
    original surface) or a ``{target_name: System}`` mapping — a *mixed*
    machine, e.g. ``{"pim": PimSystem(...), "host": HostSystem(...)}``:
    one queue, one drain loop, per-target bank allocators, and
    ``submit(..., target="host")`` routes a job to the named target
    (default: the first/only one).  A HostSystem is schedulable too —
    its "cores" are thread-pool lanes and its slices are accounting
    scopes over the same single-image execution (DESIGN.md §10.3).

    ``rank_size=None`` auto-selects the largest divisor of each machine
    not exceeding UPMEM's 64-DPU rank (see ``default_rank_size``; an
    explicit ``rank_size`` applies to the default target only);
    ``backfill=True`` lets smaller jobs jump a queue head that doesn't
    fit (better utilization, admission no longer strictly ordered —
    off by default to keep head-of-line semantics, which with multiple
    targets is per target: a full PIM machine never stalls host-lane
    admissions).
    """

    def __init__(self,
                 system: Union[System, Mapping[str, System]],
                 rank_size: Optional[int] = None,
                 backfill: bool = False,
                 preemptive: bool = False,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1,
                 fault_injector=None,
                 default_retry_budget: int = 0,
                 placement: str = "first_fit",
                 policy: str = "fifo",
                 max_modeled_seconds: Optional[float] = None):
        if isinstance(system, Mapping):
            if not system:
                raise ValueError("need at least one system to schedule on")
            self.systems = dict(system)
        else:
            self.systems = {getattr(system, "kind", "pim"): system}
        self.default_target = next(iter(self.systems))
        # rank_size=None -> the allocator's auto rank (largest divisor
        # of the machine <= the 64-DPU UPMEM rank); each allocator
        # scores placements against its system's own rank tree when one
        # exists ("contention" policy, DESIGN.md §12.4)
        self.placement = placement
        #: scheduler-scoped control-plane metrics (admissions, chunks,
        #: evictions, drift histograms — repro.obs.metrics)
        self.metrics = MetricsRegistry()
        self._allocators = {
            name: BankAllocator(
                sys_.config.n_cores,
                rank_size if name == self.default_target else None,
                topology=getattr(sys_, "topology", None),
                placement=placement,
                trace_track=f"channels:{name}")
            for name, sys_ in self.systems.items()}
        self.system = self.systems[self.default_target]
        self.allocator = self._allocators[self.default_target]
        self.backfill = backfill
        #: priority preemption in _admit: a high-priority submit may
        #: evict lower-priority resumable RUNNING jobs to claim cores
        self.preemptive = preemptive
        #: durable elastic checkpoints (None = in-memory snapshots only)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.default_retry_budget = default_retry_budget
        #: fault injection hook — explicit injector wins, else the
        #: REPRO_INJECT_FAULT environment plan (None when unset)
        self.injector = (fault_injector if fault_injector is not None
                         else injector_from_env())
        self._monitors: dict = {}   # job id -> StragglerMonitor
        if policy not in ("fifo", "deadline"):
            raise ValueError(f"unknown policy {policy!r}; "
                             "known: 'fifo', 'deadline'")
        #: admission-ordering policy: "fifo" = (priority desc,
        #: submission order); "deadline" = earliest absolute deadline
        #: first within a priority band (EDF — deadline-less jobs sort
        #: last).  Deadlines also extend preemptive eviction: an
        #: earlier-deadline submit may evict an equal-priority,
        #: later-deadline victim (``_outranks``, DESIGN.md §14.3).
        self.policy = policy
        #: default modeled-seconds admission SLO (None = unbounded);
        #: submit's per-job ``max_modeled_seconds`` overrides.  Jobs the
        #: cost model prices above the bound are rejected at submission:
        #: FAILED with an SloViolation on ``error``, never queued.
        self.max_modeled_seconds = max_modeled_seconds
        # service mode (DESIGN.md §14.2): one reentrant lock guards the
        # queue/running/finished structures; the Condition carries
        # "work arrived / state changed" wakeups between submitting
        # threads, the background drain loop, and wait()ers.  A
        # separate mutex serializes whole scheduling turns so two
        # threads can never co-advance one job's generator.
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._step_mutex = threading.Lock()
        self._serve_thread: Optional[threading.Thread] = None
        self._stop_serving = False
        self._drain_on_stop = True
        self._queue: List[_Runnable] = []
        self._running: List[_Runnable] = []
        self._finished: List[_Runnable] = []
        self._seq = itertools.count()
        self._next_job_id = itertools.count()
        self.handles: List[JobHandle] = []

    # -- submission ----------------------------------------------------------

    def _resolve_target(self, target: Optional[str]) -> str:
        if target is None:
            return self.default_target
        if target not in self.systems:
            raise ValueError(f"unknown target {target!r}; known: "
                             f"{sorted(self.systems)}")
        return target

    def _sized(self, n_cores: Optional[int],
               target: Optional[str] = None) -> int:
        """Rank-align a request, rejecting unschedulable sizes at
        submission time (an over-machine job would livelock admission)."""
        alloc = self._allocators[self._resolve_target(target)]
        size = alloc.align(n_cores)
        if size > alloc.n_cores:
            raise ValueError(
                f"job needs {size} cores (rank-aligned) but the machine "
                f"has {alloc.n_cores}")
        return size

    @staticmethod
    def _resolve_workload(workload: Union[str, Workload]) -> Workload:
        if isinstance(workload, str):
            return get_workload(workload)
        return workload

    @staticmethod
    def _host_arrays(data) -> tuple:
        """Normalize submit() data to host (X, y).

        Accepted: (X, y) tuple, a bare X array, or a PimDataset — whose
        *host* arrays are re-sharded onto the job's slice (device shards
        are shaped by their owning system and cannot be re-scoped)."""
        if isinstance(data, PimDataset):
            return data.X, data.y
        if isinstance(data, tuple):
            if len(data) != 2:
                raise ValueError(f"data tuple must be (X, y), got "
                                 f"{len(data)} elements")
            return data
        return data, None

    def submit(self, workload: Union[str, Workload], data,
               spec: Optional[TrainerSpec] = None, *,
               version: Optional[str] = None, n_cores: Optional[int] = None,
               priority: int = 0, name: Optional[str] = None,
               target: Optional[str] = None,
               retry_budget: Optional[int] = None,
               resume_state: Optional[dict] = None,
               resume_from_kind: Optional[str] = None,
               deadline_seconds: Optional[float] = None,
               max_modeled_seconds: Optional[float] = None,
               **params) -> JobHandle:
        """Queue one training job; returns its :class:`JobHandle`.

        ``spec`` wins when given; otherwise one is built from
        ``version``/``**params`` exactly as ``make_estimator`` would.
        ``n_cores`` is rounded up to whole ranks at admission (None =
        one rank).  ``target`` picks the execution System on a mixed
        machine (None = the default target).  Jobs run when capacity
        exists, in (priority desc, submission order).

        Elastic knobs (DESIGN.md §11): ``retry_budget`` caps supervised
        retries from the last snapshot (None = the scheduler default);
        ``resume_state`` seeds the fit from a prior chunk-boundary
        snapshot — cross-System migration is validated when
        ``resume_from_kind`` names the System kind the snapshot was
        taken on (integer versions are bit-exact only between
        numerically-like kinds; fp32 migrates anywhere).

        Service/SLO knobs (DESIGN.md §14): ``deadline_seconds`` sets an
        absolute deadline (now + the given seconds) — the admission key
        under the "deadline" policy and the deadline-miss observable
        under any policy; ``max_modeled_seconds`` (per-job, overriding
        the scheduler default) rejects the job at submission when the
        cost model prices it above the bound — the handle comes back
        FAILED with an :class:`SloViolation` on ``error``, nothing is
        queued.  Thread-safe: may be called while a serve loop drains.
        """
        wl = self._resolve_workload(workload)
        if spec is None:
            spec = wl.spec(version, **params)
        elif version is not None or params:
            raise TypeError("pass either spec= or version=/params, "
                            "not both")
        with self._work:
            target = self._resolve_target(target)
            size = self._sized(n_cores, target)
            handle = JobHandle(next(self._next_job_id), wl, spec,
                               priority, size, name)
            handle.target = target
            handle.retry_budget = (self.default_retry_budget
                                   if retry_budget is None
                                   else retry_budget)
            data = self._host_arrays(data)
            if self.checkpoint_dir is not None:
                handle.fingerprint = job_fingerprint(
                    wl.name, spec.version, dict(spec.params),
                    data[0], data[1])
            if resume_state is not None:
                if resume_from_kind is not None:
                    to_kind = getattr(self.systems[target], "kind", "pim")
                    check_migration(resume_from_kind, to_kind,
                                    spec.version)
                handle.snapshot = resume_state
                handle.iters = snapshot_iters(resume_state)
            run = _SingleRun([handle], data, priority,
                             next(self._seq), size, target,
                             resume_state=resume_state)
            run.est_seconds = _estimate_job_seconds(
                wl.name, spec, data, size, self.systems[target])
            bound = (max_modeled_seconds if max_modeled_seconds is not None
                     else self.max_modeled_seconds)
            if bound is not None and run.est_seconds > bound:
                handle.error = SloViolation(
                    f"job {handle.name!r}: modeled "
                    f"{run.est_seconds:.4g}s exceeds "
                    f"max_modeled_seconds={bound:.4g}")
                handle.state = JobState.FAILED
                handle.finished_at = time.monotonic()
                self.handles.append(handle)
                self.metrics.counter("sched.slo_rejections").inc()
                self._work.notify_all()
                return handle
            if deadline_seconds is not None:
                handle.deadline = (handle.submitted_at
                                   + float(deadline_seconds))
                run.deadline = handle.deadline
            self._queue.append(run)
            self.handles.append(handle)
            self._work.notify_all()
        return handle

    def sweep(self, workload: Union[str, Workload], data, grid: dict, *,
              version: Optional[str] = None, n_cores: Optional[int] = None,
              fused: bool = True, priority: int = 0,
              target: Optional[str] = None,
              **base_params) -> List[JobHandle]:
        """Submit the cartesian product of ``grid`` as one job per point.

        With ``fused=True`` (default), points whose ``fuse_key`` matches
        are gang-fused: one slice, one shared bank-resident dataset, one
        batched kernel launch per step for the whole gang (learning-rate
        sweeps collapse to a single dispatch).  Non-fusable points fall
        back to ordinary per-job scheduling.  Handles come back in grid
        order regardless of gang grouping.
        """
        wl = self._resolve_workload(workload)
        keys = sorted(grid)
        combos = [dict(zip(keys, values))
                  for values in itertools.product(*(grid[k] for k in keys))]
        specs = [wl.spec(version, **{**base_params, **combo})
                 for combo in combos]
        with self._work:
            target = self._resolve_target(target)
            size = self._sized(n_cores, target)
            data = self._host_arrays(data)

            groups = (plan_fusion(wl, specs) if fused
                      else [[i] for i in range(len(specs))])
            handles: List[Optional[JobHandle]] = [None] * len(specs)
            for group in groups:
                group_handles = []
                for i in group:
                    handle = JobHandle(next(self._next_job_id), wl,
                                       specs[i], priority, size)
                    handle.target = target
                    handles[i] = handle
                    group_handles.append(handle)
                    self.handles.append(handle)
                cls = _FusedRun if len(group) > 1 else _SingleRun
                run = cls(group_handles, data, priority,
                          next(self._seq), size, target)
                # a fused gang advances all lanes per launch, so its
                # duration is one member's, not the sum
                run.est_seconds = max(
                    (_estimate_job_seconds(wl.name, specs[i], data, size,
                                           self.systems[target])
                     for i in group), default=0.0)
                self._queue.append(run)
            self._work.notify_all()
        return handles

    # -- execution -----------------------------------------------------------

    def _preempt_running(self, run: _Runnable,
                         requeue: bool = True) -> Optional[JobHandle]:
        """Preempt a RUNNING _SingleRun at its current chunk boundary:
        snapshot the carry, release the lease, and (by default) requeue
        a fresh runnable seeded from the snapshot."""
        job = run.jobs[0]
        job._preempt_requested = True
        run.advance(self)
        self._allocators[run.target].release(run.lease)
        self._running.remove(run)
        self._finished.append(run)
        if job.state is not JobState.PREEMPTED:
            return None     # raced with completion/cancel — nothing lost
        if requeue:
            self._requeue(job)
        return job

    def _requeue(self, job: JobHandle) -> None:
        """PREEMPTED -> QUEUED on a fresh runnable seeded from the
        job's snapshot (None restarts non-resumable workloads)."""
        run = self._find_run(job)
        job.state = JobState.QUEUED
        job.lease = None
        job.iters = snapshot_iters(job.snapshot)
        new = _SingleRun([job], run.data, job.priority,
                         next(self._seq), job.n_cores, job.target,
                         resume_state=job.snapshot)
        self._queue.append(new)
        if TRACER.enabled:
            TRACER.instant("requeue", track=f"job:{job.name}",
                           cat="elastic", iters=job.iters)

    def _find_run(self, job: JobHandle) -> _Runnable:
        for pool in (self._running, self._finished, self._queue):
            for run in pool:
                if job in run.jobs:
                    return run
        raise ValueError(f"job {job.name!r} is not tracked by this "
                         "scheduler")

    def _outranks(self, run: _Runnable, victim: _Runnable) -> bool:
        """Eviction order: strictly higher priority always outranks;
        under the "deadline" policy an equal-priority run with a
        strictly earlier deadline also outranks a deadline-less or
        later-deadline victim (EDF eviction, DESIGN.md §14.3)."""
        if victim.priority < run.priority:
            return True
        if (self.policy == "deadline" and victim.priority == run.priority
                and run.deadline is not None):
            return victim.deadline is None or victim.deadline > run.deadline
        return False

    def _evict_for(self, run: _Runnable,
                   alloc: BankAllocator) -> Optional[BankLease]:
        """Priority preemption: free cores for ``run`` by preempting
        outranked resumable single jobs on its target (lowest priority
        first, latest deadline first under the "deadline" policy, LIFO
        within a band), retrying the allocation after each eviction.
        Returns the won lease, or None when even preempting every
        eligible victim cannot fit the request (then nobody is
        preempted)."""
        victims = [r for r in self._running
                   if r.target == run.target
                   and isinstance(r, _SingleRun)
                   and self._outranks(run, r)
                   and getattr(r.jobs[0].workload, "resumable", False)
                   and not r.jobs[0].done]
        if not victims:
            return None
        reclaimable = sum(r.lease.n_cores for r in victims)
        if alloc.free_cores + reclaimable < run.n_cores:
            return None
        victims.sort(key=lambda r: (
            r.priority,
            -(r.deadline if r.deadline is not None else math.inf),
            -r.seq))
        for victim in victims:
            self._preempt_running(victim, requeue=True)
            self.metrics.counter("sched.evictions").inc()
            if TRACER.enabled:
                TRACER.instant("evict", track="sched", cat="sched",
                               victim=victim.label, by=run.label)
            lease = alloc.allocate(run.n_cores)
            if lease is not None:
                return lease
        return None

    def defragment(self, target: Optional[str] = None) -> int:
        """Compact a target's allocator under churn: preempt every
        resumable running single job at its chunk boundary (releasing
        its lease), then re-admit — the allocator's first-fit over the
        coalesced free list packs the survivors contiguously.  Returns
        how many jobs were cycled.  Fused gangs are left in place
        (one gang = one lease; moving it buys nothing).  Serialized
        against scheduling turns: safe to call while a serve loop
        drains (the preempt lands at the next chunk boundary)."""
        with self._step_mutex, self._work:
            target = self._resolve_target(target)
            movable = [r for r in self._running
                       if r.target == target and isinstance(r, _SingleRun)
                       and getattr(r.jobs[0].workload, "resumable", False)
                       and not r.jobs[0].done]
            moved = 0
            for run in movable:
                if self._preempt_running(run, requeue=True) is not None:
                    moved += 1
            self._admit()
            self.metrics.counter("sched.defragments").inc()
            if TRACER.enabled:
                TRACER.instant("defragment", track="sched", cat="sched",
                               target=target, moved=moved)
            return moved

    def _admit(self) -> None:
        self._queue = [r for r in self._queue if r.live_jobs]
        # backfill mode additionally orders equal-priority candidates by
        # modeled job time (shortest-first — DESIGN.md §12.5): since
        # backfill already abandons strict submission order, the model's
        # estimate decides who jumps a blocked head.  Unpriceable jobs
        # (est 0.0) sort first and fall back to submission order.  The
        # "deadline" policy inserts EDF between priority and the
        # backfill/FIFO tie-breakers (DESIGN.md §14.3).
        if self.policy == "deadline":
            key = (lambda r: (-r.priority,
                              r.deadline if r.deadline is not None
                              else math.inf,
                              r.est_seconds if self.backfill else 0.0,
                              r.seq))
        elif self.backfill:
            key = (lambda r: (-r.priority, r.est_seconds, r.seq))
        else:
            key = (lambda r: (-r.priority, r.seq))
        pending = sorted(self._queue, key=key)
        blocked: set = set()    # head-of-line blocking is per target
        for run in pending:
            if run.target in blocked:
                continue
            alloc = self._allocators[run.target]
            lease = alloc.allocate(run.n_cores)
            if lease is None and self.preemptive:
                lease = self._evict_for(run, alloc)
            if lease is None:
                if not self.backfill:
                    blocked.add(run.target)
                continue
            self._queue.remove(run)
            try:
                run.start(self.systems[run.target], lease)
            except Exception as err:  # noqa: BLE001 — bad data/spec must
                # fail the job, not unwind the other tenants' drain
                alloc.release(lease)
                for job in run.live_jobs:
                    job.error = err
                    job.state = JobState.FAILED
                self._finished.append(run)
                continue
            self._running.append(run)
            self.metrics.counter("sched.admissions").inc()
            if TRACER.enabled:
                TRACER.instant("admit", track="sched", cat="sched",
                               job=run.label, target=run.target,
                               cores=lease.n_cores, start=lease.start)

    def _observe_stragglers(self, run: _Runnable, dt: float) -> None:
        """Feed each live job's per-chunk wall time into its
        StragglerMonitor (EWMA z-score over scheduling turns — the
        train.fault_tolerance detector wired into the drain loop)."""
        for job in run.jobs:
            if job.done:
                continue
            mon = self._monitors.get(job.id)
            if mon is None:
                mon = self._monitors[job.id] = StragglerMonitor()
            if mon.observe(dt):
                job.straggler_flags += 1

    def _account_drift(self, run: _Runnable, dt: float,
                       before: dict) -> None:
        """Per-chunk modeled-vs-measured settlement (DESIGN.md §13.5):
        every job live at the chunk start is charged the chunk's wall
        time, and — when the cost model priced any progress this chunk —
        one drift-ratio observation lands on the job's histogram and the
        scheduler-wide one.  Gang members share a launch, so each lane
        sees the full chunk wall time (the ratio then reads as
        wall-per-lane, comparable across fused/unfused runs of the same
        job, not as machine throughput)."""
        chunks = self.metrics.counter("sched.chunks")
        drift_hist = None   # materialized only when a ratio exists
        for job in run.jobs:
            if job.id not in before:
                continue    # finished before this chunk: not charged
            job.measured_seconds += dt
            chunks.inc()
            modeled = job.modeled_seconds - before[job.id]
            if modeled > 0.0 and dt > 0.0:
                ratio = dt / modeled
                job.drift.observe(ratio)
                if drift_hist is None:
                    drift_hist = self.metrics.histogram(
                        "sched.drift_ratio", DRIFT_BUCKETS)
                drift_hist.observe(ratio)

    def _settle(self, run: _Runnable) -> None:
        """Stamp completion latency on every job of ``run`` that just
        reached a terminal state, and count deadline misses — the SLO
        observable the "deadline" policy is judged by (DESIGN.md §14)."""
        now = time.monotonic()
        for job in run.jobs:
            if job.done and job.finished_at is None:
                job.finished_at = now
                if (job.deadline is not None
                        and not job.deadline_missed
                        and now > job.deadline):
                    job.deadline_missed = True
                    self.metrics.counter("sched.deadline_misses").inc()

    def step(self) -> bool:
        """One scheduling turn: admit what fits, then advance every
        running job by one gang step (round-robin, admission order).
        Returns True while any job is queued or running.  Explicitly
        preempted jobs park in PREEMPTED (their lease released) until
        :meth:`resume`; parked jobs do not keep the drain loop alive.

        Thread-safe (serve mode, DESIGN.md §14.2): whole turns are
        serialized — two threads can never co-advance one job's
        generator — and the structure lock is dropped around each job's
        chunk so ``submit()``/``stats()``/``wait()`` stay responsive
        mid-chunk."""
        with self._step_mutex:
            return self._step_turn()

    def _step_turn(self) -> bool:
        with self._work:
            self._admit()
            runs = list(self._running)
        for run in runs:
            with self._work:
                if run not in self._running:
                    continue   # evicted mid-turn / finished elsewhere
                # drift accounting (DESIGN.md §13.5): modeled progress
                # this chunk is the delta each live job's _step_seconds
                # pricing adds during advance; wall time is the chunk's
                # perf_counter envelope.  Snapshot first, settle in
                # _account_drift.
                before = {j.id: j.modeled_seconds for j in run.jobs
                          if not j.done}
            t0 = time.perf_counter()
            if TRACER.enabled:
                with TRACER.span("chunk", f"target:{run.target}",
                                 "chunk", job=run.label):
                    with TRACER.span(run.label, f"job:{run.label}",
                                     "chunk"):
                        finished = run.advance(self)
            else:
                finished = run.advance(self)
            dt = time.perf_counter() - t0
            with self._work:
                self._observe_stragglers(run, dt)
                self._account_drift(run, dt, before)
                if finished and run in self._running:
                    self._allocators[run.target].release(run.lease)
                    self._running.remove(run)
                    self._finished.append(run)
                self._settle(run)
                self._work.notify_all()
        with self._work:
            if self.checkpoint_dir is not None:
                self._persist_queue()
            self._work.notify_all()
            return bool(self._running or self._queue)

    def drain(self) -> List[JobHandle]:
        """Run scheduling turns until every job reaches a terminal
        state; returns all handles.  One job's failure never stops the
        drain (failure isolation is per step, see _SingleRun.advance)."""
        while self.step():
            pass
        return self.handles

    # -- service mode: background drain loop (DESIGN.md §14.2) ---------------

    @property
    def serving(self) -> bool:
        """True while the background drain loop is alive."""
        thread = self._serve_thread
        return thread is not None and thread.is_alive()

    @property
    def idle(self) -> bool:
        """True when nothing is queued or running (parked PREEMPTED
        jobs don't count — only ``resume()`` revives those)."""
        with self._lock:
            return not (self._queue or self._running)

    def serve(self, poll_interval: float = 0.05) -> None:
        """Start the background drain loop: a daemon thread that runs
        scheduling turns whenever work exists and sleeps on the work
        Condition otherwise (``poll_interval`` bounds the sleep so
        externally-flipped state — e.g. ``handle.cancel()`` — is seen
        promptly).  ``submit()``/``sweep()``/``resume()`` return
        immediately while the loop drains; work submitted mid-flight is
        admitted at the loop's next turn.  One loop per scheduler —
        starting twice is an error."""
        with self._work:
            if self.serving:
                raise RuntimeError("scheduler is already serving")
            self._stop_serving = False
            self._drain_on_stop = True
            self._serve_thread = threading.Thread(
                target=self._serve_loop, args=(float(poll_interval),),
                name="pim-sched-serve", daemon=True)
            self._serve_thread.start()

    def _serve_loop(self, poll_interval: float) -> None:
        while True:
            with self._work:
                while (not self._stop_serving
                       and not (self._queue or self._running)):
                    self._work.wait(poll_interval)
                if self._stop_serving and (
                        not self._drain_on_stop
                        or not (self._queue or self._running)):
                    self._work.notify_all()
                    return
            try:
                self.step()
            except Exception:  # noqa: BLE001 — per-job failures are
                # already isolated inside step(); this backstop only
                # catches scheduler-level faults, counted so the loop
                # never dies silently
                self.metrics.counter("sched.serve_errors").inc()

    def shutdown(self, wait: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the serve loop.  ``wait=True`` (default) first drains
        every queued/running job to a terminal state — no submitted
        work is lost; ``wait=False`` stops after the in-flight turn,
        leaving the queue intact (a later :meth:`serve` or
        :meth:`drain` picks it up).  No-op when not serving; raises
        when the loop fails to stop within ``timeout`` seconds."""
        with self._work:
            thread = self._serve_thread
            if thread is None:
                return
            self._drain_on_stop = wait
            self._stop_serving = True
            self._work.notify_all()
        thread.join(timeout)
        if thread.is_alive():
            raise RuntimeError(
                f"serve loop did not stop within {timeout}s")
        with self._work:
            self._serve_thread = None

    def wait(self, handles: Optional[List[JobHandle]] = None,
             timeout: Optional[float] = None) -> bool:
        """Block until every given handle (default: all) settles —
        terminal, or parked in PREEMPTED (only :meth:`resume` un-parks
        those; waiting on them would hang forever).  True when settled,
        False on timeout.  Progress needs a draining thread: serve
        mode, or another thread calling ``step()``/``drain()``."""
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        with self._work:
            while True:
                targets = (handles if handles is not None
                           else self.handles)
                if all(h.done or h.state is JobState.PREEMPTED
                       for h in targets):
                    return True
                remaining = 0.5
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                    remaining = min(remaining, 0.5)
                self._work.wait(remaining)

    def latency_summary(self) -> dict:
        """Queue/completion latency percentiles over every job that
        reached the corresponding lifecycle point — the service-level
        observables of DESIGN.md §14.2 (time.monotonic seconds): queue
        latency is first admission minus submission, completion latency
        is the terminal transition minus submission."""
        with self._lock:
            queued = [h.started_at - h.submitted_at
                      for h in self.handles if h.started_at is not None]
            completed = [h.finished_at - h.submitted_at
                         for h in self.handles
                         if h.finished_at is not None]
            misses = sum(1 for h in self.handles if h.deadline_missed)

        def _pcts(xs: List[float]) -> dict:
            if not xs:
                return {"count": 0, "mean": None, "p50": None,
                        "p99": None, "max": None}
            xs = sorted(xs)

            def pct(q: float) -> float:
                return xs[min(len(xs) - 1,
                              max(0, math.ceil(q * len(xs)) - 1))]

            return {"count": len(xs), "mean": sum(xs) / len(xs),
                    "p50": pct(0.50), "p99": pct(0.99), "max": xs[-1]}

        return {"queue": _pcts(queued), "completion": _pcts(completed),
                "deadline_misses": misses}

    # -- elastic: preempt / resume / migrate / persist -----------------------

    def resume(self, handle: JobHandle, *, data=None,
               target: Optional[str] = None) -> JobHandle:
        """Requeue a PREEMPTED job from its snapshot.

        ``target`` may name a *different* execution System (cross-System
        migration): the move is validated against the elastic
        compatibility matrix — integer versions only between
        numerically-like kinds, fp32 anywhere (tolerance-tested,
        DESIGN.md §11.3).  ``data`` re-supplies the host arrays when the
        handle comes from another scheduler (same-scheduler resumes find
        them on the parked runnable).  The handle itself is reused; on a
        foreign scheduler it is adopted into ``handles``.
        """
        with self._work:
            if handle.state is not JobState.PREEMPTED:
                raise ValueError(f"can only resume a PREEMPTED job, "
                                 f"{handle.name!r} is "
                                 f"{handle.state.value}")
            to_target = self._resolve_target(
                target if target is not None
                else (handle.target
                      if handle.target in self.systems else None))
            if (handle.snapshot is not None
                    and handle.snapshot_kind is not None):
                to_kind = getattr(self.systems[to_target], "kind", "pim")
                check_migration(handle.snapshot_kind, to_kind,
                                handle.spec.version)
            if data is None:
                data = self._find_data(handle)
            else:
                data = self._host_arrays(data)
            handle.target = to_target
            handle.n_cores = self._sized(handle.n_cores, to_target)
            handle.state = JobState.QUEUED
            handle.lease = None
            handle.iters = snapshot_iters(handle.snapshot)
            run = _SingleRun([handle], data, handle.priority,
                             next(self._seq), handle.n_cores, to_target,
                             resume_state=handle.snapshot)
            run.deadline = handle.deadline
            self._queue.append(run)
            if handle not in self.handles:
                self.handles.append(handle)
            self.metrics.counter("sched.resumes").inc()
            if TRACER.enabled:
                TRACER.instant("resume", track=f"job:{handle.name}",
                               cat="elastic", target=to_target,
                               iters=handle.iters)
            self._work.notify_all()
        return handle

    def _find_data(self, handle: JobHandle) -> tuple:
        try:
            return self._find_run(handle).data
        except ValueError:
            raise ValueError(
                f"job {handle.name!r} belongs to another scheduler; "
                "pass data= to resume it here") from None

    def attach_resume_state(self, handle: JobHandle, snapshot: dict,
                            envelope: Optional[dict] = None) -> None:
        """Seed a still-QUEUED job with a restored checkpoint (the
        crash-recovery path: run_manifest re-submits the manifest, then
        attaches each job's durable snapshot before draining).

        The envelope — when given — must carry a matching config+dataset
        ``fingerprint`` (refuse to resume someone else's weights) and
        its ``system_kind`` is migration-checked against the job's
        target."""
        with self._lock:
            self._attach_resume_state(handle, snapshot, envelope)

    def _attach_resume_state(self, handle: JobHandle, snapshot: dict,
                             envelope: Optional[dict]) -> None:
        if handle.state is not JobState.QUEUED:
            raise ValueError("attach_resume_state needs a QUEUED job, "
                             f"{handle.name!r} is {handle.state.value}")
        if envelope is not None:
            fp = envelope.get("fingerprint")
            if (fp and handle.fingerprint is not None
                    and fp != handle.fingerprint):
                raise ValueError(
                    f"checkpoint fingerprint mismatch for {handle.name!r}"
                    ": the saved config+dataset differ from the "
                    "submitted job")
            from_kind = envelope.get("system_kind")
            if from_kind:
                to_kind = getattr(self.systems[handle.target], "kind",
                                  "pim")
                check_migration(from_kind, to_kind, handle.spec.version)
                handle.snapshot_kind = from_kind
        run = self._find_run(handle)
        if not isinstance(run, _SingleRun):
            raise ValueError("cannot attach a resume state to a fused "
                             "gang member; submit it unfused")
        handle.snapshot = snapshot
        handle.iters = snapshot_iters(snapshot)
        run._resume_state = snapshot

    def mark_restored(self, handle: JobHandle, *, iters: int = 0,
                      steps: int = 0) -> None:
        """Mark a still-QUEUED job DONE-equivalent from a crash-surviving
        queue record: the fit already finished in the killed process, so
        ``--resume`` must not re-run it.  The handle lands in DONE with
        ``restored=True`` and no in-memory FitResult (the caller reloads
        artifacts from its own checkpoint if it needs them)."""
        with self._lock:
            if handle.state is not JobState.QUEUED:
                raise ValueError("mark_restored needs a QUEUED job, "
                                 f"{handle.name!r} is "
                                 f"{handle.state.value}")
            handle.state = JobState.DONE
            handle.restored = True
            handle.iters = iters
            handle.steps = steps

    def _persist_job(self, job: JobHandle) -> None:
        """Durably checkpoint one job's snapshot (atomic tmp+rename via
        train/checkpoint.py's format — see repro/elastic/checkpoint)."""
        if self.checkpoint_dir is None or job.snapshot is None:
            return
        self.metrics.counter("sched.checkpoints").inc()
        if TRACER.enabled:
            TRACER.instant("checkpoint", track=f"job:{job.name}",
                           cat="elastic", steps=job.steps)
        elastic_ckpt.save_snapshot(
            elastic_ckpt.job_dir(self.checkpoint_dir, job.name),
            job.snapshot,
            envelope={
                "workload": job.workload.name,
                "version": job.spec.version,
                "params": dict(job.spec.params),
                "fingerprint": job.fingerprint,
                "system_kind": job.snapshot_kind,
                "iters": snapshot_iters(job.snapshot),
                "steps": job.steps,
            })

    def _persist_queue(self) -> None:
        """Crash-survivable queue manifest: one atomic ``queue.json``
        naming every job and its state, so ``pim_jobs --resume`` can
        tell finished work from unfinished after a kill (-9 included:
        the rename is the commit point)."""
        rows = [{
            "name": h.name,
            "workload": h.workload.name,
            "version": h.spec.version,
            "state": h.state.value,
            "iters": h.iters,
            "steps": h.steps,
            "priority": h.priority,
            "n_cores": h.n_cores,
            "target": h.target,
            "fingerprint": h.fingerprint,
        } for h in self.handles]
        path = os.path.join(self.checkpoint_dir, "queue.json")
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump({"schema": 1, "jobs": rows}, fh, indent=1)
        os.replace(tmp, path)

    # -- introspection -------------------------------------------------------

    def counts(self) -> dict:
        by_state: dict = {s.value: 0 for s in JobState}
        for h in self.handles:
            by_state[h.state.value] += 1
        return by_state

    def fragmentation(self) -> FragmentationStats:
        return self.allocator.fragmentation()

    def stats(self) -> dict:
        """Operator snapshot: job counts, occupancy, queue depth.

        The top-level occupancy keys describe the default target (the
        original single-system surface); ``targets`` breaks occupancy
        out per execution System on a mixed machine."""
        with self._lock:
            return self._stats_locked()

    def _stats_locked(self) -> dict:
        frag = self.fragmentation()
        out = {
            "jobs": self.counts(),
            "policy": self.policy,
            "serving": self.serving,
            "queued_runnables": len(self._queue),
            "running_runnables": len(self._running),
            "cores_used": frag.used_cores,
            "cores_free": frag.free_cores,
            "external_fragmentation": frag.external_fragmentation,
            # topology occupancy (DESIGN.md §12.4): per-memory-channel
            # leased fraction and how many live leases straddle ranks —
            # the observables defragment()/placement decisions act on
            "per_channel_occupancy": list(frag.per_channel_occupancy),
            "rank_straddling_leases": frag.rank_straddling_leases,
            # elastic/fault-tolerance counters (DESIGN.md §11)
            "straggler_flags": sum(h.straggler_flags
                                   for h in self.handles),
            "preemptions": sum(h.preemptions for h in self.handles),
            "recoveries": sum(h.recoveries for h in self.handles),
        }
        out["targets"] = {
            name: {
                "kind": getattr(self.systems[name], "kind", "pim"),
                "cores_used": f.used_cores,
                "cores_free": f.free_cores,
                "external_fragmentation": f.external_fragmentation,
                "per_channel_occupancy": list(f.per_channel_occupancy),
                "rank_straddling_leases": f.rank_straddling_leases,
            }
            for name, f in ((n, a.fragmentation())
                            for n, a in self._allocators.items())}
        # unified telemetry (DESIGN.md §13): the scheduler's own
        # control-plane metrics, the parent Systems' transfer totals
        # (each job's attributable share lives on its handle), per-job
        # drift accounting, and the modeled-GPU roofline totals
        out["metrics"] = self.metrics.to_dict()
        out["transfer"] = {
            name: dataclasses.asdict(sys_.stats.snapshot())
            for name, sys_ in self.systems.items()}
        gpu = {name: dataclasses.asdict(sys_.gpu.snapshot())
               for name, sys_ in self.systems.items()
               if getattr(sys_, "gpu", None) is not None}
        if gpu:
            out["gpu_model"] = gpu
        out["drift"] = {
            h.name: {
                "modeled_seconds": h.modeled_seconds,
                "measured_seconds": h.measured_seconds,
                "ratio": h.drift_ratio,
                "chunks": h.drift.count,
                "mean_chunk_ratio": h.drift.mean,
            }
            for h in self.handles if h.measured_seconds > 0.0}
        out["latency"] = self.latency_summary()
        return out

    def capacity_estimate(self, doc: dict) -> dict:
        """Model-based capacity plan for a manifest — is this machine
        big enough, and what throughput can it promise? (DESIGN.md
        §12.5.)

        Prices every job/sweep point of the manifest through the
        :class:`HierarchicalCostModel` using only the declared dataset
        *shapes* (nothing is materialized, nothing runs) and returns

          ``jobs``                per-job rows (name, cores, modeled
                                  seconds),
          ``total_core_seconds``  the work integral,
          ``serial_seconds``      one-at-a-time makespan (sum),
          ``makespan_lower_bound``  max(longest job, work / machine) —
                                  no schedule can beat it,
          ``jobs_per_second``     job count over that bound: the
                                  capacity-planning claim ("N banks
                                  serve M jobs/s") as a measurable
                                  model output.

        Unpriceable jobs (workloads outside the paper's cost model)
        appear with ``modeled_seconds = 0.0`` and weaken the bound —
        they are counted, not guessed at.
        """
        from ..api.registry import get_workload as _get_wl
        from .manifest import dataset_shape

        shapes = {name: dataset_shape(spec)
                  for name, spec in (doc.get("datasets") or {}).items()}

        def _shape(entry: dict) -> tuple:
            name = entry.get("dataset")
            if name is None:
                if len(shapes) == 1:
                    return next(iter(shapes.values()))
                raise ValueError(f"job {entry} names no dataset and the "
                                 f"manifest defines {len(shapes)}")
            try:
                return shapes[name]
            except KeyError:
                raise ValueError(
                    f"job references unknown dataset {name!r}; "
                    f"known: {sorted(shapes)}") from None

        class _ShapeOnly:
            """Stands in for the host X array in the estimator."""
            def __init__(self, n, f):
                self.shape, self.ndim = (n, f), 2

        system = self.systems[self.default_target]
        alloc = self._allocators[self.default_target]
        rows = []

        def _price(entry: dict, spec, wl_name: str) -> None:
            n, f = _shape(entry)
            size = self._sized(entry.get("cores"))
            sec = _estimate_job_seconds(wl_name, spec,
                                        (_ShapeOnly(n, f), None),
                                        size, system)
            rows.append({
                "name": entry.get("name",
                                  f"{wl_name}/{spec.version}"),
                "workload": wl_name, "version": spec.version,
                "cores": size, "modeled_seconds": sec,
            })

        for entry in doc.get("jobs") or []:
            wl = _get_wl(entry["workload"])
            spec = wl.spec(entry.get("version"),
                           **(entry.get("params") or {}))
            _price(entry, spec, wl.name)
        for entry in doc.get("sweeps") or []:
            wl = _get_wl(entry["workload"])
            grid = entry["grid"]
            keys = sorted(grid)
            base = dict(entry.get("params") or {})
            for values in itertools.product(*(grid[k] for k in keys)):
                spec = wl.spec(entry.get("version"),
                               **{**base, **dict(zip(keys, values))})
                _price(entry, spec, wl.name)
        if not rows:
            raise ValueError("manifest defines no jobs or sweeps")

        total_core_seconds = sum(r["modeled_seconds"] * r["cores"]
                                 for r in rows)
        serial = sum(r["modeled_seconds"] for r in rows)
        longest = max((r["modeled_seconds"] for r in rows), default=0.0)
        bound = max(longest, total_core_seconds / alloc.n_cores)
        return {
            "machine_cores": alloc.n_cores,
            "placement": self.placement,
            "jobs": rows,
            "total_core_seconds": total_core_seconds,
            "serial_seconds": serial,
            "makespan_lower_bound": bound,
            "jobs_per_second": (len(rows) / bound) if bound > 0 else 0.0,
        }
