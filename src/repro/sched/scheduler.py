"""Multi-tenant PIM training-job scheduler (DESIGN.md §7.2).

``PimScheduler`` layers job management on the unified workload API: it
owns a :class:`~repro.sched.allocator.BankAllocator` per parent
:class:`~repro.systems.base.System` (a single PimSystem, or a mixed
``{"pim": ..., "host": ...}`` machine — DESIGN.md §10.3), admits queued
jobs when rank-aligned capacity exists, runs each admitted job on its
own slice (``System.slice``: a
:class:`~repro.sched.allocator.PimSlice` core extent on PIM, a
thread-pool lane scope on a host target), and gang-steps all running
jobs round-robin — one trainer iteration per job per turn — so K
concurrent fits interleave on a single host thread, exactly the way the
UPMEM host serially orchestrates many tenants' rank allocations
(paper §2.2).

Lifecycle: ``QUEUED -> RUNNING -> DONE | FAILED | CANCELLED``.  Failure
is isolated per job: an exception inside one job's step marks that job
FAILED (the exception object rides on the handle) and never unwinds the
drain loop or the other tenants.

Accounting: every job records the ``TransferStats`` delta of its slice
(attributable bytes even though jobs interleave — snapshot/delta, see
TransferStats), its step count, and modeled DPU seconds from
:class:`~repro.core.pim.DpuCostModel` (steps x per-pass kernel time).

Fused gangs: ``sweep(..., fused=True)`` routes same-``fuse_key`` GD jobs
through :class:`~repro.sched.gang.FusedGdSweep` — one slice, one shared
dataset, one batched kernel launch per step for the whole gang.
"""
from __future__ import annotations

import enum
import itertools
from typing import List, Mapping, Optional, Union

from ..api.dataset import PimDataset
from ..api.registry import FitResult, TrainerSpec, Workload, get_workload
from ..systems import DpuCostModel, System, TransferStats
from .allocator import BankAllocator, BankLease, FragmentationStats, PimSlice
from .gang import FusedGdSweep, plan_fusion


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"

    @property
    def terminal(self) -> bool:
        return self in (JobState.DONE, JobState.FAILED,
                        JobState.CANCELLED)


#: cost-model routing: workload registry name -> (model workload key,
#: version selector).  Unknown workloads simply skip cycle accounting.
_COST_KEYS = {"linreg": "lin", "logreg": "log", "dtree": "dtr",
              "kmeans": "kme"}
_COST_VERSIONS = {"dtree": "fp32", "kmeans": "int16"}


class JobHandle:
    """Caller-facing view of one submitted training job.

    Fields filled in as the job progresses: ``state``, ``steps``
    (scheduling turns taken — with step fusion one turn drains a whole
    ``lax.scan`` chunk), ``iters`` (trainer iterations covered: the
    ``fit_steps`` generators yield how many iterations each turn
    advanced, 1 unfused, up to ``fuse_steps`` fused — DESIGN.md §9.3),
    ``result`` (FitResult on DONE), ``error`` (the exception on FAILED),
    ``transfer`` (the job's attributable TransferStats delta; for fused
    jobs this is the whole gang's delta — they share one slice),
    ``modeled_seconds`` (DpuCostModel cycle accounting, per iteration),
    and ``lease`` (the core extent while running).
    """

    def __init__(self, job_id: int, workload: Workload, spec: TrainerSpec,
                 priority: int, n_cores: int, name: Optional[str] = None):
        self.id = job_id
        self.workload = workload
        self.spec = spec
        self.priority = priority
        self.n_cores = n_cores
        self.name = name or f"job{job_id}:{workload.name}/{spec.version}"
        self.target = "pim"     # execution target on a mixed machine
        self.state = JobState.QUEUED
        self.steps = 0
        self.iters = 0
        self.result: Optional[FitResult] = None
        self.error: Optional[BaseException] = None
        self.transfer: Optional[TransferStats] = None
        self.modeled_seconds = 0.0
        self.lease: Optional[BankLease] = None
        self.fused = False
        self._cancel_requested = False

    @property
    def done(self) -> bool:
        return self.state.terminal

    def cancel(self) -> None:
        """Request cancellation: queued jobs cancel immediately, running
        jobs at their next gang-step boundary."""
        if not self.done:
            self._cancel_requested = True
            if self.state is JobState.QUEUED:
                self.state = JobState.CANCELLED

    def __repr__(self) -> str:
        return (f"JobHandle({self.name!r}, {self.state.value}, "
                f"steps={self.steps}, cores={self.n_cores})")


def _modeled_step_seconds(handle: JobHandle, dataset: PimDataset,
                          slice_: System) -> float:
    """Per-pass DPU kernel seconds for one gang step of this job (0.0
    for workloads outside the paper's cost model, and for jobs running
    on a non-PIM target — DPU cycle accounting is meaningless there)."""
    if getattr(slice_, "kind", None) != "pim":
        return 0.0
    wl_key = _COST_KEYS.get(handle.workload.name)
    if wl_key is None:
        return 0.0
    version = _COST_VERSIONS.get(handle.workload.name, handle.spec.version)
    model = DpuCostModel()
    return model.workload_seconds(
        wl_key, version, dataset.n, dataset.n_features,
        slice_.config.n_cores, slice_.config.n_threads,
        k=handle.spec.params.get("n_clusters", 16))


# ---------------------------------------------------------------------------
# Runnables: one admitted queue entry (a single job or a fused gang).
# ---------------------------------------------------------------------------

class _Runnable:
    """Base: owns a lease + slice + dataset and advances by one step."""

    def __init__(self, jobs: List[JobHandle], data, priority: int,
                 seq: int, n_cores: int, target: str = "pim"):
        self.jobs = jobs
        self.data = data
        self.priority = priority
        self.seq = seq
        self.n_cores = n_cores
        self.target = target
        self.lease: Optional[BankLease] = None
        self.slice: Optional[System] = None
        self._snapshot: Optional[TransferStats] = None

    @property
    def live_jobs(self) -> List[JobHandle]:
        return [j for j in self.jobs if not j.done]

    def start(self, system: System, lease: BankLease) -> None:
        self.lease = lease
        # the system hands out its own slice type: PimSlice over a core
        # extent, HostSlice over thread-pool lanes (DESIGN.md §10.3)
        self.slice = system.slice(lease)
        self._snapshot = self.slice.stats.snapshot()
        X, y = self.data
        self.dataset = self.slice.put(X, y)
        for job in self.jobs:
            if job.state is JobState.QUEUED:
                job.state = JobState.RUNNING
                job.lease = lease
                job.n_cores = lease.n_cores

    def _transfer_delta(self) -> TransferStats:
        return self.slice.stats.delta(self._snapshot)

    def advance(self) -> bool:
        """One gang step; True when the runnable is finished."""
        raise NotImplementedError


class _SingleRun(_Runnable):
    """One job advanced via its workload's ``fit_steps`` generator."""

    def start(self, system: PimSystem, lease: BankLease) -> None:
        super().start(system, lease)
        job = self.jobs[0]
        self.gen = job.workload.fit_steps(self.dataset, job.spec)
        self._step_seconds = _modeled_step_seconds(job, self.dataset,
                                                   self.slice)

    def advance(self) -> bool:
        job = self.jobs[0]
        if job._cancel_requested:
            self.gen.close()
            job.state = JobState.CANCELLED
            job.transfer = self._transfer_delta()
            return True
        try:
            advanced = next(self.gen)
        except StopIteration as stop:
            job.result = stop.value
            job.state = JobState.DONE
            job.transfer = self._transfer_delta()
            return True
        except Exception as err:  # noqa: BLE001 — isolation by design
            job.error = err
            job.state = JobState.FAILED
            job.transfer = self._transfer_delta()
            return True
        # generators yield the iteration count each turn covered (a
        # fused chunk drains several); tolerate legacy generators that
        # yield something else by charging one iteration
        advanced = advanced if isinstance(advanced, int) and advanced > 0 \
            else 1
        job.steps += 1
        job.iters += advanced
        job.modeled_seconds += advanced * self._step_seconds
        return False


class _FusedRun(_Runnable):
    """A fused GD gang: one slice, one dataset, one launch per step."""

    def start(self, system: PimSystem, lease: BankLease) -> None:
        super().start(system, lease)
        workload = self.jobs[0].workload
        self.gang = FusedGdSweep(workload,
                                 [j.spec for j in self.jobs],
                                 self.dataset)
        self._step_seconds = [
            _modeled_step_seconds(j, self.dataset, self.slice)
            for j in self.jobs]
        for job in self.jobs:
            job.fused = True

    def _finish(self) -> None:
        delta = self._transfer_delta()
        for lane, job in enumerate(self.jobs):
            if job.done:
                continue
            job.transfer = delta
            result = self.gang.result(lane)
            if result is None:
                job.state = JobState.CANCELLED
            else:
                job.result = result
                job.state = JobState.DONE

    def advance(self) -> bool:
        for lane, job in enumerate(self.jobs):
            if job._cancel_requested and self.gang.active[lane]:
                self.gang.deactivate(lane)
                job.state = JobState.CANCELLED
                job.transfer = self._transfer_delta()
        it_before = self.gang.it
        try:
            finished = self.gang.step()
        except Exception as err:  # noqa: BLE001 — the gang shares a launch
            delta = self._transfer_delta()
            for job in self.live_jobs:
                job.error = err
                job.state = JobState.FAILED
                job.transfer = delta
            return True
        advanced = self.gang.it - it_before
        if advanced:                     # a launch actually happened
            for lane, job in enumerate(self.jobs):
                if self.gang.active[lane]:
                    job.steps += 1       # one turn, maybe a whole chunk
                    job.iters += advanced
                    job.modeled_seconds += (advanced
                                            * self._step_seconds[lane])
        if finished:
            self._finish()
        return finished


# ---------------------------------------------------------------------------
# The scheduler.
# ---------------------------------------------------------------------------

class PimScheduler:
    """FIFO+priority scheduler of training jobs over one or more Systems.

    ``system`` is a single :class:`~repro.systems.base.System` (the
    original surface) or a ``{target_name: System}`` mapping — a *mixed*
    machine, e.g. ``{"pim": PimSystem(...), "host": HostSystem(...)}``:
    one queue, one drain loop, per-target bank allocators, and
    ``submit(..., target="host")`` routes a job to the named target
    (default: the first/only one).  A HostSystem is schedulable too —
    its "cores" are thread-pool lanes and its slices are accounting
    scopes over the same single-image execution (DESIGN.md §10.3).

    ``rank_size=None`` auto-selects the largest divisor of each machine
    not exceeding UPMEM's 64-DPU rank (see ``default_rank_size``; an
    explicit ``rank_size`` applies to the default target only);
    ``backfill=True`` lets smaller jobs jump a queue head that doesn't
    fit (better utilization, admission no longer strictly ordered —
    off by default to keep head-of-line semantics, which with multiple
    targets is per target: a full PIM machine never stalls host-lane
    admissions).
    """

    def __init__(self,
                 system: Union[System, Mapping[str, System]],
                 rank_size: Optional[int] = None,
                 backfill: bool = False):
        if isinstance(system, Mapping):
            if not system:
                raise ValueError("need at least one system to schedule on")
            self.systems = dict(system)
        else:
            self.systems = {getattr(system, "kind", "pim"): system}
        self.default_target = next(iter(self.systems))
        # rank_size=None -> the allocator's auto rank (largest divisor
        # of the machine <= the 64-DPU UPMEM rank)
        self._allocators = {
            name: BankAllocator(
                sys_.config.n_cores,
                rank_size if name == self.default_target else None)
            for name, sys_ in self.systems.items()}
        self.system = self.systems[self.default_target]
        self.allocator = self._allocators[self.default_target]
        self.backfill = backfill
        self._queue: List[_Runnable] = []
        self._running: List[_Runnable] = []
        self._finished: List[_Runnable] = []
        self._seq = itertools.count()
        self._next_job_id = itertools.count()
        self.handles: List[JobHandle] = []

    # -- submission ----------------------------------------------------------

    def _resolve_target(self, target: Optional[str]) -> str:
        if target is None:
            return self.default_target
        if target not in self.systems:
            raise ValueError(f"unknown target {target!r}; known: "
                             f"{sorted(self.systems)}")
        return target

    def _sized(self, n_cores: Optional[int],
               target: Optional[str] = None) -> int:
        """Rank-align a request, rejecting unschedulable sizes at
        submission time (an over-machine job would livelock admission)."""
        alloc = self._allocators[self._resolve_target(target)]
        size = alloc.align(n_cores)
        if size > alloc.n_cores:
            raise ValueError(
                f"job needs {size} cores (rank-aligned) but the machine "
                f"has {alloc.n_cores}")
        return size

    @staticmethod
    def _resolve_workload(workload: Union[str, Workload]) -> Workload:
        if isinstance(workload, str):
            return get_workload(workload)
        return workload

    @staticmethod
    def _host_arrays(data) -> tuple:
        """Normalize submit() data to host (X, y).

        Accepted: (X, y) tuple, a bare X array, or a PimDataset — whose
        *host* arrays are re-sharded onto the job's slice (device shards
        are shaped by their owning system and cannot be re-scoped)."""
        if isinstance(data, PimDataset):
            return data.X, data.y
        if isinstance(data, tuple):
            if len(data) != 2:
                raise ValueError(f"data tuple must be (X, y), got "
                                 f"{len(data)} elements")
            return data
        return data, None

    def submit(self, workload: Union[str, Workload], data,
               spec: Optional[TrainerSpec] = None, *,
               version: Optional[str] = None, n_cores: Optional[int] = None,
               priority: int = 0, name: Optional[str] = None,
               target: Optional[str] = None,
               **params) -> JobHandle:
        """Queue one training job; returns its :class:`JobHandle`.

        ``spec`` wins when given; otherwise one is built from
        ``version``/``**params`` exactly as ``make_estimator`` would.
        ``n_cores`` is rounded up to whole ranks at admission (None =
        one rank).  ``target`` picks the execution System on a mixed
        machine (None = the default target).  Jobs run when capacity
        exists, in (priority desc, submission order).
        """
        wl = self._resolve_workload(workload)
        if spec is None:
            spec = wl.spec(version, **params)
        elif version is not None or params:
            raise TypeError("pass either spec= or version=/params, "
                            "not both")
        target = self._resolve_target(target)
        size = self._sized(n_cores, target)
        handle = JobHandle(next(self._next_job_id), wl, spec, priority,
                          size, name)
        handle.target = target
        run = _SingleRun([handle], self._host_arrays(data), priority,
                         next(self._seq), size, target)
        self._queue.append(run)
        self.handles.append(handle)
        return handle

    def sweep(self, workload: Union[str, Workload], data, grid: dict, *,
              version: Optional[str] = None, n_cores: Optional[int] = None,
              fused: bool = True, priority: int = 0,
              target: Optional[str] = None,
              **base_params) -> List[JobHandle]:
        """Submit the cartesian product of ``grid`` as one job per point.

        With ``fused=True`` (default), points whose ``fuse_key`` matches
        are gang-fused: one slice, one shared bank-resident dataset, one
        batched kernel launch per step for the whole gang (learning-rate
        sweeps collapse to a single dispatch).  Non-fusable points fall
        back to ordinary per-job scheduling.  Handles come back in grid
        order regardless of gang grouping.
        """
        wl = self._resolve_workload(workload)
        keys = sorted(grid)
        combos = [dict(zip(keys, values))
                  for values in itertools.product(*(grid[k] for k in keys))]
        specs = [wl.spec(version, **{**base_params, **combo})
                 for combo in combos]
        target = self._resolve_target(target)
        size = self._sized(n_cores, target)
        data = self._host_arrays(data)

        groups = (plan_fusion(wl, specs) if fused
                  else [[i] for i in range(len(specs))])
        handles: List[Optional[JobHandle]] = [None] * len(specs)
        for group in groups:
            group_handles = []
            for i in group:
                handle = JobHandle(next(self._next_job_id), wl, specs[i],
                                   priority, size)
                handle.target = target
                handles[i] = handle
                group_handles.append(handle)
                self.handles.append(handle)
            cls = _FusedRun if len(group) > 1 else _SingleRun
            self._queue.append(cls(group_handles, data, priority,
                                   next(self._seq), size, target))
        return handles

    # -- execution -----------------------------------------------------------

    def _admit(self) -> None:
        self._queue = [r for r in self._queue if r.live_jobs]
        pending = sorted(self._queue,
                         key=lambda r: (-r.priority, r.seq))
        blocked: set = set()    # head-of-line blocking is per target
        for run in pending:
            if run.target in blocked:
                continue
            alloc = self._allocators[run.target]
            lease = alloc.allocate(run.n_cores)
            if lease is None:
                if not self.backfill:
                    blocked.add(run.target)
                continue
            self._queue.remove(run)
            try:
                run.start(self.systems[run.target], lease)
            except Exception as err:  # noqa: BLE001 — bad data/spec must
                # fail the job, not unwind the other tenants' drain
                alloc.release(lease)
                for job in run.live_jobs:
                    job.error = err
                    job.state = JobState.FAILED
                self._finished.append(run)
                continue
            self._running.append(run)

    def step(self) -> bool:
        """One scheduling turn: admit what fits, then advance every
        running job by one gang step (round-robin, admission order).
        Returns True while any job is queued or running."""
        self._admit()
        still_running: List[_Runnable] = []
        for run in self._running:
            if run.advance():
                self._allocators[run.target].release(run.lease)
                self._finished.append(run)
            else:
                still_running.append(run)
        self._running = still_running
        return bool(self._running or self._queue)

    def drain(self) -> List[JobHandle]:
        """Run scheduling turns until every job reaches a terminal
        state; returns all handles.  One job's failure never stops the
        drain (failure isolation is per step, see _SingleRun.advance)."""
        while self.step():
            pass
        return self.handles

    # -- introspection -------------------------------------------------------

    def counts(self) -> dict:
        by_state: dict = {s.value: 0 for s in JobState}
        for h in self.handles:
            by_state[h.state.value] += 1
        return by_state

    def fragmentation(self) -> FragmentationStats:
        return self.allocator.fragmentation()

    def stats(self) -> dict:
        """Operator snapshot: job counts, occupancy, queue depth.

        The top-level occupancy keys describe the default target (the
        original single-system surface); ``targets`` breaks occupancy
        out per execution System on a mixed machine."""
        frag = self.fragmentation()
        out = {
            "jobs": self.counts(),
            "queued_runnables": len(self._queue),
            "running_runnables": len(self._running),
            "cores_used": frag.used_cores,
            "cores_free": frag.free_cores,
            "external_fragmentation": frag.external_fragmentation,
        }
        out["targets"] = {
            name: {
                "kind": getattr(self.systems[name], "kind", "pim"),
                "cores_used": f.used_cores,
                "cores_free": f.free_cores,
                "external_fragmentation": f.external_fragmentation,
            }
            for name, f in ((n, a.fragmentation())
                            for n, a in self._allocators.items())}
        return out
