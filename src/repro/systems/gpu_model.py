"""Modeled-GPU target: HostSystem numerics, A100 roofline reporting.

The paper's GPU comparison points (Figs. 13-17, Table 4) come from a
discrete GPU this container does not have.  Instead of echoing the
paper's reported speedup constants — which is what the benchmark driver
used to do — :class:`ModeledGpuSystem` *executes* every workload with
:class:`~repro.systems.host.HostSystem` semantics (bit-identical
results, asserted by tests/test_systems.py) and prices each compiled
program on a calibrated A100 roofline
(:class:`repro.launch.roofline.GpuRoofline`):

    seconds = launch_overhead + max(FLOPs / peak, bytes / HBM_bw)
    energy  = seconds * TDP

FLOPs and memory traffic are read from the XLA cost analysis of the
very executable the launch ran (``compiled.cost_analysis()``, drift-
normalized by :func:`repro.launch.hlo_analysis.normalize_cost_analysis`
— the same machinery the dry-run roofline uses), with an operand-bytes
fallback when the analysis is unavailable.  A fused k-step chunk is one
launch whose analyzed program already contains the whole scan, so step
fusion shrinks the modeled launch-overhead term exactly as it shrinks
the real dispatch count — the GPU column responds to the same
optimizations the PIM column does.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax

from ..launch.roofline import GpuRoofline, a100
from .base import _tree_bytes, adopt_parent_session, check_lease_bounds
from .host import HostConfig, HostSystem


@dataclasses.dataclass
class GpuModelConfig(HostConfig):
    roofline: GpuRoofline = dataclasses.field(default_factory=a100)


@dataclasses.dataclass
class GpuModelReport:
    """Accumulated roofline accounting of every launch on the system."""

    modeled_seconds: float = 0.0
    modeled_energy_j: float = 0.0
    launches: int = 0
    flops: float = 0.0
    hbm_bytes: float = 0.0

    def snapshot(self) -> "GpuModelReport":
        return dataclasses.replace(self)

    def delta(self, snapshot: "GpuModelReport") -> "GpuModelReport":
        return GpuModelReport(
            **{f.name: getattr(self, f.name) - getattr(snapshot, f.name)
               for f in dataclasses.fields(GpuModelReport)})


_REPORT_FIELDS = tuple(f.name for f in
                       dataclasses.fields(GpuModelReport))


class _MirrorGpuReport(GpuModelReport):
    """Slice-local roofline ledger that forwards every *increment* to
    the parent system's ``gpu`` report — the ``_MirrorStats`` pattern
    (systems/base.py) applied to modeled GPU accounting, so a job
    queue's global totals keep accumulating in one place while each
    slice's ``snapshot()/delta()`` stays per-job attributable
    (DESIGN.md §10.4)."""

    def __init__(self, parent: GpuModelReport):
        object.__setattr__(self, "_parent", parent)
        super().__init__()

    def __setattr__(self, name, value):
        if name in _REPORT_FIELDS:
            delta = value - getattr(self, name, 0)
            if delta > 0:
                setattr(self._parent, name,
                        getattr(self._parent, name) + delta)
        object.__setattr__(self, name, value)

    def snapshot(self) -> GpuModelReport:
        # a plain value snapshot — dataclasses.replace would try to
        # construct another mirror (whose __init__ wants a parent)
        return GpuModelReport(**{f: getattr(self, f)
                                 for f in _REPORT_FIELDS})

    def delta(self, snapshot: GpuModelReport) -> GpuModelReport:
        return self.snapshot().delta(snapshot)


class ModeledGpuSystem(HostSystem):
    """Host-CPU execution whose time/energy report is an A100 roofline."""

    kind = "gpu-model"

    def __init__(self, config: Optional[GpuModelConfig] = None,
                 devices: Optional[Sequence] = None):
        super().__init__(config or GpuModelConfig())
        self.roofline: GpuRoofline = getattr(self.config, "roofline",
                                             None) or a100()
        self.gpu = GpuModelReport()
        #: (jit key, shape signature) -> (flops, bytes) — one AOT
        #: lowering + cost analysis per compiled program, not per launch
        self._cost_cache: dict = {}

    # -- roofline pricing ----------------------------------------------------

    def _program_cost(self, key, step, args) -> tuple:
        sig = tuple((tuple(v.shape), str(v.dtype))
                    for v in jax.tree_util.tree_leaves(args))
        ckey = (key if isinstance(key, tuple) else (key,), sig)
        cached = self._cost_cache.get(ckey)
        if cached is None:
            cached = self._analyze(step, args)
            self._cost_cache[ckey] = cached
        return cached

    def _analyze(self, step, args) -> tuple:
        """(flops, bytes) of the compiled program; operand-bytes fallback
        when XLA's cost analysis is unavailable on this build."""
        fallback = (0.0, float(_tree_bytes(args)))
        try:
            from ..launch.hlo_analysis import normalize_cost_analysis
            ca = normalize_cost_analysis(
                step.lower(*args).compile().cost_analysis())
        except Exception:
            return fallback
        flops = float(ca.get("flops", 0.0) or 0.0)
        bytes_ = float(ca.get("bytes accessed", 0.0) or 0.0)
        if flops <= 0.0 and bytes_ <= 0.0:
            return fallback
        if bytes_ <= 0.0:
            bytes_ = fallback[1]
        return (flops, bytes_)

    def _record_execution(self, key, step, args, k: int = 1) -> None:
        flops, bytes_ = self._program_cost(key, step, args)
        seconds = self.roofline.kernel_seconds(flops, bytes_)
        self.gpu.launches += 1
        self.gpu.flops += flops
        self.gpu.hbm_bytes += bytes_
        self.gpu.modeled_seconds += seconds
        self.gpu.modeled_energy_j += self.roofline.kernel_energy_j(seconds)

    # -- multi-tenancy -------------------------------------------------------

    def slice(self, lease) -> "ModeledGpuSystem":
        return GpuModelSlice(self, lease)


class GpuModelSlice(ModeledGpuSystem):
    """Lane-scoped view of a parent ModeledGpuSystem: shared caches,
    mirrored TransferStats — and a slice-local :class:`_MirrorGpuReport`
    roofline ledger whose increments forward to the parent's ``gpu``,
    so global totals keep accumulating while
    ``slice.gpu.snapshot()/delta()`` yields the *per-job* modeled
    seconds of a mixed queue (DESIGN.md §10.4)."""

    def __init__(self, parent: ModeledGpuSystem, lease):
        check_lease_bounds(parent, lease, "lanes")
        self.parent = parent
        self.lease = lease
        super().__init__(dataclasses.replace(parent.config,
                                             n_cores=lease.n_cores))
        adopt_parent_session(self, parent)
        self.gpu = _MirrorGpuReport(parent.gpu)
        self._cost_cache = parent._cost_cache
