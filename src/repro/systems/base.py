"""The backend-portable ``System`` protocol (DESIGN.md §10).

The paper's central contribution is the processor-centric vs
memory-centric comparison (Figs. 13-17, Tables 5-7): every workload is
evaluated on a real PIM machine AND on matched CPU/GPU baselines driven
through identical harnesses.  This module makes that comparison a
first-class API: :class:`System` is the abstract execution surface the
trainers, the estimator facade, the workload registry, the scheduler,
and the fused step engine are written against, with three
implementations:

  ``PimSystem``        (systems/pim.py)       the paper's memory-centric
                       target: data sharded across banks, host-
                       orchestrated reduce, quantized hot loops.
  ``HostSystem``       (systems/host.py)      the processor-centric
                       baseline: one resident image, fp32 jnp hot
                       loops, ``TransferStats`` counting DRAM traffic.
  ``ModeledGpuSystem`` (systems/gpu_model.py) HostSystem numerics with
                       time/energy reported through a calibrated A100
                       roofline model (launch/roofline.py).

The surface (shared by all systems):
  put / shard_rows / row_validity_mask / broadcast     data placement
  register_kernel / named_kernel / registered_kernels  kernel registry
  map_reduce / map_reduce_custom / map_elementwise     execution
  step_program                                         fused k-step scan
  stats (TransferStats), slice(lease)                  accounting, tenancy

Per-system behavior lives in a small set of overridable hooks — the
placement methods plus the ``_charge_*`` accounting hooks — so the
execution semantics (kernel resolution, jit caching, reduce strategies,
scan fusion) are defined exactly once and cannot drift between targets.
Ghose et al. (arXiv:1907.12947) argue a PIM programming model must hide
the memory-centric/processor-centric split from the workload author;
here a trainer sees only ``dataset.system`` and never knows which side
it is running on.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import threading
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.trace import NULL_SPAN, TRACER


class ReduceVia(enum.Enum):
    """Legacy reduction selector (kept for config compatibility; the
    per-call ``strategy=`` argument accepts these, their string values,
    or a :class:`ReduceStrategy` instance)."""

    FABRIC = "fabric"   # on-fabric psum (TPU-native; strictly cheaper)
    HOST = "host"       # explicit host round trip (paper-faithful schedule)
    HIERARCHICAL = "hierarchical"  # rank-level fabric sum + host combine


@dataclasses.dataclass
class TransferStats:
    """Byte counters mirroring the paper's CPU-PIM / PIM-CPU breakdowns.

    The counters are shared across systems but their *semantics* are
    per-system (DESIGN.md §10.2):

    On a :class:`~repro.systems.pim.PimSystem`, ``cpu_to_pim`` counts
    every host->bank byte (dataset shards AND model broadcasts) and
    ``pim_to_cpu`` the reduce legs back — the paper's transfer
    breakdown.  On a :class:`~repro.systems.host.HostSystem` there is no
    CPU<->PIM boundary; those counters stay zero and ``dram_bytes``
    counts the memory traffic of the hot loop instead (the dataset
    bytes each training pass streams from DRAM — the processor-centric
    bottleneck the roofline model prices).

    ``shard_transfers``/``shard_bytes`` count dataset view
    materializations on every system, so callers can assert that a
    hyperparameter sweep over one :class:`PimDataset` pays for the
    partition exactly once (DESIGN.md §3).  ``kernel_launches`` counts
    host-issued kernel dispatches (one per ``map_reduce``/
    ``map_reduce_custom``/``map_elementwise`` call) — the scheduler's
    fused gang step is asserted against it (DESIGN.md §7.3).

    ``host_syncs`` counts host synchronization points — places where the
    host blocks on device results (one per ``map_reduce``/
    ``map_reduce_custom`` call, one per fused :class:`StepProgram`
    chunk).  The step-fusion engine's whole point is that a k-step chunk
    costs ONE sync instead of k (DESIGN.md §9).

    ``snapshot()``/``delta(snapshot)`` make the counters attributable
    when several jobs share one system: snapshot before the job, delta
    after, and the job's own bytes fall out even though the globals keep
    interleaving (DESIGN.md §7.2).
    """

    cpu_to_pim: int = 0
    pim_to_cpu: int = 0
    inter_core_via_host: int = 0
    shard_transfers: int = 0
    shard_bytes: int = 0
    kernel_launches: int = 0
    host_syncs: int = 0
    #: processor-centric targets only: bytes the training hot loop
    #: streams from DRAM (HostSystem / ModeledGpuSystem); 0 on PIM.
    dram_bytes: int = 0
    #: topology split of the reduce legs (PIM only — DESIGN.md §12.3):
    #: ``rank_local_bytes`` is intra-rank combine traffic (a rank-aligned
    #: HierarchicalReduce group folding its partials inside the rank);
    #: ``cross_rank_bytes`` is everything that crosses a rank boundary on
    #: its way to the host — the serialized leg the hierarchical cost
    #: model prices and contention-aware placement tries to localize.
    rank_local_bytes: int = 0
    cross_rank_bytes: int = 0
    #: EMB deferred-update accounting (DESIGN.md §15): ``flush_bytes``
    #: is the logical sparse update payload (ids + delta rows) shipped
    #: to the table shards by eager applies and deferred flushes alike —
    #: the counter the deferred-vs-eager traffic claim is asserted on.
    flush_bytes: int = 0
    #: actual wire bytes moved by int8 error-feedback compression
    #: (CompressedReduce and compressed EMB flushes) in place of the
    #: uncompressed payload counted above / in the reduce legs.
    compressed_bytes: int = 0

    def reset(self) -> None:
        for field in dataclasses.fields(TransferStats):
            setattr(self, field.name, 0)

    def snapshot(self) -> "TransferStats":
        """Point-in-time copy of every counter (a plain TransferStats).
        Taken under the mirroring lock so a caller-thread reading never
        sees a slice increment half-propagated to its parent."""
        with _STATS_LOCK:
            return TransferStats(
                **{f.name: getattr(self, f.name)
                   for f in dataclasses.fields(TransferStats)})

    def delta(self, snapshot: "TransferStats") -> "TransferStats":
        """Counters accumulated since ``snapshot`` was taken."""
        return TransferStats(
            **{f.name: getattr(self, f.name) - getattr(snapshot, f.name)
               for f in dataclasses.fields(TransferStats)})


_STAT_FIELDS = tuple(f.name for f in dataclasses.fields(TransferStats))

#: Serializes _MirrorStats increment mirroring: the scheduler's serve
#: thread charges slice counters while caller threads read ``stats()``
#: snapshots or submit work (DESIGN.md §14.2).  Reentrant because a
#: mirror's parent can itself be a mirror (slice-of-slice), nesting the
#: read-modify-write chain under one acquisition.
_STATS_LOCK = threading.RLock()


class _MirrorStats(TransferStats):
    """Slice-local counters that forward every *increment* to the parent
    system's stats.  ``reset()`` zeroes only the slice view — cumulative
    parent totals are never rolled back (only positive deltas mirror)."""

    def __init__(self, parent: TransferStats):
        object.__setattr__(self, "_parent", parent)
        super().__init__()

    def __setattr__(self, name, value):
        if name in _STAT_FIELDS:
            with _STATS_LOCK:
                delta = value - getattr(self, name, 0)
                if delta > 0:
                    setattr(self._parent, name,
                            getattr(self._parent, name) + delta)
                object.__setattr__(self, name, value)
            return
        object.__setattr__(self, name, value)


def check_lease_bounds(parent: "System", lease, unit: str = "cores") -> None:
    """Reject a lease extending past the parent's capacity (shared by
    every slice type — PimSlice, HostSlice, GpuModelSlice)."""
    if lease.stop > parent.config.n_cores:
        raise ValueError(f"lease {lease} exceeds the parent system "
                         f"({parent.config.n_cores} {unit})")


def adopt_parent_session(slice_: "System", parent: "System") -> None:
    """Wire a slice to its parent's session state: mirrored stats plus
    the shared kernel registry and jit cache (one compile serves every
    tenant).  Shared by the lane-scoped host/gpu slices; PimSlice keeps
    its own wiring because its cache sharing is backend-conditional."""
    slice_.stats = _MirrorStats(parent.stats)
    slice_._kernels = parent._kernels
    slice_._kernel_gen = parent._kernel_gen
    slice_._jit_cache = parent._jit_cache


def run_steps(gen):
    """Drain a trainer step generator and return its result.

    The iterative trainers expose ``fit_steps(dataset, cfg)`` generators
    (one host-orchestrated iteration per ``next()``) so the job
    scheduler can gang-step many fits concurrently; ``fit`` is simply
    this drain loop.  The fitted result travels on ``StopIteration``.
    """
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


class ChunkTick(int):
    """What a resumable trainer's ``fit_steps`` yields per chunk.

    Behaves as the plain iteration count (an ``int`` — every existing
    consumer keeps working), but additionally carries a lazy
    ``snapshot()`` hook: calling it while the generator is suspended at
    this yield materializes the trainer's chunk-boundary state as a
    ``{"arrays": {...}, "meta": {...}}`` dict — the serializable carry
    the elastic job runtime checkpoints (DESIGN.md §11).  The snapshot
    is lazy so trainers pay the device->host copies only when someone
    (preemption, the scheduler's checkpoint cadence) actually asks.
    """

    def __new__(cls, iters: int, snapshot_fn: Optional[Callable] = None):
        tick = super().__new__(cls, iters)
        tick._snapshot_fn = snapshot_fn
        return tick

    @property
    def resumable(self) -> bool:
        return self._snapshot_fn is not None

    def snapshot(self) -> Optional[dict]:
        """Materialize the chunk-boundary trainer state (None when the
        trainer is not resumable).  Only valid while the generator that
        yielded this tick is suspended at the yield."""
        if self._snapshot_fn is None:
            return None
        return self._snapshot_fn()


def chunk_schedule(n_iters: int, fuse_steps: int, record_every: int,
                   start: int = 0):
    """Chunk sizes covering ``n_iters`` fused-step iterations, with
    record points forced onto chunk boundaries: each chunk is
    ``min(fuse_steps, next record point, remaining)`` (shared by the GD
    and K-Means trainers and the fused gang — DESIGN.md §9.3).

    ``start`` resumes the schedule mid-run (elastic restore, DESIGN.md
    §11): chunks continue from iteration ``start`` exactly as the
    uninterrupted schedule would have cut them — checkpoints always land
    on chunk boundaries, so a resumed fit replays the identical chunk
    sequence from that boundary on."""
    it = start
    while it < n_iters:
        k = min(fuse_steps, n_iters - it)
        if record_every:
            next_rec = (it // record_every + 1) * record_every
            k = min(k, next_rec - it)
        yield k
        it += k


# ---------------------------------------------------------------------------
# Reduction strategies (pluggable per map_reduce call).
# ---------------------------------------------------------------------------

class ReduceStrategy:
    """How per-shard partials are combined into the host-visible result.

    ``device_reduce`` runs inside the compiled step (traced); ``finalize``
    runs on the host afterwards; ``count_pim_to_cpu`` models the PIM->CPU
    bytes the schedule moves (PIM systems only — processor-centric
    systems bypass strategy byte accounting entirely, see
    ``System._charge_reduce``).  ``cache_token`` namespaces the jit cache.

    Step fusion (DESIGN.md §9): ``fusable`` says whether the schedule can
    run entirely on device inside a ``lax.scan`` chunk;
    ``device_reduce_full`` is the fully-on-device reduction the scan body
    uses (for :class:`HierarchicalReduce` it completes the host-combine
    leg on fabric); ``count_chunk`` is the analytic per-chunk byte
    accounting — the reduce still moves k× the single-step bytes even
    when the host round-trip is fused away.
    """

    name = "base"
    #: False when the per-step reduction needs the host (HostReduce): a
    #: StepProgram then degrades to per-step map_reduce syncs.
    fusable = True

    def bind(self, system: "System") -> "ReduceStrategy":
        """Resolve any topology-derived parameters against the system
        about to execute (called once per map_reduce / StepProgram).
        Base strategies have none — they bind to themselves;
        :class:`HierarchicalReduce` derives an unset ``group_size`` from
        the system's rank tree here."""
        return self

    def device_reduce(self, partials):
        return partials

    def device_reduce_full(self, partials):
        """Complete on-device reduction for use inside a fused scan."""
        return self.device_reduce(partials)

    def finalize(self, system: "System", out):
        return out

    def count_pim_to_cpu(self, system: "System", out) -> int:
        raise NotImplementedError

    def count_topology(self, system: "System", out) -> tuple:
        """Rank-level split ``(rank_local_bytes, cross_rank_bytes)`` of
        one step's reduce movement (DESIGN.md §12.3).  Flat schedules
        ship every partial over the host link — all bytes cross a rank
        boundary; :class:`HierarchicalReduce` reclassifies the
        intra-group leg as rank-local when its groups sit inside ranks.
        """
        return 0, self.count_pim_to_cpu(system, out)

    def count_chunk(self, system: "System", out, k: int) -> None:
        """Account k fused steps' reduce movement (``out`` is the
        abstract per-step ``device_reduce`` result)."""
        system.stats.pim_to_cpu += k * self.count_pim_to_cpu(system, out)
        rank_local, cross_rank = self.count_topology(system, out)
        system._charge_topology(k * rank_local, k * cross_rank)

    def cache_token(self):
        return self.name


def _leaf_bytes(v) -> int:
    """nbytes of an array OR an abstract value (ShapeDtypeStruct)."""
    nb = getattr(v, "nbytes", None)
    if nb is None:
        nb = int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
    return int(nb)


def _tree_bytes(tree) -> int:
    return sum(_leaf_bytes(v) for v in jax.tree_util.tree_leaves(tree))


def _host_sum(tree, axis=0):
    """Promoted numpy reduction (int64 / float64 accumulators)."""
    return jax.tree_util.tree_map(
        lambda v: np.sum(np.asarray(v, np.int64)
                         if np.issubdtype(np.asarray(v).dtype, np.integer)
                         else np.asarray(v, np.float64), axis=axis),
        tree)


class FabricReduce(ReduceStrategy):
    """On-device sum over the cores axis (psum under shard_map)."""

    name = "fabric"

    def device_reduce(self, partials):
        return jax.tree_util.tree_map(lambda v: jnp.sum(v, axis=0),
                                      partials)

    def count_pim_to_cpu(self, system, out) -> int:
        # every core ships its partial of the reduced shape to the host
        return _tree_bytes(out) * system.config.n_cores

    def finalize(self, system, out):
        return out


class HostReduce(ReduceStrategy):
    """Paper-faithful schedule: per-core partials are copied to the host
    and reduced with numpy; the result lives on the host (the caller then
    ``broadcast``s the updated model, completing the round trip).

    Not fusable: the reduce itself IS a host round trip, so a
    :class:`StepProgram` chunk degrades to k per-step syncs (DESIGN.md
    §9) — faithful to the UPMEM topology, where fusing the update
    on-device would still leave per-step host reduction."""

    name = "host"
    fusable = False

    def count_pim_to_cpu(self, system, out) -> int:
        return _tree_bytes(out)  # stacked (n_cores, ...) leaves

    def finalize(self, system, out):
        return _host_sum(jax.device_get(out))


class HierarchicalReduce(ReduceStrategy):
    """Two-level schedule: fabric sum inside each rank of ``group_size``
    cores, then a host combine of the rank partials — the PIM analogue of
    the multi-pod RS->AR->AG decomposition in distributed/collectives.py
    (each rank's leader ships 1/group_size of the flat-host bytes over the
    host link; see ``cross_pod_bytes``).

    ``group_size=None`` derives the group from the executing system's
    rank tree at :meth:`bind` time (the largest divisor of the core
    count that fits one rank) — the group that keeps the fabric leg
    rank-local instead of a hand-picked constant (DESIGN.md §12.3)."""

    def __init__(self, group_size: Optional[int] = 8):
        self.group_size = group_size
        self.name = f"hier{group_size}" if group_size is not None else "hier-auto"

    def bind(self, system: "System") -> "HierarchicalReduce":
        if self.group_size is not None:
            return self
        from .topology import DEFAULT_DPUS_PER_RANK  # no cycle: topology is leaf
        topo = getattr(system, "topology", None)
        cap = topo.dpus_per_rank if topo is not None else DEFAULT_DPUS_PER_RANK
        n = system.config.n_cores
        group = max((d for d in range(1, min(cap, n) + 1) if n % d == 0),
                    default=1)
        return HierarchicalReduce(group)

    def cache_token(self):
        return ("hier", self.group_size)

    def _groups(self, n_cores: int) -> int:
        g = self.group_size
        return n_cores // g if g > 1 and n_cores % g == 0 else 0

    def device_reduce(self, partials):
        def _grouped(v):
            n_cores = v.shape[0]
            n_groups = self._groups(n_cores)
            if not n_groups:        # awkward core count: flat host schedule
                return v
            return jnp.sum(
                v.reshape(n_groups, self.group_size, *v.shape[1:]), axis=1)
        return jax.tree_util.tree_map(_grouped, partials)

    def count_pim_to_cpu(self, system, out) -> int:
        return _tree_bytes(out)  # (n_groups, ...) rank partials

    def _groups_rank_local(self, system: "System") -> bool:
        """Do the reduce groups sit inside physical ranks?  True when
        the system exposes a topology whose rank is a whole multiple of
        the group (aligned groups never straddle a rank boundary)."""
        topo = getattr(system, "topology", None)
        return (topo is not None and self.group_size is not None
                and 1 < self.group_size <= topo.dpus_per_rank
                and topo.dpus_per_rank % self.group_size == 0)

    def count_topology(self, system, out) -> tuple:
        # Two legs per step: every core's partial folds into its group
        # (group_size x the rank-partial bytes), then the rank partials
        # cross to the host.  The intra-group leg is rank-local only
        # when the groups are rank-aligned; straddling groups drag it
        # across rank boundaries too.
        if not self._groups(system.config.n_cores):
            return 0, _tree_bytes(out)        # flat fallback: all cross
        out_bytes = _tree_bytes(out)
        intra = out_bytes * self.group_size
        if self._groups_rank_local(system):
            return intra, out_bytes
        return 0, intra + out_bytes

    def device_reduce_full(self, partials):
        """In a fused scan the rank partials combine on fabric instead of
        on the host (int32 accumulation — exact whenever the flat fabric
        sum is, which the GD/KME value ranges guarantee)."""
        return jax.tree_util.tree_map(
            lambda v: jnp.sum(v, axis=0), self.device_reduce(partials))

    def count_chunk(self, system, out, k: int) -> None:
        # same per-step movement as the unfused schedule: each step the
        # rank partials leave the ranks AND cross the (modeled) host
        # link, k times per chunk
        system.stats.pim_to_cpu += k * self.count_pim_to_cpu(system, out)
        if self._groups(system.config.n_cores):
            system._charge_inter_core(k * _tree_bytes(out))
        rank_local, cross_rank = self.count_topology(system, out)
        system._charge_topology(k * rank_local, k * cross_rank)

    def finalize(self, system, out):
        # intra-rank movement happened "on fabric"; record the rank->host
        # leg separately so the hierarchy's saving is visible in the
        # stats (1/group_size of the flat-host bytes, same napkin as
        # collectives.cross_pod_bytes).  If the core count forced the
        # flat fallback, no rank-level reduction occurred — record none.
        # The write goes through the system hook: on a processor-centric
        # target there is no host link, and the counter must stay 0.
        if self._groups(system.config.n_cores):
            system._charge_inter_core(_tree_bytes(out))
        return _host_sum(jax.device_get(out))


_STRATEGIES: dict[str, Callable[[], ReduceStrategy]] = {
    "fabric": FabricReduce,
    "host": HostReduce,
    "hierarchical": HierarchicalReduce,
    # topology-derived group (resolved per system at bind time)
    "hierarchical-auto": lambda: HierarchicalReduce(group_size=None),
}

StrategyLike = Union[None, str, ReduceVia, ReduceStrategy]


def resolve_reduce_strategy(spec: StrategyLike,
                            default: StrategyLike = None) -> ReduceStrategy:
    if spec is None:
        spec = default if default is not None else "fabric"
    if isinstance(spec, ReduceStrategy):
        return spec
    if isinstance(spec, ReduceVia):
        spec = spec.value
    if isinstance(spec, str) and spec in _STRATEGIES:
        return _STRATEGIES[spec]()
    raise ValueError(f"unknown reduce strategy {spec!r}; "
                     f"known: {sorted(_STRATEGIES)}")


# ---------------------------------------------------------------------------
# The System protocol.
# ---------------------------------------------------------------------------

class System:
    """Abstract execution target behind the workload-session API.

    Subclasses implement the data-placement surface (``shard_rows``,
    ``row_validity_mask``, ``broadcast``), declare their identity
    (``kind``, ``n_shards``), and override the ``_charge_*`` accounting
    hooks; the execution machinery — kernel registry, jit caching,
    reduce strategies, :class:`StepProgram` fusion — is shared and
    defined exactly once here.

    ``config`` must expose ``n_cores`` (the scheduling width the bank
    allocator carves — physical PIM cores, or thread-pool lanes on a
    host target), ``n_threads``, and ``reduce`` (the default strategy).
    ``n_shards`` is the *data-parallel* width of the leading shard axis
    — equal to ``n_cores`` on PIM, and 1 on processor-centric targets,
    which keep one resident image regardless of lane count.
    """

    #: target identity: "pim" | "host" | "gpu-model" (CLI spelling)
    kind: str = "abstract"
    #: True on processor-centric targets with native transcendentals:
    #: the LOG fp32 baseline then uses the exact sigmoid (the paper's
    #: MKL/cuML baselines), not the DPU Taylor expansion.
    exact_transcendentals: bool = False

    def __init__(self, config):
        self.config = config
        self.stats = TransferStats()
        self._jit_cache: dict = {}
        self._kernels: dict[str, Callable] = {}
        self._kernel_gen: dict[str, int] = {}
        #: trace timeline for this system's kernel launches (precomputed
        #: so the hot path never builds the string — DESIGN.md §13.2)
        self._trace_track = f"system:{self.kind}"

    def _launch_span(self, op: str, kkey):
        """Span covering one kernel launch on the system's trace track.

        The overhead contract (repro.obs.trace): when tracing is off
        this returns the shared no-op before any span *name* is built —
        the f-string below never runs on the untraced hot path."""
        if not TRACER.enabled:
            return NULL_SPAN
        name = (kkey[1] if kkey[0] == "named"
                else getattr(kkey[1], "__name__", "fn"))
        return TRACER.span(f"{op}:{name}", self._trace_track, "launch")

    # -- identity ------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        """Width of the leading shard axis ``shard_rows`` produces."""
        raise NotImplementedError

    # -- data placement ------------------------------------------------------

    def put(self, X, y=None) -> "Any":
        """Place a dataset on this system ONCE and return a
        :class:`repro.api.dataset.PimDataset` handle.

        The handle owns the resident arrays, the validity mask, and
        per-version views (lazily materialized, cached), so repeated
        fits / restarts / sweeps reuse one placement per view."""
        from ..api.dataset import PimDataset  # local import: api -> systems
        return PimDataset(self, X, y)

    def put_table(self, weights, *, placement: str = "mod",
                  seed: int = 0) -> "Any":
        """Row-shard an embedding table across this system's bank
        extents ONCE and return a
        :class:`repro.api.table.ShardedTable` handle (the PimDataset
        sibling for sharded model state — DESIGN.md §15.1)."""
        from ..api.table import ShardedTable  # local import: api -> systems
        return ShardedTable(self, weights, placement=placement, seed=seed)

    def shard_rows(self, x: np.ndarray, pad_value=0) -> jnp.ndarray:
        """Partition rows: (n, ...) -> (n_shards, n_per_shard, ...)."""
        raise NotImplementedError

    def row_validity_mask(self, n: int) -> jnp.ndarray:
        """(n_shards, n_per_shard) bool mask marking real rows."""
        raise NotImplementedError

    def broadcast(self, tree: Any) -> Any:
        """Model-state broadcast to every execution site (accounted)."""
        raise NotImplementedError

    # -- kernel registry -----------------------------------------------------

    def register_kernel(self, name: str, fn: Callable) -> str:
        """Register (or replace) a named per-shard kernel.

        Re-registering a name with a different function bumps a generation
        counter, orphaning any compiled entries for the old function — a
        stale kernel can never be served for a new registration."""
        if self._kernels.get(name) is not fn:
            self._kernel_gen[name] = self._kernel_gen.get(name, -1) + 1
            self._kernels[name] = fn
        return name

    def named_kernel(self, name: str, builder: Callable[[], Callable]) -> str:
        """Register ``builder()`` under ``name`` unless already present.

        The idiom for parameterized kernel factories: encode the factory
        parameters in the name (e.g. ``"kme.assign/k=16"``) and the
        compiled kernel is reused across fits and restarts."""
        if name not in self._kernels:
            self.register_kernel(name, builder())
        return name

    def registered_kernels(self) -> tuple:
        """Sorted names of all registered kernels (diagnostics/tests).

        Trainer kernel names encode their dispatch routing — e.g.
        ``"kme.assign/k16/be=pallas_tpu"`` — so this is also how tests
        assert that a fit actually went through the kernel tier."""
        return tuple(sorted(self._kernels))

    def _resolve_kernel(self, kernel) -> tuple:
        """Map a kernel reference to (stable cache key, callable).

        Named kernels key by (name, generation).  Raw callables key by the
        function object itself — the cache then holds a strong reference,
        so the function cannot be collected and its identity can never be
        recycled for a different kernel (the id()-reuse bug this replaced).
        """
        if isinstance(kernel, str):
            fn = self._kernels.get(kernel)
            if fn is None:
                raise KeyError(
                    f"no kernel registered under {kernel!r}; "
                    f"known: {sorted(self._kernels)}")
            return ("named", kernel, self._kernel_gen[kernel]), fn
        if not callable(kernel):
            raise TypeError(f"kernel must be a registered name or a "
                            f"callable, got {type(kernel).__name__}")
        return ("fn", kernel), kernel

    # -- accounting hooks (per-system TransferStats semantics) ---------------

    def _charge_launch_operands(self, sharded, replicated) -> None:
        """Per-launch operand movement.  PIM: none (data is bank-
        resident).  Host targets: the pass streams the shards from DRAM.
        """

    def _charge_reduce(self, strat: ReduceStrategy, out) -> None:
        """Post-reduce movement of one map_reduce launch."""
        self.stats.pim_to_cpu += strat.count_pim_to_cpu(self, out)
        rank_local, cross_rank = strat.count_topology(self, out)
        self._charge_topology(rank_local, cross_rank)

    def _charge_reduce_custom(self, out) -> None:
        self.stats.pim_to_cpu += _tree_bytes(out) * self.config.n_cores
        # flat custom reduce: every per-core partial crosses to the host
        self._charge_topology(0, _tree_bytes(out) * self.config.n_cores)

    def _charge_topology(self, rank_local: int, cross_rank: int) -> None:
        """Rank-level classification of reduce movement (DESIGN.md
        §12.3).  Host targets override to a no-op: a single resident
        image has no rank tree."""
        self.stats.rank_local_bytes += rank_local
        self.stats.cross_rank_bytes += cross_rank

    def _charge_inter_core(self, nbytes: int) -> None:
        """Modeled inter-core-via-host movement (HierarchicalReduce's
        rank->host leg).  Host targets override to a no-op: there is no
        host link between shards of a single resident image."""
        self.stats.inter_core_via_host += nbytes

    def _charge_elementwise(self, sharded, replicated) -> None:
        self.stats.cpu_to_pim += sum(
            np.asarray(v).nbytes for v in replicated) * self.config.n_cores

    def _charge_chunk(self, carry, sharded, reduced_shape,
                      strat: ReduceStrategy, k: int) -> None:
        """Analytic accounting of one fused k-step chunk (DESIGN.md
        §9.2): the carry (model state) enters the banks once per chunk;
        the reduce legs move k× the single-step bytes."""
        self.stats.cpu_to_pim += _tree_bytes(carry) * self.config.n_cores
        strat.count_chunk(self, reduced_shape, k)

    def _charge_chunk_boundary(self, carry, outs) -> None:
        """One sync per chunk boundary: final carry + stacked emits."""
        self.stats.pim_to_cpu += _tree_bytes(carry) + _tree_bytes(outs)

    def _record_execution(self, key, step, args, k: int = 1) -> None:
        """Post-launch modeling hook (``ModeledGpuSystem`` prices the
        compiled program on a roofline here).  ``step`` is the jitted
        callable, ``args`` its call arguments, ``k`` the number of
        training iterations the launch covered."""

    # -- execution ------------------------------------------------------------

    def map_reduce(self, kernel, sharded: tuple, replicated: tuple,
                   strategy: StrategyLike = None):
        """Run ``kernel(*shard_args, *replicated)`` on every shard and
        reduce the resulting pytree across the shard axis.

        ``kernel`` is a registered name or a callable.  ``strategy`` picks
        the reduction schedule per call ("fabric" | "host" |
        "hierarchical" | a ReduceStrategy); default is the system config.
        Movement is tracked for every schedule in the system's own
        TransferStats semantics."""
        strat = resolve_reduce_strategy(strategy, self.config.reduce).bind(self)
        kkey, fn = self._resolve_kernel(kernel)
        key = ("map_reduce", kkey, len(sharded), len(replicated),
               strat.cache_token())
        step = self._jit_cache.get(key)
        if step is None:
            step = self._build_step(fn, strat)
            self._jit_cache[key] = step
        self.stats.kernel_launches += 1
        self.stats.host_syncs += 1
        self._charge_launch_operands(sharded, replicated)
        with self._launch_span("map_reduce", kkey):
            out = step(tuple(sharded), tuple(replicated))
        self._record_execution(key, step, (tuple(sharded),
                                           tuple(replicated)))
        self._charge_reduce(strat, out)
        return strat.finalize(self, out)

    def map_reduce_custom(self, kernel, sharded: tuple,
                          replicated: tuple, reduce: dict):
        """Like map_reduce but with per-key reduce ops ("sum"|"min"|"max").

        Used by DTR's min-max command (the host reduces per-core extrema).
        """
        kkey, fn = self._resolve_kernel(kernel)
        key = ("custom", kkey, tuple(sorted(reduce.items())))
        step = self._jit_cache.get(key)
        if step is None:
            def _step(sharded_, replicated_, _fn=fn):
                partials = self._per_core(_fn, sharded_, replicated_)
                return {k: (jnp.sum(v, axis=0) if reduce[k] == "sum"
                            else jnp.min(v, axis=0) if reduce[k] == "min"
                            else jnp.max(v, axis=0))
                        for k, v in partials.items()}
            step = jax.jit(_step)
            self._jit_cache[key] = step
        self.stats.kernel_launches += 1
        self.stats.host_syncs += 1
        self._charge_launch_operands(sharded, replicated)
        with self._launch_span("custom", kkey):
            out = step(tuple(sharded), tuple(replicated))
        self._record_execution(key, step, (tuple(sharded),
                                           tuple(replicated)))
        self._charge_reduce_custom(out)
        return out

    def map_elementwise(self, kernel, sharded: tuple, replicated: tuple):
        """Per-shard kernel with *no* reduction: output stays resident
        (DTR's split-commit).  Only the replicated command arguments
        cross the boundary; counted accordingly."""
        kkey, fn = self._resolve_kernel(kernel)
        key = ("elem", kkey)
        step = self._jit_cache.get(key)
        if step is None:
            step = jax.jit(
                lambda s, r, _fn=fn: self._per_core(_fn, s, r))
            self._jit_cache[key] = step
        self.stats.kernel_launches += 1
        self._charge_elementwise(sharded, replicated)
        with self._launch_span("elem", kkey):
            out = step(tuple(sharded), tuple(replicated))
        self._record_execution(key, step, (tuple(sharded),
                                           tuple(replicated)))
        return out

    def _per_core(self, local_fn, sharded, replicated):
        """Trace the per-shard kernel (vmap over the shard axis)."""
        return jax.vmap(lambda *s: local_fn(*s, *replicated))(*sharded)

    def _build_step(self, local_fn, strat: ReduceStrategy):
        """Compile one step: per-shard kernel + on-device reduce stage."""
        def step(sharded, replicated):
            partials = self._per_core(local_fn, sharded, replicated)
            return strat.device_reduce(partials)
        return jax.jit(step)

    def step_program(self, kernel, prepare: Callable, update: Callable,
                     *, name: str, strategy: StrategyLike = None,
                     select: Optional[Callable] = None) -> "StepProgram":
        """Build a :class:`StepProgram` over a registered kernel.

        ``prepare(carry) -> replicated`` derives the per-step broadcast
        arguments (e.g. quantized weights) from the carry; ``update(carry,
        reduced) -> (carry, out)`` applies the host-update math — both
        pure jnp functions, traced into the fused chunk.  ``select(
        sharded, x) -> sharded`` (optional) derives each step's shard
        view from a per-step scan input ``x`` — how minibatch SGD feeds
        precomputed batch offsets into the fused scan (DESIGN.md §9.5).
        ``name`` is the jit-cache namespace for the closure set and must
        encode every parameter baked into it (same convention as
        ``named_kernel``)."""
        return StepProgram(self, kernel, prepare, update, name=name,
                           strategy=strategy, select=select)

    # -- multi-tenancy -------------------------------------------------------

    def slice(self, lease) -> "System":
        """Execution view scoped to a :class:`~repro.sched.allocator.
        BankLease` — the surface the job scheduler runs tenants on."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support scheduling slices")


class StepProgram:
    """k consecutive training steps compiled into ONE ``lax.scan`` launch.

    The unfused trainers drive every iteration from the host: broadcast
    the model, launch the kernel, reduce, pull the result back, update in
    numpy, repeat — the CPU<->PIM synchronization cadence the paper (and
    PIM-Opt, arXiv:2404.07164) identify as the dominant cost once kernels
    are resident.  A StepProgram keeps the whole iterate-update-broadcast
    cycle on device: per scan step it runs ``prepare(carry)`` (weight
    quantization), the per-core kernel, the strategy's full on-device
    reduce, and ``update(carry, reduced)`` (dequantize + GD update) —
    with the carry buffers donated, so k steps cost one dispatch and one
    host sync instead of k of each (DESIGN.md §9).

    Works on ANY :class:`System` (DESIGN.md §10): on a processor-centric
    target there is no reduce leg to fuse away, so the chunk collapses
    to a plain k-iteration scan over the resident image — still one
    dispatch and one sync per chunk.

    Minibatch SGD (DESIGN.md §9.5): a ``select`` hook plus per-chunk
    ``xs`` feed precomputed batch offsets through the scan, so SGD
    configs fuse too — the host draws the chunk's offsets from the same
    rng stream the serial loop uses, then sleeps for the whole chunk.

    Numerics: prepare/update are the *same* closures the serial loop
    applies between launches, so for the integer versions a fused chunk
    is bit-identical to k unfused steps (asserted by
    tests/test_step_fusion.py).

    Degradation: a non-``fusable`` strategy (HostReduce — the reduce
    itself is a host round trip) runs the chunk as k ordinary
    ``map_reduce`` steps with identical accounting to the unfused loop.
    """

    def __init__(self, system: System, kernel, prepare: Callable,
                 update: Callable, *, name: str,
                 strategy: StrategyLike = None,
                 select: Optional[Callable] = None):
        self.system = system
        self.prepare = prepare
        self.update = update
        self.select = select
        self.name = name
        self.strategy = resolve_reduce_strategy(
            strategy, system.config.reduce).bind(system)
        self._kernel = kernel
        self._kkey, self._fn = system._resolve_kernel(kernel)

    # -- fused chunk ---------------------------------------------------------

    def _build_chunk(self, k: int, with_xs: bool, donate: bool = True):
        prepare, update, strat = self.prepare, self.update, self.strategy
        per_core, fn, select = self.system._per_core, self._fn, self.select

        def chunk(carry, sharded, xs):
            def one_step(carry, x):
                shards = select(sharded, x) if with_xs else sharded
                replicated = prepare(carry)
                partials = per_core(fn, shards, replicated)
                reduced = strat.device_reduce_full(partials)
                return update(carry, reduced)
            return jax.lax.scan(one_step, carry, xs, length=k)
        # donate the carry: the model state is updated in place on
        # device, never materialized on the host inside the chunk.
        # Pipelined callers (ChunkPipeline depth >= 2) must keep the
        # chunk-N boundary carry readable while chunk N+1 is in flight,
        # so they compile without donation — same numerics, extra buffer.
        return jax.jit(chunk, donate_argnums=0 if donate else ())

    def _reduced_shape(self, carry, sharded, xs):
        """Abstract per-step ``device_reduce`` output (eval_shape, cached)
        — what the analytic chunk accounting sizes the reduce legs by.
        Keyed by the operand shapes: one system can run same-named
        programs over datasets of different widths (and slices share
        the parent cache), so name alone would serve stale shapes and
        corrupt the byte accounting."""
        sig = tuple((v.shape, str(v.dtype)) for v in
                    jax.tree_util.tree_leaves((carry, sharded, xs)))
        key = ("step_bytes", self._kkey, self.name,
               self.strategy.cache_token(), sig,
               self.system.config.n_cores)
        out = self.system._jit_cache.get(key)
        if out is None:
            def reduce_stage(carry, sharded, xs):
                shards = sharded
                if xs is not None and self.select is not None:
                    x0 = jax.tree_util.tree_map(lambda v: v[0], xs)
                    shards = self.select(sharded, x0)
                partials = self.system._per_core(
                    self._fn, shards, self.prepare(carry))
                return self.strategy.device_reduce(partials)
            out = jax.eval_shape(reduce_stage, carry, sharded, xs)
            self.system._jit_cache[key] = out
        return out

    def run(self, carry, sharded: tuple, k: int, xs=None, *,
            donate: bool = True):
        """Advance ``carry`` by ``k`` fused steps over the resident
        shards; returns ``(carry, outs)`` where ``outs`` stacks the
        per-step emits (None when ``update`` emits nothing).  ``xs`` is
        an optional pytree of per-step scan inputs with leading dim
        ``k`` routed to the ``select`` hook (minibatch offsets).

        One kernel launch and one host sync for the whole chunk; the
        analytic byte accounting charges the carry broadcast once, the
        reduce movement k times, and one chunk-boundary PIM->CPU sync of
        the final carry + emits (DESIGN.md §9.2).

        ``donate=False`` compiles the chunk without carry donation so
        the input carry stays readable after dispatch — required when a
        :class:`ChunkPipeline` overlaps chunk N+1 with the host drain of
        boundary N (DESIGN.md §14.1).  Donation only affects buffer
        reuse, never numerics."""
        sharded = tuple(sharded)
        if k <= 0:
            return carry, None
        with_xs = xs is not None
        if with_xs and self.select is None:
            raise ValueError("xs given but this StepProgram has no "
                             "select hook")
        if not self.strategy.fusable:
            return self._run_per_step(carry, sharded, k, xs)
        # n_cores in the key: slices share the parent jit cache (vmap
        # backend) and hierarchical rank-partial shapes depend on width
        key = ("step_program", self._kkey, self.name,
               self.strategy.cache_token(), len(sharded), k, with_xs,
               donate, self.system.config.n_cores)
        chunk = self.system._jit_cache.get(key)
        if chunk is None:
            chunk = self._build_chunk(k, with_xs, donate)
            self.system._jit_cache[key] = chunk
        stats = self.system.stats
        stats.kernel_launches += 1
        stats.host_syncs += 1
        self.system._charge_chunk(
            carry, sharded, self._reduced_shape(carry, sharded, xs),
            self.strategy, k)
        if TRACER.enabled:
            with TRACER.span(f"chunk:{self.name}",
                             self.system._trace_track, "launch", k=k):
                carry, outs = chunk(carry, sharded, xs)
        else:
            carry, outs = chunk(carry, sharded, xs)
        self.system._record_execution(key, chunk, (carry, sharded, xs),
                                      k=k)
        # one pim->cpu sync per chunk boundary: final carry + emits
        self.system._charge_chunk_boundary(carry, outs)
        return carry, outs

    def _run_per_step(self, carry, sharded: tuple, k: int, xs=None):
        """HostReduce degradation: k single steps, each with the per-step
        broadcast + host reduce + host-visible update of the unfused
        loop (byte/launch/sync accounting identical to not fusing)."""
        outs = []
        for i in range(k):
            shards = sharded
            if xs is not None:
                x = jax.tree_util.tree_map(lambda v: v[i], xs)
                shards = tuple(self.select(sharded, x))
            replicated = self.system.broadcast(self.prepare(carry))
            reduced = self.system.map_reduce(
                self._kernel, shards, tuple(replicated),
                strategy=self.strategy)
            carry, out = self.update(carry, reduced)
            outs.append(out)
        if outs and outs[0] is not None:
            outs = jax.tree_util.tree_map(
                lambda *vals: jnp.stack(vals), *outs)
        else:
            outs = None
        return carry, outs


@dataclasses.dataclass
class ChunkBoundary:
    """One dispatched-but-not-yet-drained chunk inside a
    :class:`ChunkPipeline`: the post-chunk carry/emits (device futures
    until someone reads them) plus the caller's ``tag`` — the
    host-side state captured at dispatch time (iteration count, packed
    rng, ...) that the boundary's record/snapshot work needs."""

    k: int
    carry: Any
    outs: Any
    tag: Any = None


class ChunkPipeline:
    """Double-buffered :class:`StepProgram` driver (DESIGN.md §14.1).

    JAX dispatch is asynchronous: ``StepProgram.run`` returns device
    futures, and the host only blocks when it *reads* them (``record``
    eval, convergence flags, ``ChunkTick.snapshot()``).  The serial
    trainer loop wastes that: it drains boundary N before dispatching
    chunk N+1, so the device idles for every host-side record.  A
    ChunkPipeline keeps ``depth`` chunks in flight — ``dispatch()``
    launches the next chunk immediately and hands back the boundaries
    that have fallen ``depth`` behind, which the caller drains while
    the device works.

    Sync discipline: the drained :class:`ChunkBoundary` is the only
    place reads happen; everything the drain needs that lives on the
    host (iteration counters, rng state) must be captured eagerly at
    dispatch time via ``tag`` — by drain time the trainer's live
    variables have already advanced past this boundary.

    ``depth=1`` degenerates to the serial cadence (dispatch, drain,
    repeat) and keeps carry donation; ``depth>=2`` disables donation so
    boundary N stays readable while chunk N+1 executes.  Numerics are
    untouched either way — pipelining reorders host work only, so a
    pipelined fit is bit-identical to the serial one (asserted by
    tests/test_step_fusion.py).
    """

    def __init__(self, program: StepProgram, depth: int = 2):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.program = program
        self.depth = depth
        self._pending: collections.deque = collections.deque()

    @property
    def donate(self) -> bool:
        """Depth 1 never holds a boundary while the next chunk runs, so
        the in-place carry update stays safe."""
        return self.depth == 1

    @property
    def in_flight(self) -> int:
        return len(self._pending)

    def dispatch(self, carry, sharded: tuple, k: int, xs=None, tag=None):
        """Launch the next ``k``-step chunk and return ``(new_carry,
        drained)`` where ``drained`` lists the boundaries now due for
        host processing (empty until the pipeline fills).  ``new_carry``
        is a device future — feed it straight into the next dispatch,
        never read it directly (read drained boundaries instead)."""
        carry, outs = self.program.run(carry, sharded, k, xs=xs,
                                       donate=self.donate)
        self._pending.append(ChunkBoundary(k, carry, outs, tag))
        drained = []
        while len(self._pending) >= self.depth:
            drained.append(self._pending.popleft())
        return carry, drained

    def flush(self) -> list:
        """Hand back every still-in-flight boundary (end of schedule or
        early stop).  Boundaries dispatched after a stop decision are
        the caller's to discard — for the convergence-latched trainers
        an overshot chunk is a frozen no-op, so discarding it is exact
        (DESIGN.md §14.1)."""
        drained = list(self._pending)
        self._pending.clear()
        return drained
