"""Hierarchical PIM memory-fidelity model (DESIGN.md §12).

The paper's kernel-time results (Figs. 8-10) and scaling curves
(Figs. 11-12) are shaped by the UPMEM memory hierarchy — MRAM<->WRAM
DMA granularity, rank-level transfer serialization, per-channel
host-link bandwidth — none of which a flat per-core
``max(compute, mram_bw)`` formula can see.  This module models the
hierarchy explicitly, the way HBM-PIMulator models its
channel -> bankgroup -> bank tree:

  :class:`PimTopology`          the static channel -> rank -> DPU tree:
                                which ranks/channels a core extent
                                touches, WRAM/MRAM capacities, and the
                                segmented MRAM<->WRAM DMA cost.
  :class:`HierarchicalCostModel` prices a kernel launch as per-DPU
                                pipeline/DMA time (the old calibrated
                                instruction tables stay the leaf
                                compute term) plus rank-serialized
                                broadcast/gather legs over shared
                                channels, with concurrent tenants
                                dividing a channel's bandwidth.
  :class:`ExtentFootprint`      the rank/channel set of one core
                                extent — what a
                                :class:`~repro.sched.allocator.BankLease`
                                carries so placement can be scored by
                                predicted contention.

Calibration: the per-DPU leaf keeps the Fig. 8-10 version-ratio fit
(tests/test_topology.py asserts modeled-vs-paper ratio error bounds);
the transfer constants come from the UPMEM benchmarking literature
(provenance next to each constant) and are validated against the
paper's Fig. 11-12 strong-scaling band — the serialized transfer legs
are exactly why the measured 2048/256-core speedup is 6.37-7.98x, not
the flat model's 8.0x.

``DpuCostModel`` (repro/systems/pim.py) remains as a one-warning
deprecation shim over the leaf; every in-repo consumer now prices time
through :class:`HierarchicalCostModel`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

# ---------------------------------------------------------------------------
# Per-DPU constants (paper §2.1 / UPMEM benchmarking literature).
# ---------------------------------------------------------------------------

#: DPU clock (paper Table 1: 425 MHz production silicon).
DPU_FREQ_HZ = 425e6

#: fine-grained multithreading: one instruction/cycle only once >= 11
#: tasklets are resident (paper Fig. 8-10 saturation shape).
DPU_PIPELINE_SATURATION_THREADS = 11

#: MRAM streaming bandwidth per DPU, bytes/cycle at large DMA sizes
#: (~700 MB/s at 425 MHz — Gómez-Luna et al., arXiv:2105.03814, Fig. 7).
DPU_MRAM_BYTES_PER_CYCLE = 1.6

#: fixed per-DMA-transfer setup cost in cycles.  UPMEM MRAM<->WRAM DMA
#: reaches its ~1.6 B/cycle streaming rate only at large transfer
#: sizes; small transfers are latency-dominated (arXiv:2105.03814
#: Fig. 7: 8-byte transfers run ~20x below peak).  ~96 cycles of setup
#: reproduces that small-transfer cliff.
DPU_DMA_SETUP_CYCLES = 96.0

#: largest single MRAM<->WRAM DMA transfer the SDK issues (2 KB).
DPU_DMA_SEGMENT_BYTES = 2048

#: per-DPU scratchpad (WRAM) and bank (MRAM) capacities (paper §2.1).
DPU_WRAM_BYTES = 64 * 1024
DPU_MRAM_BYTES = 64 * 1024 * 1024

# ---------------------------------------------------------------------------
# Host-link constants (rank/channel legs).
# ---------------------------------------------------------------------------

#: sustained host->rank (broadcast) and rank->host (gather) bandwidth
#: PER MEMORY CHANNEL.  The UPMEM benchmarking paper measures ~6.7 GB/s
#: aggregate CPU->DPU and ~4.7 GB/s DPU->CPU across the full 2556-DPU
#: machine (arXiv:2105.03814 §3.3); spread over the ~10 memory channels
#: its 20 ranks populate, that is ~0.67 / ~0.47 GB/s per channel.
CHANNEL_CPU_TO_PIM_BW = 0.67e9
CHANNEL_PIM_TO_CPU_BW = 0.47e9

#: fixed software setup per rank-level parallel transfer (the
#: ``dpu_push_xfer`` call overhead: gathering per-DPU buffers and
#: issuing the rank burst — tens of microseconds at UPMEM SDK scale).
RANK_XFER_LATENCY_S = 20e-6

#: UPMEM hands workloads DPUs in ranks of 64 (paper §2.2).
DEFAULT_DPUS_PER_RANK = 64

#: modeled DIMM population: 2 PIM DIMMs of 2 ranks each share one
#: memory channel (the paper's server populates 20 ranks on ~10
#: channels -> 2 ranks/channel at full build-out; we default to 4 so
#: modest core counts still exercise rank-vs-channel contention).
DEFAULT_RANKS_PER_CHANNEL = 4


def default_rank_size(n_cores: int) -> int:
    """The auto-selected rank: the largest divisor of ``n_cores`` not
    exceeding the UPMEM rank of 64 (96 -> 48, 100 -> 50, 2556 -> 36) —
    carving stays rank-aligned without a hand-picked rank."""
    if n_cores <= 0:
        raise ValueError(f"n_cores must be positive, got {n_cores}")
    for rank in range(min(DEFAULT_DPUS_PER_RANK, n_cores), 0, -1):
        if n_cores % rank == 0:
            return rank
    return 1  # pragma: no cover — rank 1 always divides


@dataclasses.dataclass(frozen=True)
class ExtentFootprint:
    """The topology shadow of one core extent ``[start, start+n)``."""

    ranks: Tuple[int, ...]
    channels: Tuple[int, ...]

    @property
    def rank_straddling(self) -> bool:
        return len(self.ranks) > 1

    @property
    def channel_straddling(self) -> bool:
        return len(self.channels) > 1


@dataclasses.dataclass(frozen=True)
class PimTopology:
    """The channel -> rank -> DPU tree of one PIM machine.

    Pure geometry + per-level cost primitives: which rank/channel a
    core lives on, what footprint an extent casts, whether a working
    set fits WRAM, and what a segmented MRAM<->WRAM DMA costs.  The
    :class:`HierarchicalCostModel` composes these into launch prices;
    the :class:`~repro.sched.allocator.BankAllocator` scores placements
    against them.
    """

    n_cores: int
    dpus_per_rank: int = DEFAULT_DPUS_PER_RANK
    ranks_per_channel: int = DEFAULT_RANKS_PER_CHANNEL
    wram_bytes: int = DPU_WRAM_BYTES
    mram_bytes: int = DPU_MRAM_BYTES

    def __post_init__(self):
        if self.n_cores <= 0:
            raise ValueError(f"n_cores must be positive, got {self.n_cores}")
        if self.dpus_per_rank <= 0:
            raise ValueError("dpus_per_rank must be positive, got "
                             f"{self.dpus_per_rank}")
        if self.ranks_per_channel <= 0:
            raise ValueError("ranks_per_channel must be positive, got "
                             f"{self.ranks_per_channel}")

    @classmethod
    def for_cores(cls, n_cores: int,
                  dpus_per_rank: Optional[int] = None,
                  ranks_per_channel: int = DEFAULT_RANKS_PER_CHANNEL,
                  ) -> "PimTopology":
        """Build the tree for a machine size, auto-sizing the rank the
        same way the bank allocator does (largest divisor <= 64) so the
        allocator's rank granularity and the cost model's rank tree
        always agree."""
        if dpus_per_rank is None:
            dpus_per_rank = default_rank_size(n_cores)
        return cls(n_cores=n_cores, dpus_per_rank=dpus_per_rank,
                   ranks_per_channel=ranks_per_channel)

    # -- tree geometry -------------------------------------------------------

    @property
    def n_ranks(self) -> int:
        return -(-self.n_cores // self.dpus_per_rank)

    @property
    def n_channels(self) -> int:
        return -(-self.n_ranks // self.ranks_per_channel)

    @property
    def cores_per_channel(self) -> int:
        return self.dpus_per_rank * self.ranks_per_channel

    def rank_of(self, core: int) -> int:
        if not 0 <= core < self.n_cores:
            raise ValueError(f"core {core} outside [0, {self.n_cores})")
        return core // self.dpus_per_rank

    def channel_of(self, core: int) -> int:
        return self.rank_of(core) // self.ranks_per_channel

    def footprint(self, start: int, n_cores: int) -> ExtentFootprint:
        """Ranks and channels the extent ``[start, start+n_cores)``
        touches (inclusive of partial ranks at either edge)."""
        if n_cores <= 0:
            raise ValueError(f"extent size must be positive, got {n_cores}")
        if start < 0 or start + n_cores > self.n_cores:
            raise ValueError(f"extent [{start}, {start + n_cores}) outside "
                             f"the machine [0, {self.n_cores})")
        first = self.rank_of(start)
        last = self.rank_of(start + n_cores - 1)
        ranks = tuple(range(first, last + 1))
        channels = tuple(sorted({r // self.ranks_per_channel
                                 for r in ranks}))
        return ExtentFootprint(ranks=ranks, channels=channels)

    def rank_cores(self, rank: int, start: int, n_cores: int) -> int:
        """How many cores of extent ``[start, start+n)`` live on ``rank``."""
        lo = max(start, rank * self.dpus_per_rank)
        hi = min(start + n_cores, (rank + 1) * self.dpus_per_rank)
        return max(0, hi - lo)

    # -- per-DPU memory costs ------------------------------------------------

    def wram_fits(self, working_set_bytes: int) -> bool:
        """Does a per-tasklet working set fit the 64 KB WRAM scratchpad
        (the LOG LUT's WRAM-vs-MRAM placement decision, paper §5.2.2)?"""
        return 0 <= working_set_bytes <= self.wram_bytes

    def mram_fits(self, resident_bytes: int) -> bool:
        return 0 <= resident_bytes <= self.mram_bytes

    def mram_wram_cycles(self, nbytes: float) -> float:
        """Cycles to stream ``nbytes`` between MRAM and WRAM in DMA
        segments of at most :data:`DPU_DMA_SEGMENT_BYTES`: each segment
        pays the fixed DMA setup, then bytes move at the streaming
        rate.  Large transfers converge to the flat model's
        ``bytes / 1.6``; small ones surface the measured latency cliff.
        """
        if nbytes <= 0:
            return 0.0
        segments = -(-nbytes // DPU_DMA_SEGMENT_BYTES)
        return (segments * DPU_DMA_SETUP_CYCLES
                + nbytes / DPU_MRAM_BYTES_PER_CYCLE)


# ---------------------------------------------------------------------------
# Hierarchical cost model.
# ---------------------------------------------------------------------------

#: instruction-cost table (cycles/op at full pipeline) — calibrated so
#: the modeled version ratios match the paper's measured speedups:
#:   LIN-INT32 ~= 10x LIN-FP32 ("order of magnitude", §5.2.1)
#:   LIN-HYB   ~= 1.41x LIN-INT32 (+41%)
#:   LIN-BUI   ~= 1.25x LIN-HYB  (+25%)
#:   LOG LUT   ~= 53x  LOG-INT32 Taylor (§5.2.2)
#:   LOG-HYB-LUT ~= 1.28x LOG-INT32-LUT(WRAM); LOG-BUI-LUT ~= 1.43x HYB
DPU_OP_CYCLES: dict[str, float] = {
    "add32": 1.0,          # native
    "cmp": 1.0,            # native
    "load": 1.0,           # WRAM load (per 32-bit word, post-DMA)
    "mul8_builtin": 4.0,   # custom built-in multiply (Listing 1d)
    "mul16": 7.0,          # compiler-generated 8/16-bit multiply (Listing 1b)
    "mul32_emul": 24.0,    # runtime-emulated 32-bit multiply
    "div32_emul": 56.0,    # runtime-emulated division
    "fadd_emul": 55.0,     # software float add
    "fmul_emul": 70.0,     # software float multiply
    "lut_query_wram": 2.0,   # index clamp + load
    "lut_query_mram": 6.0,   # + DMA latency amortized over batched queries
}

#: per-iteration transfer-leg bytes per DPU for each modeled workload:
#: (broadcast bytes the host pushes to every DPU, gather bytes every
#: DPU ships back).  GD moves the (F+1)-vector both ways; K-Means
#: broadcasts k centroids and gathers per-cluster sums+counts; DTR
#: broadcasts a small split command and gathers per-node histograms.
def _gd_leg_bytes(n_features: int, k: int) -> Tuple[float, float]:
    return 4.0 * (n_features + 1), 4.0 * (n_features + 1)


def _kme_leg_bytes(n_features: int, k: int) -> Tuple[float, float]:
    return 4.0 * k * n_features, k * (4.0 * n_features + 8.0)


def _dtr_leg_bytes(n_features: int, k: int) -> Tuple[float, float]:
    return 64.0, 4.0 * 2 * 32      # command; 32-bin class histograms


#: modeled embedding width for EMB leg/leaf pricing — the dataset's
#: n_features is the (user, item) pair width (2), not the table dim,
#: so the model prices a representative dim (the trainer default)
EMB_MODEL_DIM = 8


def _emb_leg_bytes(n_features: int, k: int) -> Tuple[float, float]:
    """EMB per-step legs, ``k`` = minibatch size B (DESIGN.md §15.6):
    down, the broadcast minibatch (2 id columns + targets, int32/f32);
    up, the two gathered (B, dim) row blocks plus the relayed targets.
    The deferred flush payload is charged separately by the trainer
    (``TransferStats.flush_bytes``) — it amortizes over the window, so
    it is not part of the per-step launch price."""
    return 4.0 * 3 * k, 4.0 * k * (2 * EMB_MODEL_DIM + 1)


WORKLOAD_LEG_BYTES = {
    "lin": _gd_leg_bytes,
    "log": _gd_leg_bytes,
    "kme": _kme_leg_bytes,
    "dtr": _dtr_leg_bytes,
    "emb": _emb_leg_bytes,
}


@dataclasses.dataclass
class HierarchicalCostModel:
    """Topology-aware kernel/launch pricing (DESIGN.md §12).

    Three layers, matching the machine:

      per-DPU leaf   ``kernel_seconds``: the calibrated instruction
                     tables vs the *segmented* MRAM<->WRAM DMA cost
                     (all leased DPUs run in parallel);
      rank legs      ``broadcast_seconds``/``gather_seconds``: the host
                     moves model state rank-by-rank — one fixed setup
                     plus a burst per rank, ranks on one channel
                     serialized, channels in parallel;
      channel share  ``sharers`` tenants on a channel divide its
                     bandwidth (the contention the topology-aware
                     placer minimizes).

    ``step_seconds`` composes all three into the price of ONE training
    iteration on an extent; ``job_seconds`` multiplies it out — the
    scheduler's backfill ordering and ``capacity_estimate`` run on it.
    """

    topology: PimTopology
    freq_hz: float = DPU_FREQ_HZ
    saturation_threads: int = DPU_PIPELINE_SATURATION_THREADS
    cpu_to_pim_bw: float = CHANNEL_CPU_TO_PIM_BW
    pim_to_cpu_bw: float = CHANNEL_PIM_TO_CPU_BW
    rank_latency_s: float = RANK_XFER_LATENCY_S

    @classmethod
    def for_cores(cls, n_cores: int, **topo_kwargs) -> "HierarchicalCostModel":
        return cls(PimTopology.for_cores(n_cores, **topo_kwargs))

    # -- per-DPU leaf --------------------------------------------------------

    def kernel_seconds(self, instr_cycles: float, mram_bytes: float,
                       n_threads: int) -> float:
        """Single-DPU kernel time: pipeline term (saturating at 11
        tasklets) vs the segmented MRAM DMA term.  ``n_threads`` must
        be positive — a degenerate zero-thread lease is a caller bug,
        not a near-infinite compute time."""
        if n_threads <= 0:
            raise ValueError(
                f"n_threads must be positive, got {n_threads} "
                "(a lease cannot run a kernel with no tasklets)")
        tp = min(n_threads, self.saturation_threads) / self.saturation_threads
        compute = instr_cycles / tp
        memory = self.topology.mram_wram_cycles(mram_bytes)
        return max(compute, memory) / self.freq_hz

    # -- per-workload instruction estimates (per sample, F features) --------
    #
    # Calibrated against the paper's measured version-to-version speedups
    # (§5.2.1/§5.2.2) rather than summed from DPU_OP_CYCLES: the compiled
    # inner loops also contain loads, address arithmetic and loop control,
    # so the per-feature totals below are the fitted quantities.  Anchors:
    #   bui  ~ custom mul (4 instr, Listing 1d) + load/acc     -> 8
    #   hyb  ~ compiler 16-bit mul (7 instr, Listing 1b) + l/a -> 10
    #   int32~ emulated 32-bit mul + shifts                    -> 14
    #   fp32 ~ software float mul+add                          -> 120
    # giving fp32/int32 = 8.6x ("order of magnitude"), int32/hyb = 1.40
    # (+41%), hyb/bui = 1.25 (+25%).
    LIN_INSTR_PER_FEATURE = {"fp32": 120.0, "int32": 14.0,
                             "hyb": 10.0, "bui": 8.0}

    #: per-sample sigmoid cost.  The Taylor numbers are fitted to the
    #: paper's measured 53x LUT-over-Taylor speedup and the 65% INT32-
    #: over-FP32 reduction (§5.2.2).
    LOG_SIGMOID_CYCLES = {"fp32": 66_000.0, "int32": 24_000.0,
                          "int32_lut_mram": 6.0, "int32_lut_wram": 2.0,
                          "hyb_lut": 2.0, "bui_lut": 2.0}

    @staticmethod
    def lin_instr(version: str, n_features: int) -> float:
        per_feat = HierarchicalCostModel.LIN_INSTR_PER_FEATURE[version]
        overhead = 24.0 if version == "fp32" else 10.0
        # dot product + gradient pass back over features (second pass)
        return 2 * n_features * per_feat + overhead

    @staticmethod
    def log_instr(version: str, n_features: int) -> float:
        base_ver = {"fp32": "fp32", "int32": "int32",
                    "int32_lut_mram": "int32", "int32_lut_wram": "int32",
                    "hyb_lut": "hyb", "bui_lut": "bui"}[version]
        base = HierarchicalCostModel.lin_instr(base_ver, n_features)
        return base + HierarchicalCostModel.LOG_SIGMOID_CYCLES[version]

    @staticmethod
    def dtr_split_evaluate_instr(n_points: int) -> float:
        c = DPU_OP_CYCLES
        return n_points * (c["load"] + c["cmp"] + c["add32"])

    @staticmethod
    def kme_instr(n_points: int, n_features: int, k: int) -> float:
        c = DPU_OP_CYCLES
        per_pt = k * n_features * (c["load"] + c["mul16"] + c["add32"]) \
            + k * c["cmp"] + n_features * c["add32"]
        return n_points * per_pt

    def _workload_leaf(self, workload: str, version: str, n_samples: int,
                       n_features: int, n_cores: int, k: int = 16,
                       ) -> Tuple[float, float]:
        """(instr_cycles, mram_bytes) of one per-DPU training pass."""
        from .pim import workload_element_bytes  # table lives with PimSystem
        n_pc = -(-n_samples // n_cores)
        elem_bytes = workload_element_bytes(workload, version)
        bytes_ = n_pc * n_features * elem_bytes
        if workload == "lin":
            instr = n_pc * self.lin_instr(version, n_features)
        elif workload == "log":
            instr = n_pc * self.log_instr(version, n_features)
        elif workload == "dtr":
            instr = self.dtr_split_evaluate_instr(n_pc) * n_features
        elif workload == "kme":
            instr = self.kme_instr(n_pc, n_features, k)
        elif workload == "emb":
            # k = minibatch size; each sample is one dot + one axpy per
            # table over EMB_MODEL_DIM-wide rows — the same op mix as a
            # LIN step over that many features — plus a shard-local id
            # probe per lookup.  MRAM traffic is the touched rows, not
            # the resident shard (sparse access is the point).
            elem = workload_element_bytes("emb", version)
            instr = k * 2 * self.lin_instr(version, EMB_MODEL_DIM)
            bytes_ = k * 2 * EMB_MODEL_DIM * elem + n_pc * 4
        else:
            raise ValueError(workload)
        return instr, bytes_

    def workload_seconds(self, workload: str, version: str, n_samples: int,
                         n_features: int, n_cores: int, n_threads: int,
                         k: int = 16) -> float:
        """Per-DPU kernel seconds of one training pass — the Fig. 8-10
        quantity (kernel time only, no transfer legs)."""
        instr, bytes_ = self._workload_leaf(workload, version, n_samples,
                                            n_features, n_cores, k)
        return self.kernel_seconds(instr, bytes_, n_threads)

    # -- rank/channel transfer legs ------------------------------------------

    def _ranks_by_channel(self, start: int, n_cores: int
                          ) -> dict[int, list]:
        topo = self.topology
        fp = topo.footprint(start, n_cores)
        by_channel: dict[int, list] = {}
        for rank in fp.ranks:
            by_channel.setdefault(rank // topo.ranks_per_channel,
                                  []).append(rank)
        return by_channel

    def _leg_seconds(self, bytes_per_dpu: float, start: int, n_cores: int,
                     bw: float, sharers: int) -> float:
        """One rank-serialized transfer leg over the extent's channels:
        each touched rank pays the fixed transfer setup plus its burst
        (bytes_per_dpu x cores-on-rank) at the channel's bandwidth;
        ranks sharing a channel serialize, channels run in parallel,
        and ``sharers`` concurrent tenants divide each channel's
        bandwidth."""
        if bytes_per_dpu <= 0 or n_cores <= 0:
            return 0.0
        share = bw / max(1, sharers)
        worst = 0.0
        for _ch, ranks in self._ranks_by_channel(start, n_cores).items():
            t = 0.0
            for rank in ranks:
                cores = self.topology.rank_cores(rank, start, n_cores)
                t += self.rank_latency_s + bytes_per_dpu * cores / share
            worst = max(worst, t)
        return worst

    def broadcast_seconds(self, bytes_per_dpu: float, n_cores: int,
                          start: int = 0, sharers: int = 1) -> float:
        """Host -> extent model broadcast (CPU->PIM direction)."""
        return self._leg_seconds(bytes_per_dpu, start, n_cores,
                                 self.cpu_to_pim_bw, sharers)

    def gather_seconds(self, bytes_per_dpu: float, n_cores: int,
                       start: int = 0, sharers: int = 1) -> float:
        """Extent -> host partial gather (PIM->CPU direction)."""
        return self._leg_seconds(bytes_per_dpu, start, n_cores,
                                 self.pim_to_cpu_bw, sharers)

    def launch_seconds(self, instr_cycles: float, mram_bytes: float,
                       n_threads: int, *, broadcast_bytes_per_dpu: float = 0.0,
                       gather_bytes_per_dpu: float = 0.0, n_cores: int = 1,
                       start: int = 0, sharers: int = 1) -> float:
        """Full price of one launch on an extent: per-DPU kernel time
        (all leased DPUs in parallel) + the serialized broadcast and
        gather legs."""
        return (self.kernel_seconds(instr_cycles, mram_bytes, n_threads)
                + self.broadcast_seconds(broadcast_bytes_per_dpu, n_cores,
                                         start, sharers)
                + self.gather_seconds(gather_bytes_per_dpu, n_cores,
                                      start, sharers))

    # -- end-to-end workload pricing -----------------------------------------

    def step_seconds(self, workload: str, version: str, n_samples: int,
                     n_features: int, n_cores: Optional[int] = None,
                     n_threads: int = 16, k: int = 16, start: int = 0,
                     sharers: int = 1) -> float:
        """One training iteration on the extent ``[start, start+n)``:
        kernel + broadcast + gather.  This is the quantity the Fig.
        11-12 scaling curves measure — at 2048 cores the serialized
        legs are why speedup-vs-256 lands below the flat model's 8.0x.
        """
        if n_cores is None:
            n_cores = self.topology.n_cores
        instr, bytes_ = self._workload_leaf(workload, version, n_samples,
                                            n_features, n_cores, k)
        leg = WORKLOAD_LEG_BYTES.get(workload)
        bcast, gather = leg(n_features, k) if leg else (0.0, 0.0)
        return self.launch_seconds(
            instr, bytes_, n_threads,
            broadcast_bytes_per_dpu=bcast, gather_bytes_per_dpu=gather,
            n_cores=n_cores, start=start, sharers=sharers)

    def job_seconds(self, workload: str, version: str, n_samples: int,
                    n_features: int, n_iters: int,
                    n_cores: Optional[int] = None, n_threads: int = 16,
                    k: int = 16, start: int = 0, sharers: int = 1) -> float:
        """Modeled end-to-end time of an ``n_iters``-iteration fit —
        the scheduler's backfill-ordering and capacity-planning unit."""
        return max(0, n_iters) * self.step_seconds(
            workload, version, n_samples, n_features, n_cores, n_threads,
            k, start, sharers)

    # -- contention -----------------------------------------------------------

    def contention_sharers(self, start: int, n_cores: int,
                           live_extents: Iterable[Tuple[int, int]]) -> int:
        """How many tenants (this one included) share this extent's
        busiest channel — the divisor the transfer legs see.  The
        placement scorer minimizes exactly this quantity."""
        fp = self.topology.footprint(start, n_cores)
        per_channel = {ch: 1 for ch in fp.channels}
        for other_start, other_n in live_extents:
            if other_n <= 0:
                continue
            other = self.topology.footprint(other_start, other_n)
            for ch in other.channels:
                if ch in per_channel:
                    per_channel[ch] += 1
        return max(per_channel.values(), default=1)
