"""int8 error-feedback compression as a pluggable ReduceStrategy.

Wires the seed :mod:`repro.optim.grad_compression` (previously only
reachable from the pmap/shard_map LM path) into the System protocol's
reduce axis: :class:`CompressedReduce` wraps ANY inner strategy and
quantizes the float reduce payload to int8 with a persistent
error-feedback buffer (Karimireddy et al.-style EF-SGD — the same math
as ``ef_compress_psum``, applied host-side where the strategy's
finalize leg runs).  The modeled wire shrinks 4x; ``TransferStats``
gains a ``compressed_bytes`` counter recording the actual int8 bytes
moved, while ``pim_to_cpu``/the topology split are charged at the
compressed width.

Semantics and caveats (DESIGN.md §15.4):

* Only float leaves are quantized.  Integer (Q-format fixed-point)
  leaves pass through exactly at full width — compressing them would
  silently break the bit-exactness contracts of the int versions.
* With a host/hierarchical inner, the quantizer sees the stacked
  per-partial leaves before the host combine — each shipped partial is
  int8 on the wire.  With a fabric inner the tree arrives pre-folded,
  so the quantizer runs once on the total (a compressing fabric).
* Error feedback persists on the strategy INSTANCE.  Pass an instance
  (``make_system("pim", reduce=CompressedReduce())``) to keep buffers
  across steps; the string spelling ``reduce="compressed"`` constructs
  a fresh instance per call — still correct wire accounting, but the
  quantization noise is then unbiased only per step, not over time.
* ``fusable = False``: the quantizer is a host-side leg, so a
  StepProgram degrades to per-step syncs (exactly like HostReduce).

:func:`quantize_rows` is the sparse sibling used by the EMB deferred
flush (per-row scales over the deduped update rows; integer tables get
integer scales so the residual stays exact).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

from .base import (FabricReduce, ReduceStrategy, StrategyLike, _STRATEGIES,
                   _tree_bytes, resolve_reduce_strategy)


def ef_quantize(arr: np.ndarray, err: np.ndarray):
    """Host-side twin of ``ef_compress_psum``'s per-replica leg:
    ``(q int8, scale, dequantized f32, new error buffer)``."""
    corrected = np.asarray(arr, np.float32) + err
    amax = float(np.abs(corrected).max()) if corrected.size else 0.0
    scale = max(amax, 1e-12) / 127.0
    q = np.clip(np.rint(corrected / scale), -127, 127).astype(np.int8)
    deq = q.astype(np.float32) * np.float32(scale)
    return q, scale, deq, corrected - deq


def quantize_rows(upd: np.ndarray):
    """Per-row symmetric int8 quantization of sparse update rows
    ``[U, D]`` -> ``(q int8 [U, D], scales [U], deq, residual)``.

    Float rows use f32 scales (residual is the float quantization
    error); integer Q-format rows use integer scales ``ceil(amax/127)``
    so both ``deq`` and the residual are EXACT int32 — re-staging the
    residual loses nothing on the fixed-point path."""
    upd = np.asarray(upd)
    if upd.size == 0:
        z = np.zeros_like(upd)
        return (np.zeros(upd.shape, np.int8),
                np.zeros((upd.shape[0],), np.float32), z, z)
    if np.issubdtype(upd.dtype, np.integer):
        amax = np.abs(upd.astype(np.int64)).max(axis=1)
        scales = np.maximum((amax + 126) // 127, 1)        # int, >= 1
        q = np.clip(np.rint(upd / scales[:, None]),
                    -127, 127).astype(np.int8)
        deq = (q.astype(np.int64) * scales[:, None]).astype(upd.dtype)
        return q, scales.astype(np.int32), deq, upd - deq
    a = upd.astype(np.float32)
    scales = np.maximum(np.abs(a).max(axis=1), 1e-12) / 127.0
    scales = scales.astype(np.float32)
    q = np.clip(np.rint(a / scales[:, None]), -127, 127).astype(np.int8)
    deq = (q.astype(np.float32) * scales[:, None]).astype(upd.dtype)
    return q, scales, deq, upd - deq


class CompressedReduce(ReduceStrategy):
    """int8 + error-feedback over any inner :class:`ReduceStrategy`."""

    name = "compressed"
    fusable = False  # the quantizer is a host-side finalize leg

    def __init__(self, inner: StrategyLike = None):
        self.inner = (inner if isinstance(inner, ReduceStrategy)
                      else resolve_reduce_strategy(inner, FabricReduce()))
        #: persistent EF buffers keyed by leaf position (ef_compress_psum
        #: keeps these as explicit trainer state; here they ride the
        #: strategy instance so existing trainers need no plumbing)
        self._err: Dict[int, np.ndarray] = {}

    def bind(self, system) -> "CompressedReduce":
        self.inner = self.inner.bind(system)
        return self  # NOT a copy: EF buffers must survive across steps

    def device_reduce(self, partials):
        return self.inner.device_reduce(partials)

    def device_reduce_full(self, partials):
        return self.inner.device_reduce_full(partials)

    def finalize(self, system, out):
        host = jax.device_get(out)
        leaves, treedef = jax.tree_util.tree_flatten(host)
        deq_leaves = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            if not np.issubdtype(arr.dtype, np.floating):
                deq_leaves.append(arr)  # Q-format stays exact, full width
                continue
            err = self._err.get(i)
            if err is None or err.shape != arr.shape:
                err = np.zeros(arr.shape, np.float32)
            _, _, deq, new_err = ef_quantize(arr, err)
            self._err[i] = new_err
            deq_leaves.append(deq.astype(arr.dtype))
        deq_tree = jax.tree_util.tree_unflatten(treedef, deq_leaves)
        return self.inner.finalize(system, deq_tree)

    def _wire_bytes(self, full_bytes: int, out) -> int:
        """Compressed wire width of an inner leg that would move
        ``full_bytes``: every (4-byte) element ships as one int8 byte,
        plus one f32 scale per float leaf.  Integer leaves ship at full
        width (see finalize), so their bytes are kept uncompressed."""
        leaves = jax.tree_util.tree_leaves(out)
        float_frac_num = sum(
            _tree_bytes(v) for v in leaves
            if np.issubdtype(np.dtype(v.dtype), np.floating))
        total = max(_tree_bytes(out), 1)
        float_bytes = full_bytes * float_frac_num // total
        n_scales = sum(
            1 for v in leaves
            if np.issubdtype(np.dtype(v.dtype), np.floating))
        return (full_bytes - float_bytes) + float_bytes // 4 + 4 * n_scales

    def count_pim_to_cpu(self, system, out) -> int:
        wire = self._wire_bytes(self.inner.count_pim_to_cpu(system, out),
                                out)
        system.stats.compressed_bytes += wire
        return wire

    def count_topology(self, system, out) -> tuple:
        local, cross = self.inner.count_topology(system, out)
        return self._wire_bytes(local, out), self._wire_bytes(cross, out)

    def cache_token(self):
        return f"compressed({self.inner.cache_token()})"


_STRATEGIES["compressed"] = CompressedReduce
