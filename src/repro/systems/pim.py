"""PIM execution model (paper §2.2, Fig. 3) mapped onto JAX.

The paper's system: N PIM cores, each owning a DRAM bank; training data is
partitioned once and stays bank-resident; each iteration every core computes
a partial result over its shard; partials are reduced *via the host* (DPUs
cannot talk to each other) and the updated model is re-broadcast.

JAX mapping (DESIGN.md §2):
  PIM core            -> one mesh element of a 1-D "cores" axis
  bank-resident shard -> device-resident leading-axis shard of the dataset
  host reduction      -> jax.lax.psum over "cores" (FabricReduce) or an
                         actual device_get/numpy/device_put round trip
                         (HostReduce — faithful to UPMEM's topology), or a
                         two-level rank schedule (HierarchicalReduce)

:class:`PimSystem` is the memory-centric implementation of the
:class:`~repro.systems.base.System` protocol (DESIGN.md §10); the
execution surface — ``put``/``register_kernel``/``map_reduce``/
``step_program`` — is defined on the shared base and behaves here
exactly as it did when this class WAS the surface (bit-identical fits,
identical TransferStats; asserted by tests/test_pim_system.py and
tests/test_step_fusion.py).

Backends:
  "vmap"      single-device semantic model (cores simulated by vmap) — used
              by unit tests and quality reproduction; bit-identical to the
              sharded path because the kernels are deterministic integer ops.
  "shard_map" real multi-device execution over a jax.Mesh "cores" axis —
              used by the scaling benchmarks and the dry-run.

Also here: ``DpuCostModel``, an instruction-level cost model of the UPMEM
DPU pipeline (425 MHz, fine-grained multithreaded, throughput saturates at
11 tasklets) calibrated against the paper's measured version-to-version
speedups.  The benchmark harness uses it to reproduce Fig. 8-12 shapes
without UPMEM hardware; the calibration table is printed next to the
paper's reported ratios so the fit is auditable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.quantization import storage_bytes
from .base import ReduceVia, System


@dataclasses.dataclass
class PimConfig:
    n_cores: int = 64
    n_threads: int = 16          # tasklets per core (cost model + layouts)
    reduce: ReduceVia = ReduceVia.FABRIC   # default strategy for map_reduce
    backend: str = "vmap"        # "vmap" | "shard_map"


class PimSystem(System):
    """Host-orchestrated data-parallel execution over PIM cores.

    The redesigned surface (DESIGN.md §3, §10):
      put(X, y)                 -> PimDataset (bank-resident, view-cached)
      register_kernel(name, fn) -> kernel name usable with map_* calls
      named_kernel(name, build) -> register-once helper for kernel factories
      map_reduce(kernel, ...)   -> kernel may be a registered name or a
                                   callable; ``strategy=`` picks the
                                   reduction per call
    """

    kind = "pim"

    def __init__(self, config: PimConfig, devices: Optional[Sequence] = None):
        super().__init__(config)
        self._mesh = None
        if config.backend == "shard_map":
            devices = list(devices if devices is not None else jax.devices())
            if len(devices) < config.n_cores:
                raise ValueError(
                    f"shard_map backend needs >= {config.n_cores} devices, "
                    f"got {len(devices)} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=...)")
            self._mesh = Mesh(np.array(devices[: config.n_cores]), ("cores",))

    @property
    def n_shards(self) -> int:
        return self.config.n_cores

    # -- data placement ------------------------------------------------------

    def shard_rows(self, x: np.ndarray, pad_value=0) -> jnp.ndarray:
        """Partition rows across cores: (n, ...) -> (n_cores, n_pc, ...).

        Equal-size shards (padding as needed) mirror the paper's requirement
        that parallel CPU->PIM transfers need equal buffer sizes per bank.
        Counts the modeled CPU->PIM transfer bytes (and the dedicated
        shard_transfers/shard_bytes counters — see TransferStats)."""
        c = self.config.n_cores
        n = x.shape[0]
        n_pc = -(-n // c)
        pad = c * n_pc - n
        if pad:
            x = np.concatenate(
                [x, np.full((pad,) + x.shape[1:], pad_value, x.dtype)], 0)
        out = x.reshape(c, n_pc, *x.shape[1:])
        self.stats.cpu_to_pim += out.nbytes
        self.stats.shard_transfers += 1
        self.stats.shard_bytes += out.nbytes
        arr = jnp.asarray(out)
        if self._mesh is not None:
            arr = jax.device_put(
                arr, NamedSharding(self._mesh, P("cores")))
        return arr

    def row_validity_mask(self, n: int) -> jnp.ndarray:
        """(n_cores, n_pc) bool mask marking real (non-padding) rows."""
        c = self.config.n_cores
        n_pc = -(-n // c)
        idx = np.arange(c * n_pc).reshape(c, n_pc)
        mask = jnp.asarray(idx < n)
        if self._mesh is not None:
            mask = jax.device_put(mask, NamedSharding(self._mesh, P("cores")))
        return mask

    def broadcast(self, tree: Any) -> Any:
        """Host -> all cores broadcast of model state (counted per core)."""
        nbytes = sum(np.asarray(v).nbytes for v in jax.tree_util.tree_leaves(tree))
        self.stats.cpu_to_pim += nbytes * self.config.n_cores
        if self._mesh is not None:
            tree = jax.device_put(
                tree, NamedSharding(self._mesh, P()))  # replicated
        return tree

    # -- execution ------------------------------------------------------------

    def _per_core(self, local_fn, sharded, replicated):
        """Trace the per-core kernel under vmap or shard_map."""
        if self._mesh is None:
            return jax.vmap(lambda *s: local_fn(*s, *replicated))(*sharded)
        mesh = self._mesh

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(tuple(P("cores") for _ in sharded), P()),
            out_specs=P("cores"))
        def _shmap(shard_args, rep):
            local = [jnp.squeeze(a, 0) for a in shard_args]
            out = local_fn(*local, *rep)
            return jax.tree_util.tree_map(lambda v: v[None], out)
        return _shmap(sharded, replicated)

    # -- multi-tenancy -------------------------------------------------------

    def slice(self, lease) -> "PimSystem":
        """A :class:`~repro.sched.allocator.PimSlice` over the leased
        extent — itself a PimSystem, so trainers run on it unmodified."""
        from ..sched.allocator import PimSlice  # local: sched -> systems
        return PimSlice(self, lease)


# ---------------------------------------------------------------------------
# DPU cost model (benchmark harness only — reproduces Fig. 8-12 shapes).
# ---------------------------------------------------------------------------

#: instruction-cost table (cycles/op at full pipeline) — calibrated so the
#: modeled version ratios match the paper's measured speedups:
#:   LIN-INT32 ~= 10x LIN-FP32 ("order of magnitude", §5.2.1)
#:   LIN-HYB   ~= 1.41x LIN-INT32 (+41%)
#:   LIN-BUI   ~= 1.25x LIN-HYB  (+25%)
#:   LOG LUT   ~= 53x  LOG-INT32 Taylor (§5.2.2)
#:   LOG-HYB-LUT ~= 1.28x LOG-INT32-LUT(WRAM); LOG-BUI-LUT ~= 1.43x HYB
DPU_OP_CYCLES: dict[str, float] = {
    "add32": 1.0,          # native
    "cmp": 1.0,            # native
    "load": 1.0,           # WRAM load (per 32-bit word, post-DMA)
    "mul8_builtin": 4.0,   # custom built-in multiply (Listing 1d)
    "mul16": 7.0,          # compiler-generated 8/16-bit multiply (Listing 1b)
    "mul32_emul": 24.0,    # runtime-emulated 32-bit multiply
    "div32_emul": 56.0,    # runtime-emulated division
    "fadd_emul": 55.0,     # software float add
    "fmul_emul": 70.0,     # software float multiply
    "lut_query_wram": 2.0,   # index clamp + load
    "lut_query_mram": 6.0,   # + DMA latency amortized over batched queries
}

#: MRAM streaming bandwidth per DPU, bytes/cycle (≈ 700 MB/s at 425 MHz)
DPU_MRAM_BYTES_PER_CYCLE = 1.6
DPU_FREQ_HZ = 425e6
DPU_PIPELINE_SATURATION_THREADS = 11

#: on-bank storage dtype of the training data per (workload, version) —
#: the explicit table the cost model's MRAM byte counting reads, with the
#: per-dtype widths shared with quantization.STORAGE_BYTES.  Mirrors the
#: quantized views PimDataset materializes (repro/api/dataset.py).
WORKLOAD_STORAGE_DTYPE: dict[tuple[str, str], str] = {
    ("lin", "fp32"): "fp32",
    ("lin", "int32"): "int32",
    ("lin", "hyb"): "int8",
    ("lin", "bui"): "int8",
    ("log", "fp32"): "fp32",
    ("log", "int32"): "int32",
    ("log", "int32_lut_mram"): "int32",
    ("log", "int32_lut_wram"): "int32",
    ("log", "hyb_lut"): "int8",
    ("log", "bui_lut"): "int8",
    ("dtr", "fp32"): "fp32",
    ("kme", "int16"): "int16",
    ("kme", "fp32"): "fp32",
}


def workload_element_bytes(workload: str, version: str) -> int:
    """Bytes per stored feature value for a workload version."""
    try:
        name = WORKLOAD_STORAGE_DTYPE[(workload, version)]
    except KeyError:
        raise ValueError(
            f"no storage dtype recorded for {workload}/{version}; "
            f"add it to WORKLOAD_STORAGE_DTYPE") from None
    return storage_bytes(name)


@dataclasses.dataclass
class DpuCostModel:
    """Analytic single-DPU kernel-time model.

    ``cycles = max(instr_cycles / throughput(threads), mram_bytes / bw)``
    where throughput(t) = min(t, 11) / 11  (fine-grained multithreading:
    one instruction per cycle only once >= 11 tasklets are resident).
    """

    freq_hz: float = DPU_FREQ_HZ
    saturation_threads: int = DPU_PIPELINE_SATURATION_THREADS

    def kernel_seconds(self, instr_cycles: float, mram_bytes: float,
                       n_threads: int) -> float:
        tp = min(n_threads, self.saturation_threads) / self.saturation_threads
        compute = instr_cycles / max(tp, 1e-9)
        memory = mram_bytes / DPU_MRAM_BYTES_PER_CYCLE
        return max(compute, memory) / self.freq_hz

    # -- per-workload instruction estimates (per sample, F features) --------
    #
    # Calibrated against the paper's measured version-to-version speedups
    # (§5.2.1/§5.2.2) rather than summed from DPU_OP_CYCLES: the compiled
    # inner loops also contain loads, address arithmetic and loop control,
    # so the per-feature totals below are the fitted quantities.  Anchors:
    #   bui  ~ custom mul (4 instr, Listing 1d) + load/acc     -> 8
    #   hyb  ~ compiler 16-bit mul (7 instr, Listing 1b) + l/a -> 10
    #   int32~ emulated 32-bit mul + shifts                    -> 14
    #   fp32 ~ software float mul+add                          -> 120
    # giving fp32/int32 = 8.6x ("order of magnitude"), int32/hyb = 1.40
    # (+41%), hyb/bui = 1.25 (+25%).
    LIN_INSTR_PER_FEATURE = {"fp32": 120.0, "int32": 14.0,
                             "hyb": 10.0, "bui": 8.0}

    #: per-sample sigmoid cost.  The Taylor numbers are fitted to the
    #: paper's measured 53x LUT-over-Taylor speedup and the 65% INT32-over-
    #: FP32 reduction (§5.2.2) — the DPU Taylor loop iterates with emulated
    #: high-precision arithmetic, which is why it is this expensive.
    LOG_SIGMOID_CYCLES = {"fp32": 66_000.0, "int32": 24_000.0,
                          "int32_lut_mram": 6.0, "int32_lut_wram": 2.0,
                          "hyb_lut": 2.0, "bui_lut": 2.0}

    @staticmethod
    def lin_instr(version: str, n_features: int) -> float:
        per_feat = DpuCostModel.LIN_INSTR_PER_FEATURE[version]
        overhead = 24.0 if version == "fp32" else 10.0
        # dot product + gradient pass back over features (second pass)
        return 2 * n_features * per_feat + overhead

    @staticmethod
    def log_instr(version: str, n_features: int) -> float:
        base_ver = {"fp32": "fp32", "int32": "int32",
                    "int32_lut_mram": "int32", "int32_lut_wram": "int32",
                    "hyb_lut": "hyb", "bui_lut": "bui"}[version]
        base = DpuCostModel.lin_instr(base_ver, n_features)
        return base + DpuCostModel.LOG_SIGMOID_CYCLES[version]

    @staticmethod
    def dtr_split_evaluate_instr(n_points: int) -> float:
        c = DPU_OP_CYCLES
        return n_points * (c["load"] + c["cmp"] + c["add32"])

    @staticmethod
    def kme_instr(n_points: int, n_features: int, k: int) -> float:
        c = DPU_OP_CYCLES
        per_pt = k * n_features * (c["load"] + c["mul16"] + c["add32"]) \
            + k * c["cmp"] + n_features * c["add32"]
        return n_points * per_pt

    # -- end-to-end modeled time for the scaling benchmarks ------------------

    def workload_seconds(self, workload: str, version: str, n_samples: int,
                         n_features: int, n_cores: int, n_threads: int,
                         k: int = 16) -> float:
        n_pc = -(-n_samples // n_cores)
        elem_bytes = workload_element_bytes(workload, version)
        bytes_ = n_pc * n_features * elem_bytes
        if workload == "lin":
            instr = n_pc * self.lin_instr(version, n_features)
        elif workload == "log":
            instr = n_pc * self.log_instr(version, n_features)
        elif workload == "dtr":
            instr = self.dtr_split_evaluate_instr(n_pc) * n_features
        elif workload == "kme":
            instr = self.kme_instr(n_pc, n_features, k)
        else:
            raise ValueError(workload)
        return self.kernel_seconds(instr, bytes_, n_threads)
