"""PIM execution model (paper §2.2, Fig. 3) mapped onto JAX.

The paper's system: N PIM cores, each owning a DRAM bank; training data is
partitioned once and stays bank-resident; each iteration every core computes
a partial result over its shard; partials are reduced *via the host* (DPUs
cannot talk to each other) and the updated model is re-broadcast.

JAX mapping (DESIGN.md §2):
  PIM core            -> one mesh element of a 1-D "cores" axis
  bank-resident shard -> device-resident leading-axis shard of the dataset
  host reduction      -> jax.lax.psum over "cores" (FabricReduce) or an
                         actual device_get/numpy/device_put round trip
                         (HostReduce — faithful to UPMEM's topology), or a
                         two-level rank schedule (HierarchicalReduce)

:class:`PimSystem` is the memory-centric implementation of the
:class:`~repro.systems.base.System` protocol (DESIGN.md §10); the
execution surface — ``put``/``register_kernel``/``map_reduce``/
``step_program`` — is defined on the shared base and behaves here
exactly as it did when this class WAS the surface (bit-identical fits,
identical TransferStats; asserted by tests/test_pim_system.py and
tests/test_step_fusion.py).

Backends:
  "vmap"      single-device semantic model (cores simulated by vmap) — used
              by unit tests and quality reproduction; bit-identical to the
              sharded path because the kernels are deterministic integer ops.
  "shard_map" real multi-device execution over a jax.Mesh "cores" axis —
              used by the scaling benchmarks and the dry-run.

Cost modeling moved to :mod:`repro.systems.topology` (DESIGN.md §12):
:class:`~repro.systems.topology.HierarchicalCostModel` prices launches
over the explicit channel -> rank -> DPU tree (per-DPU instruction
tables as the leaf compute term, segmented MRAM<->WRAM DMA,
rank-serialized transfer legs, channel contention).  The flat
``DpuCostModel`` remains below as a one-warning deprecation shim so old
imports keep working; every in-repo consumer now uses the hierarchical
model.  Still here: the on-bank storage-dtype table
(``WORKLOAD_STORAGE_DTYPE``/``workload_element_bytes``) the model's
MRAM byte counting reads, because it mirrors what ``PimDataset``
materializes.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.quantization import storage_bytes
from ..obs.trace import TRACER
from .base import ReduceVia, System
from .topology import (DEFAULT_RANKS_PER_CHANNEL, DPU_FREQ_HZ,
                       DPU_MRAM_BYTES_PER_CYCLE, DPU_OP_CYCLES,
                       DPU_PIPELINE_SATURATION_THREADS,
                       HierarchicalCostModel, PimTopology)


@dataclasses.dataclass
class PimConfig:
    n_cores: int = 64
    n_threads: int = 16          # tasklets per core (cost model + layouts)
    reduce: ReduceVia = ReduceVia.FABRIC   # default strategy for map_reduce
    backend: str = "vmap"        # "vmap" | "shard_map"
    dpus_per_rank: Optional[int] = None    # None -> auto (largest divisor <=64)
    ranks_per_channel: int = DEFAULT_RANKS_PER_CHANNEL


class PimSystem(System):
    """Host-orchestrated data-parallel execution over PIM cores.

    The redesigned surface (DESIGN.md §3, §10):
      put(X, y)                 -> PimDataset (bank-resident, view-cached)
      register_kernel(name, fn) -> kernel name usable with map_* calls
      named_kernel(name, build) -> register-once helper for kernel factories
      map_reduce(kernel, ...)   -> kernel may be a registered name or a
                                   callable; ``strategy=`` picks the
                                   reduction per call
    """

    kind = "pim"

    def __init__(self, config: PimConfig, devices: Optional[Sequence] = None):
        super().__init__(config)
        self._mesh = None
        if config.backend == "shard_map":
            devices = list(devices if devices is not None else jax.devices())
            if len(devices) < config.n_cores:
                raise ValueError(
                    f"shard_map backend needs >= {config.n_cores} devices, "
                    f"got {len(devices)} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=...)")
            self._mesh = Mesh(np.array(devices[: config.n_cores]), ("cores",))

    @property
    def n_shards(self) -> int:
        return self.config.n_cores

    @property
    def topology(self) -> PimTopology:
        """The channel -> rank -> DPU tree this machine models
        (DESIGN.md §12) — shared by the cost model, the reduce
        strategies' rank-local/cross-rank accounting, and the
        bank allocator's contention scoring."""
        return PimTopology.for_cores(
            self.config.n_cores,
            dpus_per_rank=self.config.dpus_per_rank,
            ranks_per_channel=self.config.ranks_per_channel)

    def cost_model(self) -> HierarchicalCostModel:
        """A :class:`HierarchicalCostModel` over this machine's tree."""
        return HierarchicalCostModel(self.topology)

    # -- data placement ------------------------------------------------------

    def shard_rows(self, x: np.ndarray, pad_value=0) -> jnp.ndarray:
        """Partition rows across cores: (n, ...) -> (n_cores, n_pc, ...).

        Equal-size shards (padding as needed) mirror the paper's requirement
        that parallel CPU->PIM transfers need equal buffer sizes per bank.
        Counts the modeled CPU->PIM transfer bytes (and the dedicated
        shard_transfers/shard_bytes counters — see TransferStats)."""
        c = self.config.n_cores
        n = x.shape[0]
        n_pc = -(-n // c)
        pad = c * n_pc - n
        if pad:
            x = np.concatenate(
                [x, np.full((pad,) + x.shape[1:], pad_value, x.dtype)], 0)
        out = x.reshape(c, n_pc, *x.shape[1:])
        self.stats.cpu_to_pim += out.nbytes
        self.stats.shard_transfers += 1
        self.stats.shard_bytes += out.nbytes
        arr = jnp.asarray(out)
        if self._mesh is not None:
            arr = jax.device_put(
                arr, NamedSharding(self._mesh, P("cores")))
        return arr

    def row_validity_mask(self, n: int) -> jnp.ndarray:
        """(n_cores, n_pc) bool mask marking real (non-padding) rows."""
        c = self.config.n_cores
        n_pc = -(-n // c)
        idx = np.arange(c * n_pc).reshape(c, n_pc)
        mask = jnp.asarray(idx < n)
        if self._mesh is not None:
            mask = jax.device_put(mask, NamedSharding(self._mesh, P("cores")))
        return mask

    def broadcast(self, tree: Any) -> Any:
        """Host -> all cores broadcast of model state (counted per core)."""
        nbytes = sum(np.asarray(v).nbytes for v in jax.tree_util.tree_leaves(tree))
        self.stats.cpu_to_pim += nbytes * self.config.n_cores
        if TRACER.enabled:
            TRACER.instant("broadcast", self._trace_track, "transfer",
                           bytes=nbytes * self.config.n_cores)
        if self._mesh is not None:
            tree = jax.device_put(
                tree, NamedSharding(self._mesh, P()))  # replicated
        return tree

    # -- execution ------------------------------------------------------------

    def _per_core(self, local_fn, sharded, replicated):
        """Trace the per-core kernel under vmap or shard_map."""
        if self._mesh is None:
            return jax.vmap(lambda *s: local_fn(*s, *replicated))(*sharded)
        mesh = self._mesh

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(tuple(P("cores") for _ in sharded), P()),
            out_specs=P("cores"))
        def _shmap(shard_args, rep):
            local = [jnp.squeeze(a, 0) for a in shard_args]
            out = local_fn(*local, *rep)
            return jax.tree_util.tree_map(lambda v: v[None], out)
        return _shmap(sharded, replicated)

    # -- multi-tenancy -------------------------------------------------------

    def slice(self, lease) -> "PimSystem":
        """A :class:`~repro.sched.allocator.PimSlice` over the leased
        extent — itself a PimSystem, so trainers run on it unmodified."""
        from ..sched.allocator import PimSlice  # local: sched -> systems
        return PimSlice(self, lease)


# ---------------------------------------------------------------------------
# Storage-dtype table (feeds the cost model's MRAM byte counting).
# ---------------------------------------------------------------------------

#: on-bank storage dtype of the training data per (workload, version) —
#: the explicit table the cost model's MRAM byte counting reads, with the
#: per-dtype widths shared with quantization.STORAGE_BYTES.  Mirrors the
#: quantized views PimDataset materializes (repro/api/dataset.py).
WORKLOAD_STORAGE_DTYPE: dict[tuple[str, str], str] = {
    ("lin", "fp32"): "fp32",
    ("lin", "int32"): "int32",
    ("lin", "hyb"): "int8",
    ("lin", "bui"): "int8",
    ("log", "fp32"): "fp32",
    ("log", "int32"): "int32",
    ("log", "int32_lut_mram"): "int32",
    ("log", "int32_lut_wram"): "int32",
    ("log", "hyb_lut"): "int8",
    ("log", "bui_lut"): "int8",
    ("dtr", "fp32"): "fp32",
    ("kme", "int16"): "int16",
    ("kme", "fp32"): "fp32",
    ("emb", "fp32"): "fp32",     # ShardedTable float shards
    ("emb", "int32"): "int32",   # ShardedTable Q(frac_bits) shards
}


def workload_element_bytes(workload: str, version: str) -> int:
    """Bytes per stored feature value for a workload version."""
    try:
        name = WORKLOAD_STORAGE_DTYPE[(workload, version)]
    except KeyError:
        raise ValueError(
            f"no storage dtype recorded for {workload}/{version}; "
            f"add it to WORKLOAD_STORAGE_DTYPE") from None
    return storage_bytes(name)


# ---------------------------------------------------------------------------
# DpuCostModel — deprecation shim over the hierarchical model.
# ---------------------------------------------------------------------------

_DPU_COST_MODEL_WARNED = False


class DpuCostModel(HierarchicalCostModel):
    """Deprecated flat cost model — use
    :class:`repro.systems.topology.HierarchicalCostModel`.

    Kept so old imports (``repro.core.pim.DpuCostModel``) keep working:
    this is the hierarchical model pinned to a single-DPU topology, so
    ``kernel_seconds``/``workload_seconds`` keep their historical
    per-DPU semantics (no transfer legs).  Emits one
    ``DeprecationWarning`` per process.
    """

    def __init__(self, freq_hz: float = DPU_FREQ_HZ,
                 saturation_threads: int = DPU_PIPELINE_SATURATION_THREADS):
        global _DPU_COST_MODEL_WARNED
        if not _DPU_COST_MODEL_WARNED:
            _DPU_COST_MODEL_WARNED = True
            warnings.warn(
                "DpuCostModel is deprecated; use "
                "repro.systems.topology.HierarchicalCostModel (topology-"
                "aware launch pricing, DESIGN.md §12)",
                DeprecationWarning, stacklevel=2)
        super().__init__(topology=PimTopology(n_cores=1),
                         freq_hz=freq_hz,
                         saturation_threads=saturation_threads)
