"""Processor-centric host-CPU baseline (paper §5.4; DESIGN.md §10.3).

The paper's comparison points run the *same algorithms* on a
conventional CPU: one resident copy of the data, fp32 BLAS-style hot
loops, no partitioning, no quantization round-trip, no host<->device
command traffic.  :class:`HostSystem` is that target expressed through
the :class:`~repro.systems.base.System` protocol, replacing the ad-hoc
``train_cpu_baseline`` functions that used to live in every trainer —
now a LIN/LOG/DTR/KME ``Workload`` object fits on a HostSystem
unmodified, through the identical harness (the matched-baseline
discipline PIM-Opt, arXiv:2404.07164, argues for).

Semantics relative to PIM:
  shard_rows      no partitioning: (n, ...) -> (1, n, ...), one resident
                  image (``n_shards == 1``); the shared vmap machinery
                  then traces the kernel over the whole dataset at once
                  — i.e. a plain fp32 jnp hot loop.
  broadcast       free: the model lives in the same address space.
  reduce          degenerate (a sum over one shard); every strategy is
                  numerically a no-op, so ``fuse_steps`` chunks collapse
                  to a plain k-iteration scan ("fuses trivially").
  TransferStats   ``cpu_to_pim``/``pim_to_cpu`` stay 0; ``dram_bytes``
                  counts the dataset bytes each training pass streams
                  from DRAM — the processor-centric bottleneck (what a
                  roofline model prices).  ``shard_transfers``/
                  ``shard_bytes``/``kernel_launches``/``host_syncs``
                  keep their cross-system meaning.
  transcendentals native (``exact_transcendentals``): the LOG fp32
                  baseline uses the exact sigmoid, as the paper's
                  MKL baseline does, not the DPU Taylor expansion.

Scheduling: ``config.n_cores`` is the *lane count* — thread-pool
capacity the :class:`~repro.sched.scheduler.PimScheduler`'s bank
allocator carves, NOT a data-parallel width.  A :class:`HostSlice`
lease is therefore an accounting scope (mirrored stats, shared caches)
over the same single-image execution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .base import (ReduceVia, System, _tree_bytes, adopt_parent_session,
                   check_lease_bounds)


@dataclasses.dataclass
class HostConfig:
    """Host-CPU target configuration.

    ``n_cores`` is scheduling capacity (thread-pool lanes for the job
    scheduler), not a shard width — execution always runs over one
    resident image.  ``reduce`` is accepted for config compatibility;
    every strategy is degenerate over a single shard."""

    n_cores: int = 8
    n_threads: int = 1
    reduce: ReduceVia = ReduceVia.FABRIC
    backend: str = "host"


class HostSystem(System):
    """One-image processor-centric execution of the System surface."""

    kind = "host"
    exact_transcendentals = True

    def __init__(self, config: Optional[HostConfig] = None,
                 devices: Optional[Sequence] = None):
        super().__init__(config or HostConfig())

    @property
    def n_shards(self) -> int:
        return 1

    # -- data placement ------------------------------------------------------

    def shard_rows(self, x: np.ndarray, pad_value=0) -> jnp.ndarray:
        """No partitioning: (n, ...) -> (1, n, ...), one resident image.

        Counted as a view materialization (shard_transfers/shard_bytes)
        so sweep-reuse assertions work on every system; no CPU->PIM
        bytes — the data never leaves the host address space."""
        out = np.asarray(x)[None]
        self.stats.shard_transfers += 1
        self.stats.shard_bytes += out.nbytes
        return jnp.asarray(out)

    def row_validity_mask(self, n: int) -> jnp.ndarray:
        """(1, n) all-true mask: a single image needs no padding."""
        return jnp.ones((1, n), bool)

    def broadcast(self, tree: Any) -> Any:
        """Free: host model state is already where the kernel runs."""
        return tree

    # -- accounting: DRAM traffic instead of CPU<->PIM transfers -------------

    def _charge_launch_operands(self, sharded, replicated) -> None:
        # each training pass streams the resident operands from DRAM
        self.stats.dram_bytes += _tree_bytes(tuple(sharded)) \
            + _tree_bytes(tuple(replicated))

    def _charge_reduce(self, strat, out) -> None:
        pass  # no PIM->CPU boundary to cross

    def _charge_reduce_custom(self, out) -> None:
        pass

    def _charge_inter_core(self, nbytes: int) -> None:
        pass  # no host link between shards of one resident image

    def _charge_topology(self, rank_local: int, cross_rank: int) -> None:
        pass  # no rank tree: a single resident image has no topology

    def _charge_elementwise(self, sharded, replicated) -> None:
        self.stats.dram_bytes += _tree_bytes(tuple(sharded)) \
            + _tree_bytes(tuple(replicated))

    def _charge_chunk(self, carry, sharded, reduced_shape, strat,
                      k: int) -> None:
        # a fused k-step chunk still streams the dataset k times
        self.stats.dram_bytes += k * _tree_bytes(tuple(sharded))

    def _charge_chunk_boundary(self, carry, outs) -> None:
        pass

    # -- multi-tenancy -------------------------------------------------------

    def slice(self, lease) -> "HostSystem":
        return HostSlice(self, lease)


class HostSlice(HostSystem):
    """A lane-scoped accounting view of a parent :class:`HostSystem`.

    There is no core axis to carve on a host target, so a lease
    degrades to a thread-pool lane grant: the slice shares the parent's
    kernel registry and jit cache (one compile serves every tenant),
    executes identically over the single resident image, and mirrors
    its ``TransferStats`` into the parent's so per-job deltas stay
    attributable (DESIGN.md §7.2, §10.3)."""

    def __init__(self, parent: HostSystem, lease):
        check_lease_bounds(parent, lease, "lanes")
        self.parent = parent
        self.lease = lease
        super().__init__(dataclasses.replace(parent.config,
                                             n_cores=lease.n_cores))
        adopt_parent_session(self, parent)
