"""Execution targets behind the backend-portable System protocol.

One API, three implementations (DESIGN.md §10):

  PimSystem         the paper's memory-centric PIM machine (systems/pim.py)
  HostSystem        the processor-centric CPU baseline (systems/host.py)
  ModeledGpuSystem  HostSystem numerics + A100 roofline time/energy
                    (systems/gpu_model.py)

``make_system("pim" | "host" | "gpu-model", n_cores=..., ...)`` is the
construction path the launchers use; every workload, the estimator
facade, the job scheduler, and the fused step engine run unmodified on
any of the three — the paper's CPU-vs-GPU-vs-PIM comparison as a
first-class API call (``repro.launch.compare``).
"""
from __future__ import annotations

from .base import (ChunkBoundary, ChunkPipeline, ChunkTick, FabricReduce,
                   HierarchicalReduce, HostReduce, ReduceStrategy, ReduceVia,
                   StepProgram, System, TransferStats, chunk_schedule,
                   resolve_reduce_strategy, run_steps)
from .compress import CompressedReduce, ef_quantize, quantize_rows
from .gpu_model import GpuModelConfig, GpuModelReport, ModeledGpuSystem
from .host import HostConfig, HostSlice, HostSystem
from .pim import (DPU_FREQ_HZ, DPU_MRAM_BYTES_PER_CYCLE, DPU_OP_CYCLES,
                  DPU_PIPELINE_SATURATION_THREADS, WORKLOAD_STORAGE_DTYPE,
                  DpuCostModel, PimConfig, PimSystem,
                  workload_element_bytes)
from .topology import (DPU_DMA_SEGMENT_BYTES, DPU_DMA_SETUP_CYCLES,
                       DPU_MRAM_BYTES, DPU_WRAM_BYTES, ExtentFootprint,
                       HierarchicalCostModel, PimTopology, default_rank_size)

#: CLI spelling -> (config class, system class); aliases included so
#: both "gpu-model" (flag spelling) and "gpu_model" (identifier
#: spelling) resolve.
SYSTEM_KINDS = {
    "pim": (PimConfig, PimSystem),
    "host": (HostConfig, HostSystem),
    "gpu-model": (GpuModelConfig, ModeledGpuSystem),
    "gpu_model": (GpuModelConfig, ModeledGpuSystem),
}


def make_system(kind: str = "pim", **config_kwargs) -> System:
    """Construct an execution target by name.

    ``make_system("host", n_cores=8)`` — keyword arguments are the
    fields of the target's config dataclass (``PimConfig`` /
    ``HostConfig`` / ``GpuModelConfig``)."""
    try:
        cfg_cls, sys_cls = SYSTEM_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown system kind {kind!r}; known: "
            f"{sorted(set(SYSTEM_KINDS) - {'gpu_model'})}") from None
    return sys_cls(cfg_cls(**config_kwargs))


__all__ = [
    "ChunkBoundary", "ChunkPipeline", "ChunkTick", "CompressedReduce",
    "DPU_DMA_SEGMENT_BYTES", "DPU_DMA_SETUP_CYCLES", "DPU_FREQ_HZ",
    "DPU_MRAM_BYTES", "DPU_MRAM_BYTES_PER_CYCLE", "DPU_OP_CYCLES",
    "DPU_PIPELINE_SATURATION_THREADS", "DPU_WRAM_BYTES", "DpuCostModel",
    "ExtentFootprint", "FabricReduce",
    "GpuModelConfig", "GpuModelReport", "HierarchicalCostModel",
    "HierarchicalReduce", "HostConfig",
    "HostReduce", "HostSlice", "HostSystem", "ModeledGpuSystem",
    "PimConfig", "PimSystem", "PimTopology", "ReduceStrategy", "ReduceVia",
    "SYSTEM_KINDS", "StepProgram", "System", "TransferStats",
    "ef_quantize", "quantize_rows",
    "WORKLOAD_STORAGE_DTYPE", "chunk_schedule", "default_rank_size",
    "make_system",
    "resolve_reduce_strategy", "run_steps", "workload_element_bytes",
]
