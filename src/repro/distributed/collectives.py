"""Hierarchical collectives for the multi-pod mesh (DESIGN.md §5).

Cross-pod links are slower than intra-pod ICI, so the flat
all-reduce over ("pod","data") is decomposed into:

  1. reduce-scatter within the pod  (fast links carry the bulk)
  2. all-reduce of the scattered shards across pods
     (slow links carry 1/pod_size of the bytes)
  3. all-gather within the pod

This is the standard two-level schedule (NCCL tree / TPU hierarchical);
with GSPMD the flat psum often lowers similarly, but the explicit form
pins the schedule and is what the explicit-DP trainer uses on multi-pod
meshes.  Equivalence with the flat psum is tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import axis_size


def hierarchical_psum(x: jnp.ndarray, *, intra_axis: str = "data",
                      inter_axis: str = "pod") -> jnp.ndarray:
    """Sum over (inter_axis x intra_axis) via RS -> inter-AR -> AG.

    Must run inside shard_map with both axes manual.  Requires the
    leading dim of ``x`` to be divisible by the intra-axis size (pad at
    call site otherwise; the trainer's grad vectors satisfy this).
    """
    n_intra = axis_size(intra_axis)
    lead = x.shape[0]
    if lead % n_intra != 0:
        # fall back to the flat reduction for awkward shapes
        return jax.lax.psum(x, (inter_axis, intra_axis))
    # 1. reduce-scatter within the pod over the leading dim
    shard = jax.lax.psum_scatter(x, intra_axis, scatter_dimension=0,
                                 tiled=True)
    # 2. all-reduce the shard across pods (1/n_intra of the bytes)
    shard = jax.lax.psum(shard, inter_axis)
    # 3. all-gather within the pod
    return jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)


def hierarchical_pmean(x: jnp.ndarray, *, intra_axis: str = "data",
                       inter_axis: str = "pod") -> jnp.ndarray:
    total = axis_size(intra_axis) * axis_size(inter_axis)
    return hierarchical_psum(x, intra_axis=intra_axis,
                             inter_axis=inter_axis) / total


def cross_pod_bytes(n_bytes: int, pod_size: int) -> tuple[int, int]:
    """(flat slow-link bytes, hierarchical slow-link bytes) per device —
    the napkin justification: hierarchical moves 1/pod_size as much over
    the slow links."""
    return n_bytes, n_bytes // pod_size
