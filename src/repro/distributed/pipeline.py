"""Pipeline parallelism (GPipe-style) over a mesh "stage" axis.

Completes the parallelism matrix (DP/TP/EP/FSDP are GSPMD-driven; PP needs
an explicit schedule): the layer stack is split into contiguous stages,
microbatches flow through a shard_map'd tick loop, and activations hop
stage-to-stage via ``jax.lax.ppermute``.  Because ppermute transposes to
the reverse permutation under AD, ``jax.grad`` *through* the pipelined
loop yields exactly the GPipe backward schedule — no hand-written
backward pass (validated bitwise against sequential execution in
tests/test_pipeline.py).

Scope: the embedding and LM head stay outside the pipelined region
(replicated or TP-sharded as usual); the pipeline carries the residual
stream [B_mb, S, d].  Bubble fraction is the standard
(n_stages - 1) / (n_micro + n_stages - 1); the tick loop issues compute
for invalid (bubble) slots and masks their writes — on real hardware the
latency-hiding scheduler overlaps the ppermute with the next tick's
compute.

On the production mesh the natural stage axis is "pod" (2 stages across
pods: intra-pod ICI stays TP/DP, the slower pod link carries only
boundary activations — the standard hierarchical deployment).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from ..compat import pcast, shard_map
from jax.sharding import Mesh, PartitionSpec as P


def split_stages(stacked_params, n_stages: int):
    """(reps, ...) leaves -> (n_stages, reps//n_stages, ...)."""
    def one(v):
        reps = v.shape[0]
        assert reps % n_stages == 0, (reps, n_stages)
        return v.reshape(n_stages, reps // n_stages, *v.shape[1:])
    return jax.tree_util.tree_map(one, stacked_params)


def pipeline_apply(mesh: Mesh, stage_axis: str, block_fn: Callable,
                   staged_params, x_micro: jnp.ndarray) -> jnp.ndarray:
    """Run ``block_fn(stage_params, x) -> x`` over all stages.

    staged_params: leaves (n_stages, layers_per_stage, ...) — sharded
                   P(stage_axis) on the leading axis inside shard_map.
    x_micro:       (n_micro, B_mb, S, d) replicated microbatches.
    Returns (n_micro, B_mb, S, d), replicated.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1

    pspecs = jax.tree_util.tree_map(lambda _: P(stage_axis), staged_params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(pspecs, P()), out_specs=P())
    def run(params_stage, xs):
        # local view: leading stage axis is length-1 on each shard
        local = jax.tree_util.tree_map(lambda v: v[0], params_stage)
        stage_id = jax.lax.axis_index(stage_axis)
        last = n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (clipped; bubbles masked below)
            mb_in = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage_id == 0, xs[mb_in], buf)
            h = block_fn(local, x_in)
            # last stage owns microbatch t - last at this tick
            mt = t - last
            write = jnp.logical_and(stage_id == last,
                                    jnp.logical_and(mt >= 0, mt < n_micro))
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(write, h, outs[jnp.clip(mt, 0, n_micro - 1)]),
                jnp.clip(mt, 0, n_micro - 1), 0)
            # hand activations to the next stage
            buf = jax.lax.ppermute(h, stage_axis, fwd_perm)
            return (buf, outs), None

        # mark the carries as varying over the stage axis (shard_map VMA
        # typing: they become stage-dependent after the first ppermute;
        # identity on pre-VMA jax via repro.compat)
        buf0 = pcast(jnp.zeros_like(xs[0]), (stage_axis,), to="varying")
        outs0 = pcast(jnp.zeros_like(xs), (stage_axis,), to="varying")
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        # replicate the last stage's outputs to every shard
        outs = jax.lax.psum(
            jnp.where(stage_id == last, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    return run(staged_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
