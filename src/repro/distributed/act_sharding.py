"""Activation sharding constraints (GSPMD hints inside the model).

Without these, the SPMD partitioner is free to keep activations replicated
over the data axis inside scanned layer bodies — which the granite-3-8b
baseline dry-run actually did (16x redundant compute; see EXPERIMENTS.md
§Perf iteration log).  The model code calls ``constrain(x, kind)`` at
well-known cut points; the launcher opts in by setting the mesh via
``use_mesh`` (tests and single-device runs leave it unset -> no-op).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _dp(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


import os

#: sequence parallelism (Korthikanti et al.): shard the residual stream's
#: sequence dim over "model" between blocks — norms/elementwise compute
#: shard 16x and the per-layer activation all-reduce splits into
#: reduce-scatter + all-gather (overlappable).  §Perf experiment knob.
SEQ_PARALLEL = os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1"


#: cut-point -> spec builder (ndim-aware)
def _spec_for(kind: str, ndim: int, mesh: Mesh) -> Optional[P]:
    dp = _dp(mesh)
    if kind == "btd":        # [B, S, d] residual stream
        if ndim == 3:
            return P(dp, "model", None) if SEQ_PARALLEL else \
                P(dp, None, None)
    if kind == "bhsd":       # [B, H, S, hd] attention heads
        if ndim == 4:
            return P(dp, "model", None, None)
    if kind == "btf":        # [B, S, ffn] mlp hidden
        if ndim == 3:
            return P(dp, None, "model")
    if kind == "ecd":        # [E, cap, d] moe expert inputs/outputs
        if ndim == 3:
            return P("model", None, None)
    if kind == "gecd":       # [G, E, cap, d] group-local moe buffers
        if ndim == 4:
            return P(dp, "model", None, None)
    if kind == "btv":        # [B, S, vocab] logits
        if ndim == 3:
            return P(dp, None, "model")
    if kind == "bdp":        # batch -> dp, everything else replicated
        return P(*((dp,) + (None,) * (ndim - 1)))
    return None


def constrain(x, kind: str):
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _spec_for(kind, x.ndim, mesh)
    if spec is None:
        return x
    # drop axes that don't divide
    from .sharding import validate_divisibility
    spec = validate_divisibility(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
