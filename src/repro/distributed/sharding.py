"""Sharding rules: parameter/optimizer/data PartitionSpecs over the
production mesh axes ("pod", "data", "model").

Philosophy (DESIGN.md §5): batch -> (pod, data); heads / FFN hidden /
experts / vocab -> model.  Specs are GSPMD hints — correctness is the SPMD
partitioner's job; these rules decide the collective schedule, which the
roofline reads back out of the compiled HLO.

Rules are name-based over the param tree paths (every weight in the model
zoo uses the canonical names below), with the leading stacked-layer axis
(reps) always unsharded.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: trailing-dims spec per canonical weight name (leading dims -> None)
_RULES: dict[str, tuple] = {
    # embeddings / heads: vocab over model
    "tok_emb": ("model", None),
    "lm_head": (None, "model"),
    # attention
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "wo": ("model", None),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # mlp
    "up": (None, "model"), "gate": (None, "model"), "down": ("model", None),
    # moe (leading expert axis over model = EP)
    "router": (None, "model"),
    "w_gate": ("model", None, None), "w_up": ("model", None, None),
    "w_down": ("model", None, None),
    # mlstm / ssm
    "w_in": (None, "model"),
    "w_up_m": (None, "model"),
    "conv_w": (None, "model"),
    "w_bc": ("model", None), "w_dt": ("model", None),
    "a_log": ("model", None), "d_skip": ("model",),
    "w_x": (None, "model"), "w_out": ("model", None),
    # misc
    "meta": (), "final_norm": (), "enc_ln": (), "dec_ln": (),
}

#: weight names that stay replicated regardless of shape
_REPLICATED = {"norm", "norm1", "norm2", "attn_norm", "ssm_norm",
               "q_norm", "k_norm", "b", "w", "b_if", "w_if", "r",
               "dt_bias", "gate_attn", "gate_mlp", "ln1", "ln2", "ln3"}


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(k.name)
    return names


def spec_for_param(path, leaf) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    # mlstm's w_up/w_gate collide with moe names; disambiguate by rank:
    # moe expert weights are (reps, E, d, f) = rank 4.
    if name in ("w_gate", "w_up", "w_down") and leaf.ndim < 4:
        rule = {"w_gate": (None, "model"), "w_up": (None, "model"),
                "w_down": ("model", None)}[name]
    elif name in _REPLICATED or name not in _RULES:
        return P()
    else:
        rule = _RULES[name]
    rule = tuple(rule)
    ndim = leaf.ndim
    if len(rule) > ndim:
        return P()
    lead = (None,) * (ndim - len(rule))
    spec = lead + rule
    # never shard an axis the size doesn't divide (e.g. reduced smoke cfgs)
    return P(*spec)


def validate_divisibility(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on axes whose size doesn't divide the mesh axis."""
    out = []
    for dim, s in enumerate(spec):
        if s is None:
            out.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        total = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(s if shape[dim] % total == 0 else None)
    return P(*out)


#: only embeddings keep model-axis sharding when TP is disabled for the
#: backbone (small recurrent models: replicate weights, pure DP + ZeRO)
_EMB_NAMES = {"tok_emb", "lm_head"}


def param_shardings(mesh: Mesh, param_tree, tp_dense: bool = True):
    """NamedShardings for a param (or shape) pytree.

    tp_dense=False: backbone weights replicated (vocab tensors still shard
    over "model") — the §Perf fix for xlstm-class models where TP
    all-gathers of tiny weights dominated the collective term.
    """
    def one(path, leaf):
        names = _path_names(path)
        if not tp_dense and not (_EMB_NAMES & set(names)):
            return NamedSharding(mesh, P())
        spec = spec_for_param(path, leaf)
        spec = validate_divisibility(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, param_tree)


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel mesh axes: ("pod","data") if pod axis present."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def extend_with_dp(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO/FSDP extension: additionally shard the largest still-unsharded
    dim over the data axes (weights: FSDP; adam moments: ZeRO-1).  GSPMD
    inserts the matching all-gathers/reduce-scatters automatically."""
    dp = dp_axes(mesh)
    if not dp:
        return spec
    total = int(np.prod([mesh.shape[a] for a in dp]))
    spec_t = tuple(spec) + (None,) * (len(shape) - len(spec))
    best, best_size = None, 0
    for dim, s in enumerate(spec_t):
        if s is None and shape[dim] % total == 0 and shape[dim] > best_size:
            best, best_size = dim, shape[dim]
    if best is None:
        return P(*spec_t)
    out = list(spec_t)
    out[best] = dp if len(dp) > 1 else dp[0]
    return P(*out)


def param_shardings_fsdp(mesh: Mesh, param_tree):
    """FSDP variant of param_shardings (dbrx-class models whose replicated
    weights would not fit per-chip HBM)."""
    def one(path, leaf):
        spec = spec_for_param(path, leaf)
        spec = validate_divisibility(spec, leaf.shape, mesh)
        spec = extend_with_dp(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, param_tree)


def opt_state_shardings(mesh: Mesh, param_tree):
    """ZeRO-1: adam moments sharded over data axes on top of the param
    spec (f32 moments are 4x the bf16 weights — always worth sharding)."""
    return param_shardings_fsdp(mesh, param_tree)


def batch_shardings(mesh: Mesh, batch_tree):
    """Leading axis -> data parallel; everything else replicated."""
    dp = dp_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        total = int(np.prod([mesh.shape[a] for a in dp]))
        spec = (dp if leaf.shape[0] % total == 0 else None,)
        return NamedSharding(mesh, P(*spec + (None,) * (leaf.ndim - 1)))
    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree):
    """KV caches: (reps, B, H, S, D) -> (None, dp, model, None, None);
    recurrent states (reps, B, ...) -> (None, dp, ...); scalars replicated.
    Falls back to replication when sizes don't divide."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        if leaf.ndim <= 1:
            return NamedSharding(mesh, P())
        names = _path_names(path)
        name = names[-1] if names else ""
        spec: list = [None] * leaf.ndim
        # find the batch axis: KVCache leaves are (reps, B, H, S, D) or
        # whisper dict leaves (L, B, H, S, D); states (reps, B, ...)
        b_axis = 1 if leaf.ndim >= 2 else None
        if b_axis is not None:
            spec[b_axis] = dp
        if leaf.ndim >= 4 and name in ("k", "v", "ck", "cv"):
            spec[2] = "model"
        validated = validate_divisibility(P(*spec), leaf.shape, mesh)
        return NamedSharding(mesh, validated)
    return jax.tree_util.tree_map_with_path(one, cache_tree)
