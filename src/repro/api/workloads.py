"""The paper's four workloads registered behind the Workload protocol.

Each adapter maps the unified ``TrainerSpec`` onto the native trainer
config (``GdConfig``/``LogRegConfig``/``TreeConfig``/``KMeansConfig``),
fits on a bank-resident :class:`~repro.api.dataset.PimDataset`, and
serves host-side prediction exactly as the paper's sklearn deployment
does (§4).  ``make_estimator("kmeans", version="int16", n_clusters=8)``
is the one construction path; the legacy classes in core/estimators.py
are thin shims over it.

Every workload exposes a ``kernel_backend`` hyperparameter (None =
per-platform auto-selection) that flows into the trainers' kernel
dispatch (repro.kernels.dispatch): ``make_estimator("kmeans",
kernel_backend="pallas_interpret")`` runs the assignment hot path
through the Pallas interpreter, etc.

The iterative workloads (LIN/LOG/KME) also expose ``fuse_steps``
(DESIGN.md §9): ``make_estimator("linreg", version="int32",
fuse_steps=32)`` compiles 32 consecutive training steps into one
``lax.scan`` launch — bit-identical to the per-step loop for the
integer versions, and the repo's biggest single wall-clock lever
(benchmarks/step_fusion_bench.py).
"""
from __future__ import annotations

import numpy as np

from ..core import dtree, kmeans, linreg, logreg, metrics
from .registry import FitResult, TrainerSpec, Workload, register_workload


def kmeans_sq_distances(X, C) -> np.ndarray:
    """Squared Euclidean distances (n, k) between rows of X and centroids.

    THE single distance helper shared by K-Means ``predict`` and
    ``score``: it keeps the ``||x||^2`` term, so the values are true
    squared distances — safe for argmin AND for inertia/scoring.  (The
    pre-registry facade carried two copies, one of which dropped the
    ``||x||^2`` term; fine for argmin, wrong the moment it was reused
    for distances.)"""
    X = np.asarray(X, np.float32)
    C = np.asarray(C, np.float32)
    return ((X * X).sum(1)[:, None] - 2.0 * X @ C.T
            + (C * C).sum(1)[None, :])


class LinRegWorkload(Workload):
    """LIN (paper §3.1): linear regression via gradient descent."""

    name = "linreg"
    aliases = ("lin", "linear_regression")
    versions = linreg.VERSIONS
    resumable = True
    defaults = {"n_iters": 500, "lr": 0.1, "frac_bits": 10, "x8_frac": 7,
                "w16_frac": 8, "record_every": 0, "minibatch": 0, "seed": 0,
                "kernel_backend": None, "fuse_steps": 1,
                "pipeline_depth": 2}

    def _config(self, spec: TrainerSpec) -> linreg.GdConfig:
        return linreg.GdConfig(version=spec.version, **spec.params)

    def fit(self, dataset, spec: TrainerSpec) -> FitResult:
        r = linreg.fit(dataset, self._config(spec))
        return FitResult(spec, r, {"coef_": r.w, "intercept_": r.b})

    def fit_steps(self, dataset, spec: TrainerSpec, *, state=None):
        r = yield from linreg.fit_steps(dataset, self._config(spec),
                                        state=state)
        return FitResult(spec, r, {"coef_": r.w, "intercept_": r.b})

    def predict(self, result: FitResult, X):
        return result.model.predict(np.asarray(X))

    def score(self, result: FitResult, X, y=None) -> float:
        """R^2, the sklearn regression convention."""
        y = np.asarray(y, np.float64)
        pred = self.predict(result, X)
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum())
        return 1.0 - ss_res / max(ss_tot, 1e-12)


class LogRegWorkload(Workload):
    """LOG (paper §3.2): logistic regression, Taylor or LUT sigmoid."""

    name = "logreg"
    aliases = ("log", "logistic_regression")
    versions = logreg.VERSIONS
    resumable = True
    defaults = {"n_iters": 500, "lr": 5.0, "frac_bits": 10, "x8_frac": 7,
                "w16_frac": 8, "record_every": 0, "minibatch": 0, "seed": 0,
                "taylor_terms": 8, "lut_boundary": 20, "lut_frac_bits": 10,
                "kernel_backend": None, "fuse_steps": 1,
                "pipeline_depth": 2}

    def _config(self, spec: TrainerSpec) -> logreg.LogRegConfig:
        return logreg.LogRegConfig(version=spec.version, **spec.params)

    def fit(self, dataset, spec: TrainerSpec) -> FitResult:
        r = logreg.fit(dataset, self._config(spec))
        return FitResult(spec, r, {"coef_": r.w, "intercept_": r.b})

    def fit_steps(self, dataset, spec: TrainerSpec, *, state=None):
        r = yield from logreg.fit_steps(dataset, self._config(spec),
                                        state=state)
        return FitResult(spec, r, {"coef_": r.w, "intercept_": r.b})

    def decision_function(self, result: FitResult, X):
        return result.model.predict(np.asarray(X))

    def predict_proba(self, result: FitResult, X):
        z = self.decision_function(result, X)
        p1 = 1.0 / (1.0 + np.exp(-z))
        return np.stack([1.0 - p1, p1], axis=1)

    def predict(self, result: FitResult, X):
        return (self.decision_function(result, X) > 0.0).astype(np.int32)

    def score(self, result: FitResult, X, y=None) -> float:
        return metrics.accuracy(self.predict(result, X),
                                np.asarray(y) > 0.5)


class DecisionTreeWorkload(Workload):
    """DTR (paper §3.3): extremely randomized tree classification."""

    name = "dtree"
    aliases = ("dtr", "decision_tree")
    versions = ("fp32",)
    defaults = {"max_depth": 10, "n_classes": 2, "min_samples_split": 2,
                "seed": 0, "kernel_backend": None}

    def _config(self, spec: TrainerSpec) -> dtree.TreeConfig:
        return dtree.TreeConfig(**spec.params)

    def fit(self, dataset, spec: TrainerSpec) -> FitResult:
        tree = dtree.fit(dataset, self._config(spec))
        return FitResult(spec, tree,
                         {"tree_": tree, "n_nodes_": tree.n_nodes})

    def fit_steps(self, dataset, spec: TrainerSpec, *, state=None):
        # DTR is not resumable: the tree builds host-side in one macro
        # pass; a preempted tree job restarts from scratch (state must
        # be None — enforced here as in the Workload base).
        if state is not None:
            raise ValueError("dtree is not resumable; it cannot accept "
                             "a checkpoint state")
        tree = yield from dtree.fit_steps(dataset, self._config(spec))
        return FitResult(spec, tree,
                         {"tree_": tree, "n_nodes_": tree.n_nodes})

    def predict(self, result: FitResult, X):
        return result.model.predict(np.asarray(X))

    def score(self, result: FitResult, X, y=None) -> float:
        return metrics.accuracy(self.predict(result, X), np.asarray(y))


class KMeansWorkload(Workload):
    """KME (paper §3.4): quantized Lloyd's with restarts."""

    name = "kmeans"
    aliases = ("kme",)
    #: "int16" = the paper's quantized PIM version; "fp32" = the
    #: processor-centric float baseline (DESIGN.md §10.3)
    versions = kmeans.VERSIONS
    unsupervised = True
    resumable = True
    defaults = {"n_clusters": 16, "max_iter": 300, "tol": 1e-4,
                "n_init": 1, "seed": 0, "kernel_backend": None,
                "fuse_steps": 1, "pipeline_depth": 2}

    def _config(self, spec: TrainerSpec) -> kmeans.KMeansConfig:
        p = spec.params
        return kmeans.KMeansConfig(k=p["n_clusters"],
                                   max_iters=p["max_iter"], tol=p["tol"],
                                   n_init=p["n_init"], seed=p["seed"],
                                   kernel_backend=p["kernel_backend"],
                                   fuse_steps=p["fuse_steps"],
                                   pipeline_depth=p["pipeline_depth"],
                                   version=spec.version)

    def fit(self, dataset, spec: TrainerSpec) -> FitResult:
        r = kmeans.fit(dataset, self._config(spec))
        return FitResult(spec, r, {"cluster_centers_": r.centroids,
                                   "inertia_": r.inertia,
                                   "labels_": r.labels,
                                   "n_iter_": r.n_iters})

    def fit_steps(self, dataset, spec: TrainerSpec, *, state=None):
        r = yield from kmeans.fit_steps(dataset, self._config(spec),
                                        state=state)
        return FitResult(spec, r, {"cluster_centers_": r.centroids,
                                   "inertia_": r.inertia,
                                   "labels_": r.labels,
                                   "n_iter_": r.n_iters})

    def predict(self, result: FitResult, X):
        d = kmeans_sq_distances(X, result.model.centroids)
        return d.argmin(1).astype(np.int32)

    def score(self, result: FitResult, X, y=None) -> float:
        """Negative inertia of X under the fitted centroids (sklearn)."""
        d = kmeans_sq_distances(X, result.model.centroids)
        return -float(d.min(1).sum())


register_workload(LinRegWorkload())
register_workload(LogRegWorkload())
register_workload(DecisionTreeWorkload())
register_workload(KMeansWorkload())

# EMB lives in its own subsystem (repro.emb) — importing its adapter
# here registers it alongside the paper's four (DESIGN.md §15.2)
from ..emb.workload import EmbWorkload  # noqa: E402,F401  (registers)
