"""The unified workload-session API — the single public surface.

The paper's premise (§2.2, Fig. 3) is that training data is partitioned
ONCE and stays bank-resident across iterations.  This package makes that
a first-class object model (DESIGN.md §3):

  System / make_system    backend-portable execution targets (DESIGN.md
                          §10): PimSystem (default), HostSystem (the
                          processor-centric CPU baseline), and
                          ModeledGpuSystem (A100 roofline reporting) —
                          every workload runs unmodified on any of them
  PimSystem / PimConfig   execution session over N PIM cores
  PimDataset              bank-resident dataset handle (PimSystem.put);
                          quantized views are lazy and cached, so sweeps
                          and restarts pay one CPU->PIM transfer
  Workload / registry     the four paper workloads (and any future one)
                          behind one TrainerSpec -> FitResult shape
  make_estimator          sklearn-compatible facade over any registered
                          workload (get_params/set_params, fit/predict)
  ReduceStrategy          pluggable cross-core reduction, per call

Typical session::

    from repro.api import PimConfig, PimSystem, make_estimator

    pim = PimSystem(PimConfig(n_cores=16))
    ds = pim.put(X, y)                       # one CPU->PIM partition
    for lr in (0.05, 0.1, 0.2):              # sweep reuses the banks
        est = make_estimator("linreg", version="hyb", lr=lr, system=pim)
        est.fit(ds)
"""
from ..systems import (DpuCostModel, FabricReduce, GpuModelConfig,
                       HierarchicalCostModel, HierarchicalReduce,
                       HostConfig, HostReduce,
                       HostSystem, ModeledGpuSystem, PimConfig, PimSystem,
                       PimTopology, ReduceStrategy, ReduceVia, System,
                       TransferStats, make_system, resolve_reduce_strategy)
from .dataset import PimDataset
from .estimator import PimEstimator, make_estimator
from .registry import (FitResult, TrainerSpec, Workload, get_workload,
                       list_workloads, register_workload)
from .workloads import kmeans_sq_distances  # noqa: F401 — also registers
                                            # the four paper workloads

#: scheduler-subsystem names re-exported lazily (PEP 562) — repro.sched
#: imports this package's submodules, so an eager import here would
#: cycle during ``import repro.sched``.
_SCHED_EXPORTS = ("BankAllocator", "BankLease", "FragmentationStats",
                  "JobHandle", "JobState", "PimScheduler", "PimSlice")


def __getattr__(name: str):
    if name in _SCHED_EXPORTS:
        from .. import sched
        return getattr(sched, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "DpuCostModel", "FabricReduce", "FitResult", "GpuModelConfig",
    "HierarchicalCostModel", "HierarchicalReduce", "HostConfig",
    "HostReduce", "HostSystem",
    "ModeledGpuSystem", "PimConfig", "PimDataset", "PimEstimator",
    "PimSystem", "PimTopology", "ReduceStrategy", "ReduceVia", "System",
    "TrainerSpec",
    "TransferStats", "Workload", "get_workload", "kmeans_sq_distances",
    "list_workloads", "make_estimator", "make_system",
    "register_workload", "resolve_reduce_strategy",
    *_SCHED_EXPORTS,
]
