"""Workload protocol + registry (DESIGN.md §3.3).

The four paper workloads (LIN/LOG/DTR/KME) — and any future one — plug in
behind one ``TrainerSpec -> FitResult`` shape:

  * :class:`TrainerSpec` normalizes the per-workload config dataclasses
    (``GdConfig``/``LogRegConfig``/``TreeConfig``/``KMeansConfig``) into a
    (workload, version, params) triple;
  * :class:`Workload` adapts a trainer to the spec: build the native
    config, fit on a :class:`~repro.api.dataset.PimDataset` — whose
    owning :class:`~repro.systems.base.System` may be any execution
    target (PIM, host-CPU baseline, modeled GPU — DESIGN.md §10) —
    and serve host-side prediction/scoring off the fitted model;
  * :func:`register_workload` / :func:`get_workload` is the lookup the
    estimator facade and the launchers resolve names through (aliases
    cover the paper's LIN/LOG/DTR/KME abbreviations).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

from .dataset import PimDataset


@dataclasses.dataclass(frozen=True)
class TrainerSpec:
    """Normalized description of one training run."""

    workload: str
    version: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def replace(self, **updates) -> "TrainerSpec":
        merged = dict(self.params)
        version = updates.pop("version", self.version)
        merged.update(updates)
        return TrainerSpec(self.workload, version, merged)


@dataclasses.dataclass
class FitResult:
    """What every workload's ``fit`` returns.

    ``model`` is the workload-native fitted object (``GdResult``,
    ``Tree``, ``KMeansResult``); ``attributes`` are the sklearn-style
    learned attributes the estimator facade re-exports (``coef_``,
    ``cluster_centers_``, ...).
    """

    spec: TrainerSpec
    model: Any
    attributes: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def workload(self) -> str:
        return self.spec.workload

    @property
    def version(self) -> str:
        return self.spec.version


class Workload:
    """Adapter base: one instance per registered workload.

    Subclasses define ``name``, ``versions``, ``defaults`` and implement
    ``fit``; prediction/scoring run host-side off the FitResult, exactly
    as the paper's sklearn deployment does (§4).
    """

    name: str = ""
    aliases: tuple = ()
    versions: tuple = ()
    #: default hyperparameters (the estimator facade's get_params surface)
    defaults: Mapping[str, Any] = {}
    #: True when fit consumes (X,) only — no targets (K-Means)
    unsupervised: bool = False
    #: True when ``fit_steps`` accepts ``state=`` and yields
    #: :class:`~repro.systems.base.ChunkTick` snapshots — the elastic
    #: runtime can preempt/checkpoint/migrate the job (DESIGN.md §11).
    #: Non-resumable workloads (DTR builds a tree host-side in one
    #: macro-step) lose progress on preemption and restart from scratch.
    resumable: bool = False

    def spec(self, version: Optional[str] = None, **params) -> TrainerSpec:
        version = version or self.versions[0]
        if self.versions and version not in self.versions:
            raise ValueError(
                f"{self.name}: unknown version {version!r}; "
                f"known: {self.versions}")
        unknown = set(params) - set(self.defaults)
        if unknown:
            raise TypeError(
                f"{self.name}: unknown hyperparameters {sorted(unknown)}; "
                f"known: {sorted(self.defaults)}")
        merged = dict(self.defaults)
        merged.update(params)
        return TrainerSpec(self.name, version, merged)

    def fit(self, dataset: PimDataset, spec: TrainerSpec) -> FitResult:
        raise NotImplementedError

    def fit_steps(self, dataset: PimDataset, spec: TrainerSpec, *,
                  state: Optional[dict] = None):
        """Generator: advance the fit by one host-orchestrated PIM step
        per ``next()``; the FitResult travels on StopIteration.

        This is the surface the job scheduler gang-steps (DESIGN.md
        §7.3).  The default runs :meth:`fit` as a single macro-step, so
        every workload is schedulable; iterative workloads override it
        with their trainer's true per-iteration generator.

        ``state`` is a chunk-boundary snapshot from a previous run's
        ``ChunkTick.snapshot()`` — only :attr:`resumable` workloads
        accept one (DESIGN.md §11.2)."""
        if state is not None:
            raise ValueError(
                f"workload {self.name!r} is not resumable; it cannot "
                f"accept a checkpoint state")
        result = self.fit(dataset, spec)
        yield 1
        return result

    def predict(self, result: FitResult, X):
        raise NotImplementedError

    def score(self, result: FitResult, X, y=None) -> float:
        raise NotImplementedError


_REGISTRY: dict[str, Workload] = {}


def register_workload(workload: Workload) -> Workload:
    """Register a workload under its name and aliases (idempotent)."""
    for key in (workload.name, *workload.aliases):
        existing = _REGISTRY.get(key)
        if existing is not None and type(existing) is not type(workload):
            raise ValueError(f"workload name {key!r} already registered "
                             f"by {type(existing).__name__}")
        _REGISTRY[key] = workload
    return workload


def get_workload(name: str) -> Workload:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no workload registered under {name!r}; "
                       f"known: {sorted(set(_REGISTRY))}") from None


def list_workloads() -> dict[str, Workload]:
    """Canonical name -> workload (aliases folded away)."""
    return {w.name: w for w in _REGISTRY.values()}
