"""Generic sklearn-compatible estimator facade (DESIGN.md §3.4).

One class serves all registered workloads (the paper deploys its four
implementations "as Scikit-learn estimator objects", §4; sklearn itself
is not installable offline, so the fit/predict/score/get_params protocol
is implemented directly and is duck-type compatible with pipelines).

``fit`` accepts either raw arrays (one placement per call, like the old
API) or a :class:`~repro.api.dataset.PimDataset` — the sweep path where
the placement is paid once per session.

The estimator is backend-portable (DESIGN.md §10): ``system=`` accepts
ANY :class:`~repro.systems.base.System` — the default ``PimSystem``, a
``HostSystem`` CPU baseline, or a ``ModeledGpuSystem`` — and the fit
runs there unmodified::

    make_estimator("linreg", version="fp32",
                   system=make_system("host")).fit(X, y)

(``pim=`` remains accepted as a deprecated alias for one PR.)

Hyperparameters flow through to the trainers untyped, so every knob the
workload registry declares is available here — including ``fuse_steps``
(DESIGN.md §9): ``make_estimator("linreg", version="int32",
fuse_steps=32).fit(ds)`` trains with 32 GD iterations compiled into each
``lax.scan`` launch, bit-identical to ``fuse_steps=1`` for the integer
versions and ~an order of magnitude faster wall-clock.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from ..systems import PimConfig, PimSystem, System
from .dataset import PimDataset
from .registry import FitResult, Workload, get_workload


def _default_system(n_cores: int = 16) -> PimSystem:
    return PimSystem(PimConfig(n_cores=n_cores))


def _resolve_system_kwarg(system: Optional[System],
                          pim: Optional[System]) -> Optional[System]:
    """Fold the deprecated ``pim=`` alias into ``system=`` (one
    DeprecationWarning per call site, pattern of core/estimators.py)."""
    if pim is not None:
        warnings.warn(
            "the pim= keyword is deprecated; pass system= (any "
            "repro.systems.System — PimSystem, HostSystem, "
            "ModeledGpuSystem)", DeprecationWarning, stacklevel=3)
        if system is None:
            system = pim
    return system


class PimEstimator:
    """sklearn-style facade over any registered workload."""

    def __init__(self, workload, version: Optional[str] = None,
                 n_cores: int = 16, pim: Optional[System] = None,
                 system: Optional[System] = None, **params):
        self.workload: Workload = (get_workload(workload)
                                   if isinstance(workload, str) else workload)
        # validate eagerly so a typo'd hyperparameter fails at construction
        spec = self.workload.spec(version, **params)
        self.version = spec.version
        system = _resolve_system_kwarg(system, pim)
        self.system: System = system or _default_system(n_cores)
        self.n_cores = self.system.config.n_cores
        self._params = dict(spec.params)
        self.result_: Optional[FitResult] = None

    # -- legacy alias --------------------------------------------------------

    @property
    def pim(self) -> System:
        """Deprecated name for :attr:`system` (kept for one PR)."""
        return self.system

    @pim.setter
    def pim(self, value: System) -> None:
        self.system = value
        self.n_cores = value.config.n_cores

    # -- sklearn parameter protocol -----------------------------------------

    def get_params(self, deep: bool = True) -> dict:
        out = {"version": self.version, "n_cores": self.n_cores}
        out.update(self._params)
        return out

    def set_params(self, **params) -> "PimEstimator":
        # validate the full candidate combination FIRST so a rejected
        # call leaves the estimator untouched
        version = params.pop("version", self.version)
        n_cores = params.pop("n_cores", None)
        system = _resolve_system_kwarg(params.pop("system", None),
                                       params.pop("pim", None))
        unknown = set(params) - set(self.workload.defaults)
        if unknown:
            raise ValueError(f"invalid parameters {sorted(unknown)} for "
                             f"{self.workload.name}")
        hyper = dict(self._params)
        hyper.update(params)
        self.workload.spec(version, **hyper)

        self.version = version
        self._params = hyper
        if n_cores is not None:
            # rebuild the session at the new core count, preserving the
            # rest of its config (system kind, reduce strategy, backend,
            # threads)
            self.n_cores = int(n_cores)
            self.system = type(self.system)(dataclasses.replace(
                self.system.config, n_cores=self.n_cores))
        if system is not None:
            self.system = system
            self.n_cores = self.system.config.n_cores
        return self

    # -- estimation protocol -------------------------------------------------

    def fit(self, X, y=None) -> "PimEstimator":
        if isinstance(X, PimDataset):
            if y is not None:
                raise ValueError(
                    "y must not be passed alongside a PimDataset — the "
                    "dataset already holds its labels; rebuild it with "
                    "System.put(X, y) to change them")
            # a dataset is bound to the system holding its shards;
            # training runs there.  Adopt it so the estimator's config
            # and stats refer to the system that actually trained.
            ds = X
            self.system = ds.system
            self.n_cores = self.system.config.n_cores
        else:
            ds = self.system.put(X, None if self.workload.unsupervised
                                 else y)
        spec = self.workload.spec(self.version, **self._params)
        self.result_ = self.workload.fit(ds, spec)
        for name, value in self.result_.attributes.items():
            setattr(self, name, value)
        return self

    def _fitted(self) -> FitResult:
        if self.result_ is None:
            raise RuntimeError(
                f"this {self.workload.name} estimator is not fitted yet; "
                f"call fit first")
        return self.result_

    def predict(self, X):
        return self.workload.predict(self._fitted(), X)

    def score(self, X, y=None) -> float:
        return self.workload.score(self._fitted(), X, y)

    def fit_predict(self, X, y=None):
        return self.fit(X, y).predict(
            X.X if isinstance(X, PimDataset) else X)

    # optional per-workload methods (classifiers expose probabilities)

    def decision_function(self, X):
        return self._optional("decision_function", X)

    def predict_proba(self, X):
        return self._optional("predict_proba", X)

    def _optional(self, method: str, X):
        fn = getattr(self.workload, method, None)
        if fn is None:
            raise AttributeError(
                f"{self.workload.name} does not implement {method}")
        return fn(self._fitted(), X)

    def __repr__(self) -> str:
        kv = ", ".join(f"{k}={v!r}" for k, v in self.get_params().items())
        return f"PimEstimator({self.workload.name!r}, {kv})"


def make_estimator(name: str, version: Optional[str] = None,
                   n_cores: int = 16, pim: Optional[System] = None,
                   system: Optional[System] = None,
                   **params) -> PimEstimator:
    """Construct an estimator for any registered workload by name.

    ``make_estimator("kmeans", version="int16", n_clusters=8)`` — pass
    ``system=`` to target a specific execution backend (PIM, host CPU,
    or the modeled GPU; DESIGN.md §10)."""
    return PimEstimator(get_workload(name), version=version,
                        n_cores=n_cores, pim=pim, system=system, **params)
