"""Bank-sharded embedding tables (DESIGN.md §15.1).

A :class:`ShardedTable` is the :class:`PimDataset` sibling for model
state that is too large to broadcast: an embedding table is row-sharded
across the bank extents ONCE (``System.put_table``), each shard keeping
its slice of the placement map (the global row ids it owns), and only
sparse lookups / sparse update rows cross the host<->PIM boundary per
step — exactly the LazyDP access pattern the EMB workload reproduces.

Placement maps (``placement=``):

``"mod"``   shard ``v % S`` owns global row ``v`` at slot ``v // S`` —
            the round-robin layout that load-balances Zipf-skewed id
            traffic across banks (consecutive hot ids land on different
            shards).
``"hash"``  a seeded permutation is applied first, then round-robin —
            breaks any adversarial stride in the id space.

Both pad the vocabulary tail up to ``S x R`` slots; padded slots carry
the ``ROW_PAD_ID`` sentinel in the id map and can never match a lookup.

The table also carries the LazyDP-style *staging ledger* for deferred
updates (§15.3): ``stage()`` accumulates per-minibatch sparse update
rows host-side; ``drain()`` hands back the (optionally deduplicated —
``np.add.at`` segment-sum) pending rows for one batched scatter-add
flush.  The ledger is plain host state, so it serializes into elastic
checkpoints like any other trainer array.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core.fixed_point import to_fixed
from ..kernels.sparse_gather import ROW_PAD_ID

#: table storage precisions (version -> dtype of the device shards)
TABLE_VERSIONS = ("fp32", "int32")

PLACEMENTS = ("mod", "hash")


class ShardedTable:
    """Handle to an embedding table row-sharded across bank extents."""

    def __init__(self, system, weights, *, placement: str = "mod",
                 seed: int = 0):
        if placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r}; "
                             f"known: {PLACEMENTS}")
        W = np.asarray(weights, np.float32)
        if W.ndim != 2:
            raise ValueError(f"table weights must be 2-D (rows, dim), "
                             f"got shape {W.shape}")
        self.system = system
        self.host = W                       # master f32 copy (init values)
        self.n_rows = int(W.shape[0])
        self.dim = int(W.shape[1])
        self.placement = placement
        self.seed = int(seed)

        S = system.n_shards
        self.n_shards = S
        self.rows_per_shard = -(-self.n_rows // S)          # R
        # flat placement map in shard-major order: slot (s, r) lives at
        # flat position s*R + r and owns global row ids[s, r]
        ids = np.full((S, self.rows_per_shard), ROW_PAD_ID, np.int32)
        order = np.arange(self.n_rows, dtype=np.int32)
        if placement == "hash":
            order = np.random.RandomState(self.seed).permutation(
                self.n_rows).astype(np.int32)
        # round-robin: flat grid position p = r*S + s  <- order[p]
        grid = np.full(S * self.rows_per_shard, ROW_PAD_ID, np.int32)
        grid[:self.n_rows] = order
        ids[:, :] = grid.reshape(self.rows_per_shard, S).T
        self._ids = ids                                     # (S, R) int32
        self._views: Dict[tuple, Any] = {}
        self._ids_dev: Optional[jnp.ndarray] = None
        #: per-shard materialization accounting (rows owned is fixed by
        #: the placement; bytes accrue per materialized view)
        self.shard_stats: List[dict] = [
            {"shard": s, "rows": int((ids[s] >= 0).sum()), "bytes": 0}
            for s in range(S)]
        # LazyDP staging ledger: per-minibatch sparse update rows
        self._pending_idx: List[np.ndarray] = []
        self._pending_upd: List[np.ndarray] = []
        self.pending_batches = 0

    # -- placement map -------------------------------------------------------

    @property
    def ids(self) -> np.ndarray:
        """(S, R) int32 placement map (ROW_PAD_ID marks padding)."""
        return self._ids

    def lookup_shard(self, v: int) -> tuple:
        """(shard, slot) owning global row ``v`` — placement diagnostics."""
        s, r = np.nonzero(self._ids == int(v))
        if len(s) == 0:
            raise KeyError(f"row {v} not in table of {self.n_rows} rows")
        return int(s[0]), int(r[0])

    def ids_device(self) -> jnp.ndarray:
        """(S, R) int32 placement map resident on the device (cached)."""
        if self._ids_dev is None:
            self._ids_dev = self.system.shard_rows(
                self._ids.reshape(-1), pad_value=ROW_PAD_ID)
            nb = self._ids.nbytes // self.n_shards
            for st in self.shard_stats:
                st["bytes"] += nb
        return self._ids_dev

    # -- sharded views -------------------------------------------------------

    def view(self, version: str = "fp32", frac_bits: int = 10) -> tuple:
        """(shards [S, R, D], ids [S, R]) device view, cached per
        precision.  ``"int32"`` stores Q(frac_bits) fixed point — the
        PIM version; ``"fp32"`` is the float baseline."""
        if version not in TABLE_VERSIONS:
            raise ValueError(f"unknown table version {version!r}; "
                             f"known: {TABLE_VERSIONS}")
        key = (version, frac_bits if version == "int32" else None)
        view = self._views.get(key)
        if view is None:
            rows = self._gather_rows(version, frac_bits)
            shards = self.system.shard_rows(rows.reshape(-1, self.dim))
            nb = rows.nbytes // self.n_shards
            for st in self.shard_stats:
                st["bytes"] += nb
            view = (shards, self.ids_device())
            self._views[key] = view
        return view

    @property
    def n_views(self) -> int:
        """Materialized (transferred) table views — diagnostics."""
        return len(self._views)

    def _gather_rows(self, version: str, frac_bits: int) -> np.ndarray:
        """Host (S, R, D) grid in placement order, zeros in pad slots."""
        if version == "int32":
            W = np.asarray(to_fixed(self.host, frac_bits))
        else:
            W = self.host
        grid = np.zeros((self.n_shards, self.rows_per_shard, self.dim),
                        W.dtype)
        owned = self._ids >= 0
        grid[owned] = W[self._ids[owned]]
        return grid

    def place_rows(self, rows) -> jnp.ndarray:
        """Shard raw (V, D) storage rows through this table's placement
        (uncached — the elastic-restore path: checkpointed tables are
        size-independent (V, D) host arrays, re-placed on whatever
        system resumes the job).  Inverse of :meth:`unshard`."""
        rows = np.asarray(rows)
        assert rows.shape == (self.n_rows, self.dim), rows.shape
        grid = np.zeros((self.n_shards, self.rows_per_shard, self.dim),
                        rows.dtype)
        owned = self._ids >= 0
        grid[owned] = rows[self._ids[owned]]
        shards = self.system.shard_rows(grid.reshape(-1, self.dim))
        nb = grid.nbytes // self.n_shards
        for st in self.shard_stats:
            st["bytes"] += nb
        return shards

    def unshard(self, shards, version: str = "fp32",
                frac_bits: int = 10) -> np.ndarray:
        """Reassemble (V, D) host rows from an (S, R, D) shard grid
        (e.g. the trainer's updated tables), inverting the placement.
        Returns the raw storage dtype (int32 Q(frac_bits) or float32).
        """
        del version, frac_bits  # dtype rides the shards themselves
        shards = np.asarray(shards)
        out = np.zeros((self.n_rows, self.dim), shards.dtype)
        owned = self._ids >= 0
        out[self._ids[owned]] = shards[owned]
        return out

    # -- deferred-update staging ledger (DESIGN.md §15.3) --------------------

    def stage(self, idx, upd) -> None:
        """Append one minibatch of sparse update rows to the ledger."""
        idx = np.asarray(idx, np.int32)
        upd = np.asarray(upd)
        assert idx.shape[0] == upd.shape[0], (idx.shape, upd.shape)
        self._pending_idx.append(idx)
        self._pending_upd.append(upd)
        self.pending_batches += 1

    @property
    def pending_rows(self) -> int:
        return sum(int(v.shape[0]) for v in self._pending_idx)

    def drain(self, dedup: bool = True) -> tuple:
        """Pop the ledger as one ``(idx, upd)`` flush batch.

        ``dedup=True`` segment-sums duplicate ids host-side
        (``np.unique`` + ``np.add.at``) so each touched row ships ONCE —
        the deferred-flush traffic saving.  ``dedup=False`` concatenates
        verbatim (the D=1 path: a single batch flushes exactly as the
        eager apply would, which is what makes D=1 bit-identical)."""
        if not self._pending_idx:
            return (np.zeros((0,), np.int32),
                    np.zeros((0, self.dim), np.float32))
        idx = np.concatenate(self._pending_idx)
        upd = np.concatenate(self._pending_upd)
        self.clear_pending()
        if not dedup:
            return idx, upd
        uniq, inv = np.unique(idx, return_inverse=True)
        if np.issubdtype(upd.dtype, np.integer):
            acc = np.zeros((uniq.shape[0], upd.shape[1]), np.int64)
            np.add.at(acc, inv, upd.astype(np.int64))
            acc = acc.astype(upd.dtype)
        else:
            acc = np.zeros((uniq.shape[0], upd.shape[1]), upd.dtype)
            np.add.at(acc, inv, upd)
        return uniq.astype(np.int32), acc

    def pending_arrays(self) -> tuple:
        """Ledger contents for checkpointing (concatenated, not popped)."""
        if not self._pending_idx:
            return (np.zeros((0,), np.int32),
                    np.zeros((0, self.dim), np.float32))
        return (np.concatenate(self._pending_idx),
                np.concatenate(self._pending_upd))

    def restore_pending(self, idx, upd, batches: int = 0) -> None:
        """Restore a checkpointed ledger (inverse of pending_arrays)."""
        self.clear_pending()
        idx = np.asarray(idx, np.int32)
        if idx.size:
            self._pending_idx.append(idx)
            self._pending_upd.append(np.asarray(upd))
        self.pending_batches = int(batches)

    def clear_pending(self) -> None:
        self._pending_idx = []
        self._pending_upd = []
        self.pending_batches = 0

    def __repr__(self) -> str:
        return (f"ShardedTable({self.n_rows}x{self.dim}, "
                f"{self.placement!r}, shards={self.n_shards}, "
                f"views={self.n_views})")
