"""Bank-resident dataset handles (DESIGN.md §3.2).

A :class:`PimDataset` is created by ``PimSystem.put(X, y)`` and owns

  * the host-side arrays (for centroid init / host-side prediction),
  * the padded row-validity mask, and
  * per-version quantized, sharded device views — lazily materialized
    and cached, so repeated ``fit``s, ``n_init`` restarts, and
    hyperparameter sweeps reuse ONE CPU->PIM transfer per view and the
    ``TransferStats`` counters stop double-counting the partition.

This mirrors the paper's execution model exactly: the training set is
partitioned across the DRAM banks once and never moves again; only model
state (weights / centroids / split commands) crosses the host<->PIM
boundary per iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from ..core.fixed_point import to_fixed
# 12-bit symmetric range stored in int16 — keeps int32 distance and
# coordinate-sum accumulations exact on TPU; single source of truth in
# core/kmeans.py (see its docstring for the derivation)
from ..core.kmeans import QUANT_RANGE as KMEANS_QUANT_RANGE

#: data-precision families; LIN/LOG versions map onto one of these, so
#: e.g. the "hyb" and "bui" versions share a single cached view.
GD_DATA_VERSIONS = ("fp32", "int32", "hyb")

_GD_DATA_VERSION = {
    "fp32": "fp32", "int32": "int32", "hyb": "hyb", "bui": "hyb",
    "int32_lut_mram": "int32", "int32_lut_wram": "int32",
    "hyb_lut": "hyb", "bui_lut": "hyb",
}


def gd_data_version(version: str) -> str:
    """Collapse a LIN/LOG version name to its on-bank data precision."""
    try:
        return _GD_DATA_VERSION[version]
    except KeyError:
        raise ValueError(f"unknown workload version {version!r}") from None


@dataclasses.dataclass(frozen=True)
class KMeansView:
    """Quantized K-Means view: device shards + host copy for init."""

    shards: jnp.ndarray      # (n_cores, n_pc, F) int16
    mask: jnp.ndarray        # (n_cores, n_pc) bool
    host_q: np.ndarray       # (n, F) int16 — centroid init draws from it
    scale: np.float32        # dequantization scale


class PimDataset:
    """Handle to a dataset partitioned once across the PIM banks."""

    def __init__(self, system, X, y=None):
        X = np.asarray(X)
        if X.ndim == 1:
            X = X[:, None]
        self.system = system
        self.X = X
        self.y = None if y is None else np.asarray(y)
        self.n = int(X.shape[0])
        self.n_features = int(X.shape[1])
        self._views: dict[tuple, Any] = {}

    # -- caching core --------------------------------------------------------

    def _cached(self, key: tuple, builder):
        view = self._views.get(key)
        if view is None:
            from ..obs.trace import TRACER   # local: api -> obs, no cycle
            if TRACER.enabled:
                track = getattr(self.system, "_trace_track", "system:?")
                with TRACER.span(f"shard:{key[0]}", track, "transfer"):
                    view = builder()
            else:
                view = builder()
            self._views[key] = view
        return view

    @property
    def n_views(self) -> int:
        """Number of materialized (transferred) views — diagnostics."""
        return sum(1 for k in self._views if k[0] != "mask")

    def _require_y(self, who: str) -> np.ndarray:
        if self.y is None:
            raise ValueError(
                f"{who} needs labels/targets; create the dataset with "
                f"PimSystem.put(X, y)")
        return self.y

    # -- views ---------------------------------------------------------------

    def mask(self, dtype=None) -> jnp.ndarray:
        """Row-validity mask, optionally cast (cached per dtype)."""
        key = ("mask", None if dtype is None else jnp.dtype(dtype).name)
        return self._cached(key, lambda: (
            self.system.row_validity_mask(self.n) if dtype is None
            else self.system.row_validity_mask(self.n).astype(dtype)))

    def gd_view(self, version: str, frac_bits: int = 10, x8_frac: int = 7):
        """(Xs, ys, mask) for the gradient-descent workloads (LIN/LOG).

        ``version`` may be any LIN/LOG version name; it is collapsed to
        the data precision family, so HYB and BUI (same datatypes, paper
        §3.1) share one transfer, as do the LUT placement variants.
        """
        y = self._require_y("gd_view")
        data_ver = gd_data_version(version)

        if data_ver == "fp32":
            key = ("gd", "fp32")

            def build():
                return (self.system.shard_rows(self.X.astype(np.float32)),
                        self.system.shard_rows(y.astype(np.float32)),
                        self.mask(jnp.float32))
        elif data_ver == "int32":
            key = ("gd", "int32", frac_bits)

            def build():
                Xq = np.asarray(to_fixed(self.X, frac_bits))
                yq = np.asarray(to_fixed(y, frac_bits))
                return (self.system.shard_rows(Xq),
                        self.system.shard_rows(yq),
                        self.mask(jnp.int32))
        else:  # hyb: int8 inputs, fixed-point targets at frac_bits
            key = ("gd", "hyb", x8_frac, frac_bits)

            def build():
                Xq8 = np.asarray(to_fixed(self.X, x8_frac, dtype=jnp.int8))
                yq = np.asarray(to_fixed(y, frac_bits))
                return (self.system.shard_rows(Xq8),
                        self.system.shard_rows(yq),
                        self.mask(jnp.int32))
        return self._cached(key, build)

    def tree_view(self):
        """(Xs, ys, mask) for the decision-tree workload (float32/int32)."""
        y = self._require_y("tree_view")

        def build():
            return (self.system.shard_rows(self.X.astype(np.float32)),
                    self.system.shard_rows(y.astype(np.int32)),
                    self.mask())
        return self._cached(("tree",), build)

    def emb_view(self) -> tuple:
        """(pairs, targets) for the EMB workload: host-side ``(n, 2)``
        int32 (user, item) index pairs plus float32 ratings.

        EMB keeps the *dataset* host-side by design — per-step
        minibatches of index pairs broadcast to the banks, while the
        sharded state is the embedding TABLE (a :class:`ShardedTable`
        from ``System.put_table``), inverting the usual data/model
        placement (DESIGN.md §15.1)."""
        y = self._require_y("emb_view")
        if self.n_features != 2:
            raise ValueError(
                f"emb_view needs (n, 2) (user, item) index pairs, got "
                f"{self.n_features} columns")
        X = np.asarray(self.X)
        if not np.issubdtype(X.dtype, np.integer):
            if not np.all(X == np.round(X)):
                raise ValueError("emb_view indices must be integral")
        Xi = X.astype(np.int32)
        if Xi.size and Xi.min() < 0:
            raise ValueError("emb_view indices must be non-negative")
        return self._cached(("emb",), lambda: (Xi, y.astype(np.float32)))

    def kmeans_view(self, version: str = "int16") -> KMeansView:
        """K-Means data view, cached per precision.

        ``"int16"``: symmetric quantization to +-KMEANS_QUANT_RANGE
        (the paper's PIM version).  ``"fp32"``: un-quantized float32 —
        the processor-centric baseline precision (scale 1.0, no
        quantization round-trip; DESIGN.md §10.3)."""
        if version == "fp32":
            def build():
                Xf = np.asarray(self.X, np.float32)
                return KMeansView(shards=self.system.shard_rows(Xf),
                                  mask=self.mask(),
                                  host_q=Xf,
                                  scale=np.float32(1.0))
            return self._cached(("kmeans", "fp32"), build)
        if version != "int16":
            raise ValueError(f"unknown kmeans view precision {version!r}; "
                             f"known: ('int16', 'fp32')")

        def build():
            X = np.asarray(self.X, np.float32)
            amax = float(np.abs(X).max())
            scale = max(amax, 1e-12) / KMEANS_QUANT_RANGE
            Xq = np.clip(np.round(X / scale),
                         -KMEANS_QUANT_RANGE, KMEANS_QUANT_RANGE)
            Xq = Xq.astype(np.int16)
            return KMeansView(shards=self.system.shard_rows(Xq),
                              mask=self.mask(),
                              host_q=Xq,
                              scale=np.float32(scale))
        return self._cached(("kmeans", "int16"), build)


def as_dataset(X, y, system) -> PimDataset:
    """Coerce (X, y) to a PimDataset on ``system``.

    Passing an existing PimDataset through is the sweep fast path; raw
    arrays get an ephemeral handle (one transfer, same as the old API).
    """
    if isinstance(X, PimDataset):
        return X
    return PimDataset(system, X, y)
