"""Job-queue walkthrough: the multi-tenant PIM training service.

Shows the full scheduler surface (DESIGN.md §7): rank-aligned bank
allocation, a mixed LIN/LOG/KME queue gang-stepped concurrently, failure
isolation, per-job transfer accounting, priorities, and a fused
learning-rate sweep that advances 4 jobs with one batched kernel launch
per step.

  PYTHONPATH=src python examples/job_queue.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.api import PimConfig, PimSystem
from repro.data.synthetic import make_blobs, make_linear_dataset
from repro.sched import JobState, PimScheduler


def show(handles, title):
    print(f"\n{title}")
    for h in handles:
        extra = ""
        if h.error is not None:
            extra = f"  !! {type(h.error).__name__}: {h.error}"
        elif h.transfer is not None:
            extra = (f"  launches={h.transfer.kernel_launches}"
                     f" cpu->pim={h.transfer.cpu_to_pim:,}B"
                     f" dpu={h.modeled_seconds:.2e}s")
        print(f"  {h.name[:34]:34s} {h.state.value:10s} "
              f"cores={h.n_cores:<3d} steps={h.steps:<4d}{extra}")


def main():
    print("=== PIM job scheduler walkthrough (DESIGN.md §7) ===")
    # A 32-core machine carved into ranks of 4 (UPMEM hands out ranks
    # of 64 DPUs; the default rank_size=64 clamps to the machine).
    system = PimSystem(PimConfig(n_cores=32))
    sched = PimScheduler(system, rank_size=4)

    X, y, _ = make_linear_dataset(2048, 16, seed=0)
    Xb, _, _ = make_blobs(4096, 8, centers=8, seed=1)

    # -- 1. a mixed queue: LIN + LOG + KME, one job designed to fail ----------
    handles = [
        sched.submit("linreg", (X, y), version="int32", n_iters=60,
                     n_cores=8),
        sched.submit("logreg", (X, y), version="int32_lut_wram",
                     n_iters=60, n_cores=8, priority=2),
        sched.submit("kmeans", Xb, n_clusters=8, max_iter=30, n_cores=8),
        # more clusters than points: raises inside fit — the scheduler
        # isolates it and the rest of the queue drains normally
        sched.submit("kmeans", Xb[:4], n_clusters=8, name="poison"),
    ]
    sched.step()     # one scheduling turn: everything fits, all admitted
    frag = sched.fragmentation()
    print(f"\nafter one turn: {frag.used_cores}/{frag.total_cores} cores "
          f"leased in {frag.n_leases} slices "
          f"(frag={frag.external_fragmentation:.2f})")
    sched.drain()
    show(handles, "mixed queue (note the isolated failure):")

    # -- 2. fused sweep: 4 learning rates, ONE kernel launch per step ---------
    snap = system.stats.snapshot()
    t0 = time.perf_counter()
    fused = sched.sweep("linreg", (X, y), {"lr": [0.05, 0.1, 0.2, 0.4]},
                        version="hyb", n_iters=60, n_cores=8, fused=True)
    sched.drain()
    dt = time.perf_counter() - t0
    show(fused, f"fused 4-point lr sweep ({dt:.2f}s wall):")
    d = system.stats.delta(snap)
    print(f"  whole gang: {d.kernel_launches} kernel launches for "
          f"4 jobs x 60 steps (1 batched launch/step), "
          f"{d.shard_transfers} shard transfers (one resident dataset)")

    # -- 3. results are real fits -------------------------------------------
    best = max((h for h in fused if h.state is JobState.DONE),
               key=lambda h: -np.mean(
                   (X @ h.result.attributes["coef_"]
                    + h.result.attributes["intercept_"] - y) ** 2))
    print(f"\nbest sweep point: {best.name} "
          f"(lr={best.spec.params['lr']}), "
          f"w[:3]={np.round(best.result.attributes['coef_'][:3], 3)}")
    print(f"scheduler totals: {sched.stats()['jobs']}")


if __name__ == "__main__":
    main()
