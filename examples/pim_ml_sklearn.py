"""The paper's scikit-learn estimator interface (§4) in action.

  PYTHONPATH=src python examples/pim_ml_sklearn.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.estimators import (PimDecisionTreeClassifier, PimKMeans,
                                   PimLinearRegression,
                                   PimLogisticRegression)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)


def main():
    X, y, _ = make_linear_dataset(4096, 16, task="regression", seed=0)
    reg = PimLinearRegression(version="bui", n_iters=400).fit(X, y)
    print(f"PimLinearRegression(bui)        R^2 = {reg.score(X, y):.4f}")

    Xc, yc, _ = make_linear_dataset(4096, 16, seed=1)
    clf = PimLogisticRegression(version="bui_lut", n_iters=400).fit(Xc, yc)
    print(f"PimLogisticRegression(bui_lut)  acc = {clf.score(Xc, yc):.4f}")
    print(f"  predict_proba[:2] = {np.round(clf.predict_proba(Xc[:2]), 3)}")

    Xt, yt = make_classification(20_000, 16, seed=2, class_sep=1.5)
    tree = PimDecisionTreeClassifier(max_depth=8).fit(Xt, yt)
    print(f"PimDecisionTreeClassifier       acc = {tree.score(Xt, yt):.4f}")

    Xb, _, _ = make_blobs(10_000, 8, centers=8, seed=3)
    km = PimKMeans(n_clusters=8, n_init=2).fit(Xb)
    print(f"PimKMeans                       inertia = {km.inertia_:.3e}, "
          f"centers {km.cluster_centers_.shape}")


if __name__ == "__main__":
    main()
