"""The paper's scikit-learn estimator interface (§4) in action.

Both construction paths are shown: the workload registry
(``make_estimator``) and the legacy class names, which are now thin
shims over the same registry.

  PYTHONPATH=src python examples/pim_ml_sklearn.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.api import make_estimator
from repro.core.estimators import PimDecisionTreeClassifier, PimKMeans
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)


def main():
    X, y, _ = make_linear_dataset(4096, 16, task="regression", seed=0)
    reg = make_estimator("linreg", version="bui", n_iters=400).fit(X, y)
    print(f"make_estimator('linreg', 'bui')  R^2 = {reg.score(X, y):.4f}")
    print(f"  get_params = {reg.get_params()}")

    Xc, yc, _ = make_linear_dataset(4096, 16, seed=1)
    clf = make_estimator("logreg", version="bui_lut",
                         n_iters=400).fit(Xc, yc)
    print(f"make_estimator('logreg','bui_lut') acc = {clf.score(Xc, yc):.4f}")
    print(f"  predict_proba[:2] = {np.round(clf.predict_proba(Xc[:2]), 3)}")

    # the legacy class names still work (thin shims over the registry)
    Xt, yt = make_classification(20_000, 16, seed=2, class_sep=1.5)
    tree = PimDecisionTreeClassifier(max_depth=8).fit(Xt, yt)
    print(f"PimDecisionTreeClassifier        acc = {tree.score(Xt, yt):.4f}")

    Xb, _, _ = make_blobs(10_000, 8, centers=8, seed=3)
    km = PimKMeans(n_clusters=8, n_init=2).fit(Xb)
    print(f"PimKMeans                        inertia = {km.inertia_:.3e}, "
          f"centers {km.cluster_centers_.shape}")


if __name__ == "__main__":
    main()
