"""The paper's scikit-learn estimator interface (§4) in action.

Four construction paths are shown: the workload registry
(``make_estimator``), the backend-portable ``system=`` parameter (the
same estimator on the host-CPU baseline target — DESIGN.md §10), the
legacy class names (deprecation shims over the same registry), and the
job scheduler's sweep surface — the multi-tenant way to fit a
hyperparameter grid (DESIGN.md §7).

  PYTHONPATH=src python examples/pim_ml_sklearn.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.api import PimConfig, PimSystem, make_estimator, make_system
from repro.core.estimators import PimDecisionTreeClassifier, PimKMeans
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)
from repro.sched import PimScheduler


def main():
    X, y, _ = make_linear_dataset(4096, 16, task="regression", seed=0)
    reg = make_estimator("linreg", version="bui", n_iters=400).fit(X, y)
    print(f"make_estimator('linreg', 'bui')  R^2 = {reg.score(X, y):.4f}")
    print(f"  get_params = {reg.get_params()}")

    # the same estimator on the processor-centric baseline target: pass
    # any System via system= and the fit runs there unmodified
    cpu = make_estimator("linreg", version="fp32", n_iters=400,
                         system=make_system("host")).fit(X, y)
    print(f"  ... on HostSystem (fp32 CPU baseline) R^2 = "
          f"{cpu.score(X, y):.4f}, DRAM streamed "
          f"{cpu.system.stats.dram_bytes:,} B")

    Xc, yc, _ = make_linear_dataset(4096, 16, seed=1)
    clf = make_estimator("logreg", version="bui_lut",
                         n_iters=400).fit(Xc, yc)
    print(f"make_estimator('logreg','bui_lut') acc = {clf.score(Xc, yc):.4f}")
    print(f"  predict_proba[:2] = {np.round(clf.predict_proba(Xc[:2]), 3)}")

    # the legacy class names still work (thin shims over the registry)
    Xt, yt = make_classification(20_000, 16, seed=2, class_sep=1.5)
    tree = PimDecisionTreeClassifier(max_depth=8).fit(Xt, yt)
    print(f"PimDecisionTreeClassifier        acc = {tree.score(Xt, yt):.4f}")

    Xb, _, _ = make_blobs(10_000, 8, centers=8, seed=3)
    km = PimKMeans(n_clusters=8, n_init=2).fit(Xb)
    print(f"PimKMeans                        inertia = {km.inertia_:.3e}, "
          f"centers {km.cluster_centers_.shape}")

    # single fits above; the scheduler fits a whole grid concurrently —
    # the GD points fuse into one batched kernel launch per step
    sched = PimScheduler(PimSystem(PimConfig(n_cores=16)), rank_size=4)
    handles = sched.sweep("linreg", (X, y), {"lr": [0.05, 0.1, 0.2]},
                          version="bui", n_iters=400, n_cores=8)
    sched.drain()
    from repro.api import get_workload
    lin = get_workload("linreg")
    for h in handles:
        print(f"sched.sweep('linreg','bui') lr={h.spec.params['lr']:<5}"
              f" R^2 = {lin.score(h.result, X, y):.4f}  "
              f"[{h.state.value}, fused={h.fused}]")


if __name__ == "__main__":
    main()
