"""Quickstart: the paper's four ML workloads on the PIM system model.

Trains LIN / LOG / DTR / KME with the paper's quantized versions and
prints quality next to the float CPU baselines — the 60-second tour of
the reproduction.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import dtree, kmeans, linreg, logreg
from repro.core.metrics import (accuracy, adjusted_rand_index,
                                training_error_rate)
from repro.core.pim import PimConfig, PimSystem, ReduceVia
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)


def main():
    print("=== PIM-ML quickstart (paper: Gomez-Luna et al., 2022) ===\n")
    pim = PimSystem(PimConfig(n_cores=16))

    # -- linear regression (paper §3.1, Fig. 6) ------------------------------
    X, y, _ = make_linear_dataset(8192, 16, decimals=4, seed=0)
    print("LIN (8192x16 synthetic, 500 iters)")
    cpu = linreg.train_cpu_baseline(X, y)
    print(f"  CPU float32      : {training_error_rate(cpu.predict(X), y):.2f}% err")
    for ver in linreg.VERSIONS:
        r = linreg.train(X, y, pim, linreg.GdConfig(version=ver))
        print(f"  PIM {ver:6s}       : "
              f"{training_error_rate(r.predict(X), y):.2f}% err")

    # -- logistic regression (paper §3.2, Fig. 7) -----------------------------
    print("\nLOG (same dataset; LUT sigmoid vs Taylor)")
    cpu = logreg.train_cpu_baseline(X, y)
    print(f"  CPU float32      : "
          f"{training_error_rate(cpu.predict(X), y, 0.0):.2f}% err")
    for ver in ("int32", "int32_lut_wram", "bui_lut"):
        r = logreg.train(X, y, pim, logreg.LogRegConfig(version=ver))
        print(f"  PIM {ver:15s}: "
              f"{training_error_rate(r.predict(X), y, 0.0):.2f}% err")

    # -- decision tree (paper §3.3) -------------------------------------------
    print("\nDTR (60k x 16, depth 10, extremely randomized)")
    Xc, yc = make_classification(60_000, 16, seed=0, class_sep=1.4)
    tree = dtree.train(Xc, yc, pim, dtree.TreeConfig(max_depth=10))
    tcpu = dtree.train_cpu_baseline(Xc, yc, dtree.TreeConfig(max_depth=10))
    print(f"  PIM accuracy     : {accuracy(tree.predict(Xc), yc):.4f} "
          f"({tree.n_nodes} nodes)")
    print(f"  CPU accuracy     : {accuracy(tcpu.predict(Xc), yc):.4f}")

    # -- k-means (paper §3.4) --------------------------------------------------
    print("\nKME (20k x 16, k=16, int16-quantized PIM vs float CPU)")
    Xb, _, _ = make_blobs(20_000, 16, centers=16, seed=0)
    cfg = kmeans.KMeansConfig(k=16, seed=3, n_init=2)
    rp = kmeans.train(Xb, pim, cfg)
    rc = kmeans.train_cpu_baseline(Xb, cfg)
    print(f"  adjusted Rand index(PIM, CPU) = "
          f"{adjusted_rand_index(rp.labels, rc.labels):.4f} "
          f"(paper: 0.999)")

    # -- the PIM execution model is real: host-reduce mode ---------------------
    print("\nHost-orchestrated reduce (the paper's DPU topology):")
    pim_host = PimSystem(PimConfig(n_cores=16, reduce=ReduceVia.HOST))
    r = linreg.train(X, y, pim_host, linreg.GdConfig(version="int32",
                                                     n_iters=100))
    print(f"  int32 via host round trip: "
          f"{training_error_rate(r.predict(X), y):.2f}% err;"
          f" bytes host->PIM {pim_host.stats.cpu_to_pim:,},"
          f" PIM->host {pim_host.stats.pim_to_cpu:,}")


if __name__ == "__main__":
    main()
