"""Quickstart: the paper's four ML workloads through the session API.

One PimSystem session, one bank-resident PimDataset per training set,
every version trained through the workload registry — the 60-second tour
of the reproduction.  Every CPU baseline below is the SAME workload
fitted on a ``HostSystem`` (the processor-centric ``System`` target,
DESIGN.md §10) — there is no separate baseline code path anymore.
(Background on the execution model, dataset lifecycle, and reduction
strategies: DESIGN.md §2-§3; the three-way PIM/host/modeled-GPU
comparison: `python -m repro.launch.compare --tiny`.)

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.api import (PimConfig, PimSystem, get_workload, make_estimator,
                       make_system)
from repro.core.metrics import (accuracy, adjusted_rand_index,
                                training_error_rate)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)


def main():
    print("=== PIM-ML quickstart (paper: Gomez-Luna et al., 2022) ===\n")
    pim = PimSystem(PimConfig(n_cores=16))
    host = make_system("host")     # the processor-centric CPU baseline

    # -- linear regression (paper §3.1, Fig. 6) ------------------------------
    # The dataset is partitioned across the banks ONCE; the four-version
    # sweep reuses the resident shards (one transfer per data precision).
    X, y, _ = make_linear_dataset(8192, 16, decimals=4, seed=0)
    ds = pim.put(X, y)
    hds = host.put(X, y)
    print("LIN (8192x16 synthetic, 500 iters)")
    cpu = make_estimator("linreg", version="fp32", system=host).fit(hds)
    print(f"  CPU float32      : {training_error_rate(cpu.predict(X), y):.2f}% err")
    for ver in get_workload("linreg").versions:
        est = make_estimator("linreg", version=ver, system=pim).fit(ds)
        print(f"  PIM {ver:6s}       : "
              f"{training_error_rate(est.predict(X), y):.2f}% err")
    print(f"  shard transfers for all 4 versions: "
          f"{pim.stats.shard_transfers} (3 data precisions x (X, y) + mask"
          f" reuse)")

    # -- logistic regression (paper §3.2, Fig. 7) -----------------------------
    # Same PimDataset: LOG shares LIN's precision views, so no new
    # CPU->PIM transfer happens here at all.  On the host target, fp32
    # automatically uses the exact sigmoid (native transcendentals),
    # exactly as the paper's MKL baseline does.
    print("\nLOG (same resident dataset; LUT sigmoid vs Taylor)")
    cpu = make_estimator("logreg", version="fp32", system=host).fit(hds)
    print(f"  CPU float32      : "
          f"{training_error_rate(cpu.decision_function(X), y, 0.0):.2f}% err")
    for ver in ("int32", "int32_lut_wram", "bui_lut"):
        est = make_estimator("logreg", version=ver, system=pim).fit(ds)
        print(f"  PIM {ver:15s}: "
              f"{training_error_rate(est.decision_function(X), y, 0.0):.2f}% err")

    # -- decision tree (paper §3.3) -------------------------------------------
    print("\nDTR (60k x 16, depth 10, extremely randomized)")
    Xc, yc = make_classification(60_000, 16, seed=0, class_sep=1.4)
    tree = make_estimator("dtree", max_depth=10, system=pim).fit(Xc, yc)
    tcpu = make_estimator("dtree", max_depth=10,
                          system=make_system("host")).fit(Xc, yc)
    print(f"  PIM accuracy     : {accuracy(tree.predict(Xc), yc):.4f} "
          f"({tree.n_nodes_} nodes)")
    print(f"  CPU accuracy     : {accuracy(tcpu.predict(Xc), yc):.4f}")

    # -- k-means (paper §3.4) --------------------------------------------------
    # int16 = the paper's quantized PIM version; the float baseline is
    # version="fp32" on the host target — same trainer, no quantization.
    print("\nKME (20k x 16, k=16, int16-quantized PIM vs float CPU)")
    Xb, _, _ = make_blobs(20_000, 16, centers=16, seed=0)
    km = make_estimator("kmeans", n_clusters=16, seed=3, n_init=2,
                        system=pim).fit(Xb)
    rc = make_estimator("kmeans", version="fp32", n_clusters=16, seed=3,
                        n_init=2, system=make_system("host")).fit(Xb)
    print(f"  adjusted Rand index(PIM, CPU) = "
          f"{adjusted_rand_index(km.labels_, rc.labels_):.4f} "
          f"(paper: 0.999)")

    # -- the PIM execution model is real: host-reduce strategy ----------------
    print("\nHost-orchestrated reduce (the paper's DPU topology):")
    pim_host = PimSystem(PimConfig(n_cores=16, reduce="host"))
    est = make_estimator("linreg", version="int32", n_iters=100,
                         system=pim_host).fit(pim_host.put(X, y))
    print(f"  int32 via host round trip: "
          f"{training_error_rate(est.predict(X), y):.2f}% err;"
          f" bytes host->PIM {pim_host.stats.cpu_to_pim:,},"
          f" PIM->host {pim_host.stats.pim_to_cpu:,}")

    # -- the same sweep through the job scheduler (DESIGN.md §7) --------------
    # Above, the lr sweep ran serially on the whole mesh.  The scheduler
    # carves the cores axis into rank slices and — because the sweep
    # points differ only in lr — FUSES them into one gang: one batched
    # kernel launch advances every point one GD step.
    print("\nScheduled fused sweep (1 batched launch/step for 3 jobs):")
    from repro.sched import PimScheduler
    system = PimSystem(PimConfig(n_cores=16))
    sched = PimScheduler(system, rank_size=4)
    snap = system.stats.snapshot()
    handles = sched.sweep("linreg", (X, y), {"lr": (0.05, 0.1, 0.2)},
                          version="int32", n_iters=500, n_cores=8,
                          fused=True)
    sched.drain()
    for h in handles:
        w, b = h.result.attributes["coef_"], h.result.attributes["intercept_"]
        print(f"  lr={h.spec.params['lr']:<5}: "
              f"{training_error_rate(X @ w + b, y):.2f}% err "
              f"({h.state.value}, {h.steps} steps)")
    d = system.stats.delta(snap)
    print(f"  gang total: {d.kernel_launches} launches for "
          f"{len(handles)} jobs x 500 steps; "
          f"{d.shard_transfers} shard transfers (one resident dataset)")

    # -- mixed PIM + host machine under one scheduler (DESIGN.md §10.3) -------
    print("\nMixed-target queue (PIM tenants + a host-lane baseline):")
    mixed = PimScheduler({"pim": PimSystem(PimConfig(n_cores=16)),
                          "host": make_system("host", n_cores=4)},
                         rank_size=8)
    h_pim = mixed.submit("linreg", (X, y), version="int32", n_iters=120)
    h_cpu = mixed.submit("linreg", (X, y), version="fp32", n_iters=120,
                         target="host")
    mixed.drain()
    for h in (h_pim, h_cpu):
        print(f"  {h.target:4s} {h.spec.version:6s}: {h.state.value}, "
              f"dram {h.transfer.dram_bytes:,} B, "
              f"cpu->pim {h.transfer.cpu_to_pim:,} B")


if __name__ == "__main__":
    main()
