"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the granite family at a ~100M reduced width with the paper's
techniques switched on (int8 quantized linears + LUT activations), a
Markov corpus with a known entropy floor, checkpoint/resume, and straggler
monitoring — the full training stack on CPU.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.data.tokens import MarkovCorpus
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: 12 layers x 768 wide on the granite (dense GQA) family
    params, losses, corpus = train(
        "granite-3-8b", steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=True, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        lr=3e-3, quantize_dense=False, lut_activations=False,
        microbatches=2,
        overrides=dict(d_model=768, n_layers=12, d_ff=2048,
                       vocab_size=8192, n_heads=12, n_kv_heads=4,
                       head_dim=64))
    floor = corpus.entropy_bound()
    print(f"\nfinal loss {losses[-1]:.3f} "
          f"(corpus entropy floor {floor:.3f}, "
          f"uniform would be {np.log(corpus.vocab):.3f})")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
