"""Batched serving demo: slot-based continuous batching over a reduced LM.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.api import Model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=4, max_seq=96)

    rng = np.random.RandomState(0)
    requests = [
        Request(prompt=rng.randint(0, cfg.vocab_size, 12).astype(np.int32),
                max_new_tokens=16 + 4 * i)
        for i in range(8)
    ]
    t0 = time.perf_counter()
    done = engine.run(requests)
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: {len(r.output)} tokens -> {r.output[:10]}...")
    assert all(r.done for r in done)


if __name__ == "__main__":
    main()
