"""Fig. 13-17 reproduction walkthrough: one workload, three machines.

The paper's headline result is not a kernel but a COMPARISON: the same
four training workloads ran on a real PIM system, a Xeon CPU, and an
A100-class GPU, and the takeaways (Figs. 13-17, Tables 5-7) are about
when the memory-centric machine wins.  This walkthrough shows how the
repo makes that comparison one API call per target (DESIGN.md §10):

  1. build a Workload spec once,
  2. fit it on  make_system("pim") / ("host") / ("gpu-model"),
  3. read each target's native report — DPU cost-model seconds and
     CPU<->PIM transfer bytes on PIM, measured wall + DRAM traffic on
     the host, A100-roofline time/energy on the modeled GPU.

The full table (all four workloads, JSON record under benchmarks/out/)
is `python -m repro.launch.compare --tiny`  /  `make compare`.

  PYTHONPATH=src python examples/compare_systems.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.api import HierarchicalCostModel, get_workload, make_system
from repro.data.synthetic import make_linear_dataset


def main():
    n, f, iters = 8192, 16, 200
    X, y, _ = make_linear_dataset(n, f, seed=0)
    wl = get_workload("linreg")

    # -- 1. PIM: the paper's INT32 fixed-point version ------------------------
    pim = make_system("pim", n_cores=16)
    spec = wl.spec("int32", n_iters=iters)
    result = wl.fit(pim.put(X, y), spec)
    model = HierarchicalCostModel(pim.topology)
    dpu_s = iters * model.step_seconds(
        "lin", "int32", n, f, n_cores=pim.config.n_cores,
        n_threads=pim.config.n_threads)
    print(f"pim       int32  R^2={wl.score(result, X, y):.4f}  "
          f"modeled DPU {dpu_s * 1e3:.2f} ms (kernel + rank legs)  "
          f"cpu->pim {pim.stats.cpu_to_pim:,} B, "
          f"pim->cpu {pim.stats.pim_to_cpu:,} B "
          f"({pim.stats.kernel_launches} launches)")

    # -- 2. host: the processor-centric fp32 baseline -------------------------
    # No sharding, no quantization round-trip; TransferStats counts the
    # DRAM bytes the hot loop streams instead of CPU<->PIM transfers.
    host = make_system("host")
    hspec = wl.spec("fp32", n_iters=iters)
    ds = host.put(X, y)
    wl.fit(ds, hspec)                      # warm (compile)
    t0 = time.perf_counter()
    result = wl.fit(ds, hspec)
    wall = time.perf_counter() - t0
    print(f"host      fp32   R^2={wl.score(result, X, y):.4f}  "
          f"measured {wall * 1e3:.2f} ms  "
          f"DRAM {host.stats.dram_bytes:,} B "
          f"(cpu->pim stays {host.stats.cpu_to_pim})")

    # -- 3. modeled GPU: same numerics, A100 roofline report ------------------
    gpu = make_system("gpu-model")
    result = wl.fit(gpu.put(X, y), hspec)
    g = gpu.gpu
    print(f"gpu-model fp32   R^2={wl.score(result, X, y):.4f}  "
          f"roofline {g.modeled_seconds * 1e3:.2f} ms / "
          f"{g.modeled_energy_j:.2f} J  "
          f"({g.flops:.2e} FLOPs, {g.launches} launches "
          f"x 5us launch overhead)")

    # -- the step-fusion lever works on the GPU model too ---------------------
    # Launch overhead dominates small iterative fits (why the paper's
    # GPU loses to PIM on LOG/KME): fusing k steps into one launch
    # shrinks exactly that term — on every target.
    gpu2 = make_system("gpu-model")
    wl.fit(gpu2.put(X, y), wl.spec("fp32", n_iters=iters, fuse_steps=32))
    print(f"gpu-model fp32 fuse_steps=32: roofline "
          f"{gpu2.gpu.modeled_seconds * 1e3:.2f} ms over "
          f"{gpu2.gpu.launches} launches — the dispatch tax the paper "
          f"measures is gone")


if __name__ == "__main__":
    main()
