"""EMB walkthrough: bank-sharded embedding tables + deferred updates.

The repo's first sparse workload (DESIGN.md §15): a dot-product
embedding model over Zipf-skewed (user, item, rating) triples, with the
embedding TABLES row-sharded across the PIM banks (``System.put_table``
-> ShardedTable) and the LazyDP-style deferred-update schedule — sparse
gradients stage host-side and flush every D batches as one deduplicated
scatter-add.  The demo shows:

  1. eager vs deferred training: same quality, a fraction of the
     sparse-update traffic (``TransferStats.flush_bytes``);
  2. the D=1 identity: a one-batch window is bit-identical to eager;
  3. the int32 fixed-point version next to the fp32 baseline;
  4. an int8 + error-feedback compressed flush (``compress_flush``).

  PYTHONPATH=src python examples/emb_recsys.py
  make emb    # the traffic/quality sweep (benchmarks/emb_bench.py)
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.api import make_estimator, make_system
from repro.data.synthetic import make_recsys
from repro.emb import EmbConfig, fit


def main():
    print("=== EMB: embedding training on bank-sharded tables ===\n")
    X, y = make_recsys(8192, n_users=256, n_items=192, dim=8,
                       zipf_a=1.2, seed=0)
    print(f"recsys stream: {len(X)} (user, item, rating) triples, "
          f"vocab 256x192, Zipf-skewed ids\n")

    common = dict(n_iters=160, batch=256, dim=8, lr=1.0, frac_bits=12,
                  seed=1, record_every=160)

    print("eager vs deferred (int32/Q12, 16 cores):")
    for label, D in (("eager (D=1)", 1), ("deferred D=8", 8)):
        pim = make_system("pim", n_cores=16)
        res = fit(pim.put(X, y), EmbConfig(version="int32",
                                           flush_every=D, **common))
        print(f"  {label:14s}: final MSE {res.history[-1][1]:.5f}, "
              f"flush traffic {pim.stats.flush_bytes / 1024:.0f} KiB "
              f"({res.n_flushes} flushes)")

    print("\nthe D=1 identity (staged-and-flushed == eager, bitwise):")
    outs = []
    for deferred in (False, True):
        pim = make_system("pim", n_cores=16)
        outs.append(fit(pim.put(X, y),
                        EmbConfig(version="int32", flush_every=1,
                                  deferred=deferred, **common)))
    same = np.array_equal(outs[0].user_raw, outs[1].user_raw) \
        and np.array_equal(outs[0].item_raw, outs[1].item_raw)
    print(f"  tables bit-identical: {same}")

    print("\ncompressed flush (int8 rows + error feedback):")
    pim = make_system("pim", n_cores=16)
    res = fit(pim.put(X, y), EmbConfig(version="int32", flush_every=8,
                                       compress_flush=True, **common))
    print(f"  final MSE {res.history[-1][1]:.5f}, wire "
          f"{pim.stats.compressed_bytes / 1024:.0f} KiB vs logical "
          f"{pim.stats.flush_bytes / 1024:.0f} KiB")

    print("\nthe registry surface (same estimator API as LIN/LOG/KME):")
    for ver in ("fp32", "int32"):
        est = make_estimator("emb", version=ver, flush_every=8, **common)
        est.fit(make_system("pim", n_cores=16).put(X, y))
        print(f"  emb/{ver:5s}: R^2 = {est.score(X, y):.4f}")


if __name__ == "__main__":
    main()
