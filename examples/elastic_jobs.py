"""Elastic job runtime walkthrough (DESIGN.md §11).

One script, five acts on a small PIM machine:

  1. preempt a running fit at a chunk boundary and resume it on a
     FRESH scheduler — bit-identical to never stopping;
  2. priority eviction: a high-priority submit evicts a low-priority
     tenant, which requeues from its snapshot and still finishes;
  3. cross-System migration: an fp32 fit checkpointed on PIM finishes
     on the host baseline (integer fits are refused — the quantization
     contract differs);
  4. survive an injected fault via supervised retry;
  5. kill a checkpointed manifest run mid-queue and --resume it.

Run:  PYTHONPATH=src python examples/elastic_jobs.py
"""
import os
import tempfile

import numpy as np

from repro.elastic import FaultInjector
from repro.sched import JobState, PimScheduler, run_manifest
from repro.systems import (HostConfig, HostSystem, PimConfig, PimSystem)

rng = np.random.RandomState(0)
X = rng.randn(512, 16).astype(np.float32)
y = (X @ rng.randn(16) + 0.1 * rng.randn(512)).astype(np.float32)


def pim(cores=16):
    return PimScheduler(PimSystem(PimConfig(n_cores=cores)), rank_size=4)


# -- 1. preempt / resume, bit-identical -----------------------------------
print("== 1. preempt at a chunk boundary, resume elsewhere ==")
sched = pim()
job = sched.submit("linreg", (X, y), version="int32", n_iters=200,
                   fuse_steps=16)
for _ in range(5):
    sched.step()
job.preempt()
sched.step()
print(f"   parked: {job.state.value} at iteration {job.iters}, "
      f"snapshot kind {job.snapshot_kind!r}")

fresh = pim()                       # a brand new scheduler + System
fresh.resume(job, data=(X, y))
fresh.drain()

ref_sched = pim()
ref = ref_sched.submit("linreg", (X, y), version="int32", n_iters=200,
                       fuse_steps=16)
ref_sched.drain()
same = np.array_equal(np.asarray(job.result.model.w),
                      np.asarray(ref.result.model.w))
print(f"   resumed -> {job.state.value} at {job.iters} iters; "
      f"bit-identical to uninterrupted: {same}")

# -- 2. priority eviction --------------------------------------------------
print("== 2. priority eviction (preemptive=True) ==")
sched = PimScheduler(PimSystem(PimConfig(n_cores=8)), rank_size=4,
                     preemptive=True)
tenants = [sched.submit("linreg", (X, y), version="int32", n_iters=120,
                        name=f"tenant{i}") for i in range(2)]
sched.step()                                   # machine is now full
urgent = sched.submit("linreg", (X, y), version="int32", n_iters=40,
                      priority=10, name="urgent")
sched.step()
evicted = next(t for t in tenants if t.preemptions)
print(f"   urgent: {urgent.state.value}; evicted {evicted.name} "
      f"(requeued from its snapshot)")
sched.drain()
print(f"   all done: {[t.state.value for t in tenants + [urgent]]}")

# -- 3. cross-System migration --------------------------------------------
print("== 3. fp32 PIM -> host migration ==")
mixed = PimScheduler({"pim": PimSystem(PimConfig(n_cores=8)),
                      "host": HostSystem(HostConfig(n_cores=4))},
                     rank_size=4)
mig = mixed.submit("linreg", (X, y), version="fp32", n_iters=100,
                   fuse_steps=8, target="pim")
mixed.step(); mixed.step()
mig.preempt(); mixed.step()
mixed.resume(mig, target="host")               # fp32: allowed
mixed.drain()
print(f"   finished on {mig.target!r}: {mig.state.value}")

intjob = mixed.submit("linreg", (X, y), version="int32", n_iters=20,
                      target="pim")
mixed.step(); intjob.preempt(); mixed.step()
try:
    mixed.resume(intjob, target="host")
except ValueError as err:
    print(f"   int32 migration refused: {str(err)[:64]}...")
mixed.resume(intjob, target="pim")
mixed.drain()

# -- 4. injected fault, supervised retry ----------------------------------
print("== 4. fault injection + retry budget ==")
injector = FaultInjector.parse("flaky:4")      # die at scheduling step 4
sched = PimScheduler(PimSystem(PimConfig(n_cores=8)), rank_size=4,
                     fault_injector=injector)
flaky = sched.submit("linreg", (X, y), version="int32", n_iters=100,
                     fuse_steps=8, retry_budget=2, name="flaky")
sched.drain()
print(f"   {flaky.state.value} after {flaky.recoveries} recovery "
      f"(last fault on record: {type(flaky.error).__name__})")

# -- 5. crash-survivable manifest queue -----------------------------------
print("== 5. kill a manifest run, then --resume ==")
manifest = {
    "system": {"cores": 16, "rank_size": 4},
    "datasets": {"lin": {"kind": "linear", "samples": 512,
                         "features": 16, "seed": 0}},
    "jobs": [
        {"workload": "linreg", "dataset": "lin", "cores": 4,
         "name": "quick", "version": "int32",
         "params": {"n_iters": 8, "fuse_steps": 2}},
        {"workload": "linreg", "dataset": "lin", "cores": 4,
         "name": "long", "version": "int32",
         "params": {"n_iters": 200, "fuse_steps": 2}},
    ],
}
ckpt = tempfile.mkdtemp(prefix="elastic_demo_")
crashed, handles = run_manifest(manifest, drain=False,
                                checkpoint_dir=ckpt)
for _ in range(8):
    crashed.step()
print(f"   'crash' with "
      f"{ {h.name: h.state.value for h in handles} }; "
      f"queue record: {os.path.join(ckpt, 'queue.json')}")
del crashed

sched2, handles2 = run_manifest(manifest, checkpoint_dir=ckpt,
                                resume=True)
for h in handles2:
    extra = " (restored, not re-run)" if h.restored else \
        f" (resumed, {h.iters} iters total)"
    print(f"   {h.name}: {h.state.value}{extra}")
assert all(h.state is JobState.DONE for h in handles2)
print("done.")
