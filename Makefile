# Developer entry points.  `make check` is the fast gate (~1 min);
# `make test` is the full tier-1 suite; `make bench` prints the paper
# figure reproductions as CSV; `make jobs` runs the scheduler demo;
# `make elastic-demo` walks preempt/migrate/fault/crash-resume;
# `make compare` runs the Fig. 13-17 PIM/host/gpu-model comparison on
# tiny shapes and records benchmarks/out/compare.json;
# `make placement-bench` runs the contention-aware vs first-fit
# placement comparison and records benchmarks/out/placement_bench.json;
# `make serve-bench` runs the Poisson sustained-load service benchmark
# and records benchmarks/out/service_bench.json.
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test bench bench-fusion compare placement-bench \
	serve-bench quickstart jobs elastic-demo emb

check:
	./scripts/ci.sh

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run $(ARGS)

bench-fusion:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.step_fusion_bench

compare:
	PYTHONPATH=$(PYTHONPATH) python -m repro.launch.compare --tiny

placement-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.placement_bench

serve-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.service_bench

quickstart:
	PYTHONPATH=$(PYTHONPATH) python examples/quickstart.py

jobs:
	PYTHONPATH=$(PYTHONPATH) python -m repro.launch.pim_jobs --demo

elastic-demo:
	PYTHONPATH=$(PYTHONPATH) python examples/elastic_jobs.py

# `make emb` runs the EMB deferred-update traffic/quality sweep and
# records benchmarks/out/emb_bench.json (DESIGN.md §15.6)
emb:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.emb_bench
