"""Paper Fig. 8 / 9 / 10: single-PIM-core kernel time vs # PIM threads.

Two columns per point: the calibrated hierarchical cost model's per-DPU
leaf (reproduces the paper's measured saturation-at-11-threads shape and
version ratios) and — for the thread-independent part — the measured
wall time of our JAX kernels on CPU for the same per-core workload
(2048 x 16 for LIN/LOG, 600k x 16 DTR, 100k x 16 KME).
"""
from __future__ import annotations

import numpy as np

from repro.systems.topology import HierarchicalCostModel
from .common import row

THREADS = (1, 2, 4, 8, 11, 16, 24)
PAPER_RATIOS = {
    "lin_fp32_over_int32": 8.5,   # §5.2.1 "order of magnitude"/8.5x
    "lin_int32_over_hyb": 1.41,
    "lin_hyb_over_bui": 1.25,
    "log_int32_over_lut_wram": 53.0,
    "log_lut_mram_over_wram": 1.03,
    "log_lut_wram_over_hyb": 1.28,
    "log_hyb_over_bui": 1.43,
}


def run():
    rows = []
    m = HierarchicalCostModel.for_cores(1)   # Fig. 8-10 is one PIM core

    def sec(w, v, t):
        n = {"lin": 2048, "log": 2048, "dtr": 600_000, "kme": 100_000}[w]
        return m.workload_seconds(w, v, n, 16, 1, t)

    for w, versions in (("lin", ("fp32", "int32", "hyb", "bui")),
                        ("log", ("fp32", "int32", "int32_lut_mram",
                                 "int32_lut_wram", "hyb_lut", "bui_lut"))):
        for v in versions:
            for t in THREADS:
                rows.append(row(f"fig8_9_{w}_{v}_t{t}_model_ms",
                                sec(w, v, t) * 1e3, "dpu_cost_model"))
    for w in ("dtr", "kme"):
        for t in THREADS:
            rows.append(row(f"fig10_{w}_t{t}_model_ms",
                            sec(w, "fp32" if w == "dtr" else "int16", t)
                            * 1e3, "dpu_cost_model"))

    # saturation + calibration ratios vs paper
    sat = sec("lin", "int32", 11) / sec("lin", "int32", 24)
    rows.append(row("fig8_saturation_at_11_threads", sat,
                    "paper=1.0_(flat_after_11)"))
    model_ratios = {
        "lin_fp32_over_int32": sec("lin", "fp32", 16) / sec("lin", "int32", 16),
        "lin_int32_over_hyb": sec("lin", "int32", 16) / sec("lin", "hyb", 16),
        "lin_hyb_over_bui": sec("lin", "hyb", 16) / sec("lin", "bui", 16),
        "log_int32_over_lut_wram": sec("log", "int32", 16)
        / sec("log", "int32_lut_wram", 16),
        "log_lut_mram_over_wram": sec("log", "int32_lut_mram", 16)
        / sec("log", "int32_lut_wram", 16),
        "log_lut_wram_over_hyb": sec("log", "int32_lut_wram", 16)
        / sec("log", "hyb_lut", 16),
        "log_hyb_over_bui": sec("log", "hyb_lut", 16)
        / sec("log", "bui_lut", 16),
    }
    for k, v in model_ratios.items():
        rows.append(row(f"calib_{k}", v, f"paper={PAPER_RATIOS[k]}"))
    return rows
