"""Step-fusion benchmark: fused k-step scan chunks vs the per-step loop.

Measures the on-device fused step engine (core/pim.py StepProgram,
DESIGN.md §9) on the paper's iterative workloads:

  unfused   fuse_steps=1  — the host-orchestrated loop: one kernel
            launch + one host sync per training iteration (the paper's
            CPU<->PIM cadence);
  fused     fuse_steps=32 — k iterations compiled into one lax.scan
            launch; the kernel -> reduce -> update -> re-quantize cycle
            never leaves the device inside a chunk.

Reports wall-clock per fit, speedup, and launches/syncs per iteration
(from the TransferStats deltas), and asserts that the fused integer fits
are bit-identical to the unfused loop.  Results are recorded to
``benchmarks/out/step_fusion_bench.json`` — the acceptance number is
``lin_int32.speedup`` (>= 5x on the 500-iteration LIN-INT32 fit).

The ``pipeline_lin_int32`` case measures the double-buffered chunk
pipeline (DESIGN.md §14.1) on a record-heavy fit: every chunk boundary
evaluates the model and appends a durable (fsync'd) trajectory record,
so the host drain has real work to hide behind the in-flight chunk.
``pipeline_depth=1`` serializes drain and dispatch (the §9 cadence);
``pipeline_depth=2`` overlaps them.  Reported as the median of paired
depth-1/depth-2 ratios (paired to cancel storage-latency drift — the
acceptance number is ``pipeline_lin_int32.speedup`` >= 1.15x), with
bit-identity of weights, bias, and recorded history asserted.

  PYTHONPATH=src python -m benchmarks.step_fusion_bench
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import row, write_json
from repro.api import PimConfig, PimSystem
from repro.core import kmeans, linreg, logreg
from repro.data.synthetic import make_blobs, make_linear_dataset

N_SAMPLES, N_FEATURES = 2048, 16
N_ITERS = 500
FUSE = 32
CORES = 16
OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "step_fusion_bench.json")


def _timed_fit(fit, ds, cfg):
    fit(ds, cfg)                       # warmup: compile + view transfer
    snap = ds.system.stats.snapshot()
    t0 = time.perf_counter()
    result = fit(ds, cfg)
    dt = time.perf_counter() - t0
    return result, dt, ds.system.stats.delta(snap)


def _case(name, fit, make_cfg, ds, iters, bitwise=True):
    r1, t1, d1 = _timed_fit(fit, ds, make_cfg(1))
    rk, tk, dk = _timed_fit(fit, ds, make_cfg(FUSE))
    if hasattr(r1, "w"):
        exact = bool(np.array_equal(r1.w, rk.w) and r1.b == rk.b)
        quality = abs(float(r1.b) - float(rk.b))
    else:  # KMeansResult
        exact = False
        quality = abs(r1.inertia - rk.inertia) / max(abs(r1.inertia), 1e-12)
    out = {
        "n_iters": iters,
        "fuse_steps": FUSE,
        "unfused_s": t1,
        "fused_s": tk,
        "speedup": t1 / tk,
        "unfused_launches_per_iter": d1.kernel_launches / iters,
        "fused_launches_per_iter": dk.kernel_launches / iters,
        "unfused_host_syncs": d1.host_syncs,
        "fused_host_syncs": dk.host_syncs,
        "bit_identical": exact,
    }
    if bitwise and not exact:
        raise AssertionError(f"{name}: fused result diverged from the "
                             f"serial loop (quality delta {quality})")
    return out


#: pipeline case: chunks per fit and per-boundary record size.  The
#: record is sized like a real per-boundary training artifact
#: (predictions + residuals + diagnostics); what matters to the
#: measurement is that the host's durable write genuinely waits on
#: storage while the next chunk computes.  The shape is chosen so chunk
#: compute exceeds the typical fsync latency — the regime where the
#: depth-2 pipeline fully hides the storage wait and the ratio is
#: stable against storage-latency drift.
PIPE_SAMPLES, PIPE_ITERS, PIPE_FUSE = 32768, 128, 16
PIPE_RECORD_KB = 4096
PIPE_PAIRS = 7


def _pipeline_case():
    """Record-heavy fused LIN-INT32 fit: depth-2 pipeline vs the
    depth-1 serial cadence, paired runs, median ratio."""
    X, y, _ = make_linear_dataset(PIPE_SAMPLES, N_FEATURES, seed=0)
    pim = PimSystem(PimConfig(n_cores=CORES))
    ds = pim.put(X, y)
    log_path = tempfile.mktemp(prefix="pipeline_records_",
                               suffix=".bin")

    reps = PIPE_RECORD_KB * 256 // PIPE_SAMPLES

    def eval_fn(w, b):
        pred = (X @ w + b).astype(np.float32)
        payload = pred.tobytes()   # serialize once, append repeatedly:
        with open(log_path, "ab") as fh:   # the drain is storage wait,
            for _ in range(reps):          # not host memcpy
                fh.write(payload)
            fh.flush()
            os.fsync(fh.fileno())   # durable record: real storage wait
        return float(np.mean((pred - y) ** 2))

    cfgs = {depth: linreg.GdConfig(
                version="int32", n_iters=PIPE_ITERS,
                fuse_steps=PIPE_FUSE, record_every=PIPE_FUSE,
                pipeline_depth=depth)
            for depth in (1, 2)}
    results = {}
    try:
        for depth in (1, 2):             # warmup: compile both paths
            results[depth] = linreg.fit(ds, cfgs[depth],
                                        eval_fn=eval_fn)
        r1, r2 = results[1], results[2]
        exact = bool(np.array_equal(r1.w, r2.w) and r1.b == r2.b
                     and r1.history == r2.history)
        if not exact:
            raise AssertionError(
                "pipeline_lin_int32: depth-2 result diverged from the "
                "serial cadence")
        ratios, t1s, t2s = [], [], []
        for _ in range(PIPE_PAIRS):
            t = {}
            for depth in (1, 2):
                if os.path.exists(log_path):
                    os.unlink(log_path)
                t0 = time.perf_counter()
                linreg.fit(ds, cfgs[depth], eval_fn=eval_fn)
                t[depth] = time.perf_counter() - t0
            ratios.append(t[1] / t[2])
            t1s.append(t[1])
            t2s.append(t[2])
    finally:
        if os.path.exists(log_path):
            os.unlink(log_path)
    ratios.sort()
    t1s.sort()
    t2s.sort()
    return {
        "n_iters": PIPE_ITERS,
        "fuse_steps": PIPE_FUSE,
        "record_every": PIPE_FUSE,
        "record_kb": PIPE_RECORD_KB,
        "pairs": PIPE_PAIRS,
        "unpipelined_s": t1s[len(t1s) // 2],
        "pipelined_s": t2s[len(t2s) // 2],
        #: median of paired ratios — robust to storage-latency drift
        "speedup": ratios[len(ratios) // 2],
        "bit_identical": True,
    }


def run():
    X, y, _ = make_linear_dataset(N_SAMPLES, N_FEATURES, seed=0)
    yc = (y > np.median(y)).astype(np.float32)
    Xb, _, _ = make_blobs(N_SAMPLES, N_FEATURES, centers=16, seed=1)

    results = {}

    pim = PimSystem(PimConfig(n_cores=CORES))
    ds = pim.put(X, y)
    for ver in ("int32", "hyb", "fp32"):
        results[f"lin_{ver}"] = _case(
            f"lin_{ver}", linreg.fit,
            lambda fuse, v=ver: linreg.GdConfig(
                version=v, n_iters=N_ITERS, fuse_steps=fuse),
            ds, N_ITERS, bitwise=ver != "fp32")

    pim = PimSystem(PimConfig(n_cores=CORES))
    dsl = pim.put(X, yc)
    for ver in ("int32_lut_wram", "hyb_lut"):
        results[f"log_{ver}"] = _case(
            f"log_{ver}", logreg.fit,
            lambda fuse, v=ver: logreg.LogRegConfig(
                version=v, n_iters=N_ITERS, fuse_steps=fuse),
            dsl, N_ITERS, bitwise=True)

    pim = PimSystem(PimConfig(n_cores=CORES))
    dsb = pim.put(Xb)
    kme_iters = 60
    results["kme_int16"] = _case(
        "kme_int16",
        lambda d, cfg: kmeans.fit(d, cfg, return_labels=False),
        lambda fuse: kmeans.KMeansConfig(
            k=16, max_iters=kme_iters, tol=0.0, seed=3, fuse_steps=fuse),
        dsb, kme_iters, bitwise=False)

    results["pipeline_lin_int32"] = _pipeline_case()

    write_json(OUT_PATH, results)

    rows = []
    for name, r in results.items():
        if "fused_s" not in r:   # the pipeline case reports its own keys
            rows.append(row(
                f"fusion.{name}", r["pipelined_s"] * 1e6 / r["n_iters"],
                f"speedup={r['speedup']:.2f}x;bit={r['bit_identical']}"))
            continue
        rows.append(row(
            f"fusion.{name}", r["fused_s"] * 1e6 / r["n_iters"],
            f"speedup={r['speedup']:.2f}x;"
            f"launches/it={r['fused_launches_per_iter']:.3f};"
            f"bit={r['bit_identical']}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
