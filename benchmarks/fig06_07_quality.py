"""Paper Fig. 6 / Fig. 7: LIN & LOG training error rate vs iterations.

Reproduces the quality curves on the synthetic §4.1 dataset (8192 x 16,
4 decimal digits) and prints our final error next to the paper's reported
value for each version.  Exact values depend on the unpublished data draw;
the asserted relationships live in tests/test_quality_repro.py.
"""
from __future__ import annotations

import time

from repro.api import PimConfig, PimSystem
from repro.core import linreg, logreg
from repro.core.metrics import training_error_rate
from repro.data.synthetic import make_linear_dataset
from .common import row

PAPER_LIN = {"fp32": 0.55, "int32": 1.02, "hyb": 1.29, "bui": 1.29}
PAPER_LOG = {"fp32": 1.20, "int32": 2.42, "int32_lut_mram": 2.14,
             "int32_lut_wram": 2.14, "hyb_lut": 14.12, "bui_lut": 14.12}
N_ITERS = 600


def run():
    rows = []
    X, y, _ = make_linear_dataset(8192, 16, decimals=4, seed=0)
    pim = PimSystem(PimConfig(n_cores=16))
    # one bank-resident dataset for the whole LIN+LOG version ladder:
    # ten trainings, one CPU->PIM partition per data precision
    ds = pim.put(X, y)

    for ver in linreg.VERSIONS:
        t0 = time.perf_counter()
        r = linreg.fit(ds, linreg.GdConfig(version=ver, n_iters=N_ITERS))
        dt = time.perf_counter() - t0
        err = training_error_rate(r.predict(X), y)
        rows.append(row(f"fig6_lin_{ver}_err_pct", err * 1.0,
                        f"paper={PAPER_LIN[ver]};train_s={dt:.1f}"))

    for ver in logreg.VERSIONS:
        t0 = time.perf_counter()
        r = logreg.fit(ds, logreg.LogRegConfig(version=ver,
                                               n_iters=N_ITERS))
        dt = time.perf_counter() - t0
        err = training_error_rate(r.predict(X), y, threshold=0.0)
        rows.append(row(f"fig7a_log_{ver}_err_pct", err,
                        f"paper={PAPER_LOG[ver]};train_s={dt:.1f}"))

    rows.append(row("fig6_7_shard_transfers", pim.stats.shard_transfers,
                    "one_partition_per_data_precision"))

    # Fig 7(b): 2-decimal samples reduce the hybrid versions' error
    X2, y2, _ = make_linear_dataset(8192, 16, decimals=2, seed=0)
    ds2 = pim.put(X2, y2)
    for dec, (dsd, Xd, yd) in (("4dec", (ds, X, y)),
                               ("2dec", (ds2, X2, y2))):
        r = logreg.fit(dsd, logreg.LogRegConfig(version="hyb_lut",
                                                n_iters=N_ITERS))
        err = training_error_rate(r.predict(Xd), yd, threshold=0.0)
        rows.append(row(f"fig7b_log_hyb_lut_{dec}_err_pct", err,
                        "paper=14.12_vs_4.49"))
    return rows
