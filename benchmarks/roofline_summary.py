"""Roofline summary rows from the dry-run results (deliverable (g) in the
benchmark artifact).  Reads experiments/dryrun_results.json; regenerate
with `python -m repro.launch.dryrun` + `python -m repro.launch.roofline`.
"""
from __future__ import annotations

import json
import os

from .common import row


def run():
    path = "experiments/dryrun_results.json"
    if not os.path.exists(path):
        return [row("roofline_summary_missing", -1,
                    "run python -m repro.launch.dryrun first")]
    with open(path) as f:
        results = json.load(f)
    from repro.launch.roofline import terms

    rows = []
    n_ok = n_skip = 0
    best = (None, 0.0)
    for key in sorted(results):
        parts = key.split("|")
        if len(parts) != 3:
            continue  # --mesh-shape experiment entries
        arch, shape, mesh = parts
        e = results[key]
        if e["status"] == "skipped":
            n_skip += 1
            continue
        if e["status"] != "ok":
            rows.append(row(f"dryrun_{key}", -1, "ERROR"))
            continue
        n_ok += 1
        t = terms(e, e.get("n_devices", 256), arch, shape)
        step_us = t["step_time_s"] * 1e6
        rows.append(row(
            f"roofline_{arch}_{shape}_{mesh}_step_us", step_us,
            f"bound={t['bound']};frac={t['roofline_fraction']:.3f};"
            f"model_over_hlo={t['useful_ratio']:.3f}"))
        if mesh == "1pod" and t["roofline_fraction"] > best[1]:
            best = (key, t["roofline_fraction"])
    rows.append(row("dryrun_cells_ok", n_ok, f"skipped={n_skip};errors=0"))
    rows.append(row("best_roofline_fraction", best[1], str(best[0])))
    return rows
