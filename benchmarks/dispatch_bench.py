"""Kernel-dispatch benchmark: backend wall times + trainer parity.

Two outputs per workload hot path:

  * per-op wall time of the ``jnp_ref`` vs ``pallas_interpret``
    backends (interpret mode on CPU is the correctness path, not a perf
    claim — real kernel perf comes from the TPU backend / cost model);
  * the accuracy/inertia of full ``KMeansTrainer``/``DTreeTrainer``
    fits under both backends, confirming the dispatch wiring causes
    **no accuracy regression vs the jnp path** (deltas must be 0: the
    kernels are deterministic integer ops).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import dtree, kmeans
from repro.kernels import dispatch
from repro.systems import PimConfig, PimSystem
from .common import row, time_call

_BACKENDS = ("jnp_ref", "pallas_interpret")


def run():
    rows = []
    rng = np.random.RandomState(0)

    # -- per-op backend wall times -----------------------------------------
    x = jnp.asarray(rng.randint(-2047, 2048, (4096, 16)), jnp.int16)
    c = jnp.asarray(rng.randint(-2047, 2048, (16, 16)), jnp.int16)
    ts = {be: time_call(dispatch.launch, "kmeans_assign", x, c, backend=be)
          for be in _BACKENDS}
    rows.append(row("dispatch_kmeans_assign_ref_us",
                    ts["jnp_ref"] * 1e6,
                    f"interp_us={ts['pallas_interpret'] * 1e6:.0f}"))

    xq = jnp.asarray(rng.randint(-1024, 1024, (4096, 16)), jnp.int32)
    wq = jnp.asarray(rng.randint(-1024, 1024, (16,)), jnp.int32)
    ts = {be: time_call(dispatch.launch, "fx_matvec", xq, wq, 10,
                        backend=be) for be in _BACKENDS}
    rows.append(row("dispatch_fx_matvec_ref_us", ts["jnp_ref"] * 1e6,
                    f"interp_us={ts['pallas_interpret'] * 1e6:.0f}"))

    # -- trainer parity: no accuracy regression vs the jnp path ------------
    X = rng.normal(0, 1, (512, 8)).astype(np.float32)
    y = (X[:, 0] + 0.5 * X[:, 1] > 0).astype(np.int32)
    km, acc = {}, {}
    for be in _BACKENDS:
        pim = PimSystem(PimConfig(n_cores=4))
        r = kmeans.fit(pim.put(X), kmeans.KMeansConfig(
            k=8, max_iters=10, kernel_backend=be))
        km[be] = r.inertia
        tree = dtree.fit(pim.put(X, y), dtree.TreeConfig(
            max_depth=5, kernel_backend=be))
        acc[be] = float((tree.predict(X) == y).mean())
    rows.append(row("dispatch_kmeans_inertia_delta",
                    abs(km["jnp_ref"] - km["pallas_interpret"]),
                    f"ref_inertia={km['jnp_ref']:.2f}"))
    rows.append(row("dispatch_dtree_acc_delta",
                    abs(acc["jnp_ref"] - acc["pallas_interpret"]),
                    f"ref_acc={acc['jnp_ref']:.4f}"))
    return rows
