"""EMB deferred-update benchmark: flush traffic vs update freshness.

Sweeps the LazyDP window D (``flush_every``) on a Zipf-skewed recsys
stream and records, per D:

  flush_bytes   the sparse update payload (ids + delta rows) shipped
                across the host<->bank boundary — eager (D=1) pays it
                every step; a window dedups hot rows and ships each
                touched row once per D batches;
  final_loss    training MSE at the end of the run — the freshness
                cost of deferring (stale in-window gathers);
  wall_s        measured fit wall-clock in this container;
  compressed    the same D with ``compress_flush=True`` — int8 rows +
                per-row scales + sparse error feedback on the wire.

The acceptance claim (DESIGN.md §15.6, asserted here and in the @slow
tier of tests/test_emb.py): D=8 cuts flush traffic >= 2x vs eager while
the final loss stays within 1%.  The D=32 row deliberately rides past
the freshness cliff — at lr=1.0 a 32-batch-stale window destabilizes
training, which is the point: D trades traffic for freshness, not for
free.  Results are recorded to
``benchmarks/out/emb_bench.json`` through the shared run-metadata
envelope.

  PYTHONPATH=src python -m benchmarks.emb_bench
  make emb
"""
from __future__ import annotations

import os
import time

from benchmarks.common import write_json
from repro.data.synthetic import make_recsys
from repro.emb import EmbConfig, fit
from repro.systems import make_system

N_SAMPLES = 8192
N_USERS, N_ITEMS, DIM = 256, 192, 8
N_ITERS, BATCH = 192, 256
CORES = 16
WINDOWS = (1, 2, 8, 32)
OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "emb_bench.json")


def _run(X, y, D: int, compress: bool = False) -> dict:
    cfg = EmbConfig(version="int32", n_iters=N_ITERS, batch=BATCH,
                    dim=DIM, lr=1.0, frac_bits=12, seed=1,
                    flush_every=D, compress_flush=compress,
                    record_every=N_ITERS)
    system = make_system("pim", n_cores=CORES)
    ds = system.put(X, y)
    t0 = time.perf_counter()
    res = fit(ds, cfg)
    wall = time.perf_counter() - t0
    s = system.stats
    return {"flush_every": D, "compress_flush": compress,
            "flush_bytes": s.flush_bytes,
            "compressed_bytes": s.compressed_bytes,
            "cross_rank_bytes": s.cross_rank_bytes,
            "final_loss": res.history[-1][1],
            "n_flushes": res.n_flushes,
            "wall_s": wall}


def main() -> dict:
    X, y = make_recsys(N_SAMPLES, N_USERS, N_ITEMS, dim=DIM,
                       zipf_a=1.2, seed=0)
    rows = [_run(X, y, D) for D in WINDOWS]
    rows.append(_run(X, y, 8, compress=True))

    eager = rows[0]
    print(f"EMB deferred-update sweep ({N_SAMPLES} triples, "
          f"{N_USERS}x{N_ITEMS} vocab, dim={DIM}, {N_ITERS} steps of "
          f"batch {BATCH}, int32/Q12, {CORES} cores)")
    print(f"  {'D':>4} {'compress':>8} {'flush KiB':>10} {'saving':>7} "
          f"{'wire KiB':>9} {'final loss':>11} {'wall s':>7}")
    for r in rows:
        saving = eager["flush_bytes"] / max(r["flush_bytes"], 1)
        wire = (r["compressed_bytes"] if r["compress_flush"]
                else r["flush_bytes"])
        print(f"  {r['flush_every']:>4} "
              f"{str(r['compress_flush']):>8} "
              f"{r['flush_bytes'] / 1024:>10.1f} {saving:>6.1f}x "
              f"{wire / 1024:>9.1f} {r['final_loss']:>11.6f} "
              f"{r['wall_s']:>7.2f}")

    d8 = next(r for r in rows if r["flush_every"] == 8
              and not r["compress_flush"])
    ratio = eager["flush_bytes"] / d8["flush_bytes"]
    drift = abs(d8["final_loss"] - eager["final_loss"]) \
        / max(eager["final_loss"], 1e-12)
    print(f"\n  acceptance: D=8 traffic saving {ratio:.1f}x "
          f"(>= 2x), loss drift {100 * drift:.2f}% (<= 1%)")
    assert ratio >= 2.0, f"D=8 saved only {ratio:.2f}x flush traffic"
    assert drift <= 0.01, f"D=8 final loss drifted {100 * drift:.2f}%"

    record = {"meta": {"samples": N_SAMPLES, "n_users": N_USERS,
                       "n_items": N_ITEMS, "dim": DIM,
                       "n_iters": N_ITERS, "batch": BATCH,
                       "cores": CORES},
              "rows": rows,
              "acceptance": {"d8_traffic_saving": ratio,
                             "d8_loss_drift": drift}}
    record = write_json(OUT_PATH, record)
    print(f"  recorded -> {OUT_PATH}")
    return record


if __name__ == "__main__":
    main()
