"""Pallas-kernel micro-benchmarks (interpret mode on CPU = correctness
path; wall times are indicative only — real perf numbers come from the
roofline terms of the dry-run HLO, see §Roofline).

All calls go through the family ``ops`` wrappers with the legacy
``use_pallas`` flags, which route through repro.kernels.dispatch — the
same code path the trainers use."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.fixed_point import to_fixed
from repro.core.lut import build_sigmoid_lut
from repro.kernels.flash_attention.ops import mha
from repro.kernels.kmeans_assign.ops import assign_and_accumulate
from repro.kernels.lut_activation.ops import lut_sigmoid
from repro.kernels.quant_matmul.ops import quant_matmul
from .common import row, time_call


def run():
    rows = []
    rng = np.random.RandomState(0)

    a = jnp.asarray(rng.randint(-128, 128, (256, 512)), jnp.int8)
    b = jnp.asarray(rng.randint(-128, 128, (512, 256)), jnp.int8)
    sa = jnp.float32(0.01)
    sb = jnp.float32(0.02)
    t_k = time_call(quant_matmul, a, b, sa, sb, use_pallas=True)
    t_r = time_call(quant_matmul, a, b, sa, sb, use_pallas=False)
    rows.append(row("kern_quant_matmul_interp_us", t_k * 1e6,
                    f"xla_ref_us={t_r * 1e6:.0f}"))

    lut = build_sigmoid_lut()
    xq = to_fixed(jnp.asarray(rng.uniform(-20, 20, 32768), jnp.float32), 10)
    t_v = time_call(lut_sigmoid, xq, lut, placement="vmem")
    t_h = time_call(lut_sigmoid, xq, lut, placement="hbm")
    rows.append(row("kern_lut_sigmoid_vmem_interp_us", t_v * 1e6,
                    f"hbm_us={t_h * 1e6:.0f}"))

    x = jnp.asarray(rng.randint(-2047, 2048, (4096, 16)), jnp.int16)
    c = jnp.asarray(rng.randint(-2047, 2048, (16, 16)), jnp.int16)
    t = time_call(assign_and_accumulate, x, c, use_pallas=True)
    rows.append(row("kern_kmeans_assign_interp_us", t * 1e6, ""))

    q = jnp.asarray(rng.normal(0, 1, (1, 4, 256, 64)), jnp.float32)
    t_f = time_call(mha, q, q, q, causal=True, use_pallas=True,
                    bq=128, bk=128)
    t_x = time_call(mha, q, q, q, causal=True, use_pallas=False)
    rows.append(row("kern_flash_attn_interp_us", t_f * 1e6,
                    f"xla_ref_us={t_x * 1e6:.0f}"))
    return rows
