"""Scheduler benchmark: fused gang-stepped sweeps vs serial fits.

Measures the multi-tenant subsystem (DESIGN.md §7) on a K-point
learning-rate sweep of LIN gradient descent:

  serial    K back-to-back ``fit``s on the whole mesh (the pre-scheduler
            baseline) — K kernel launches per step-equivalent;
  gang      K jobs on disjoint rank slices advanced round-robin — same
            launch count, but concurrent tenancy;
  fused     one gang on one slice, one *batched* launch per step.

Reports makespan (wall seconds for all K fits), throughput (jobs/s), and
the accuracy check that the fused sweep's coefficients match serial
bit-for-bit (integer GD is exact).  Each record also carries the
hierarchical cost model's modeled DPU seconds for one job and for the
serial K-job baseline (DESIGN.md §12) — what the same sweep would cost
on the paper's hardware rather than this container.  Results are also
written to ``benchmarks/out/sched_bench.json`` so the makespan claim is
recorded.

  PYTHONPATH=src python -m benchmarks.sched_bench
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row, write_json
from repro.api import (HierarchicalCostModel, PimConfig, PimSystem,
                       make_estimator)
from repro.data.synthetic import make_linear_dataset
from repro.sched import PimScheduler

N_SAMPLES, N_FEATURES = 2048, 16
N_ITERS = 120
LRS = [0.02, 0.04, 0.06, 0.08, 0.1, 0.15, 0.2, 0.3]
VERSION = "int32"
CORES = 16
OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "sched_bench.json")


def _serial(X, y, lrs):
    """K sequential whole-mesh fits through the session API."""
    pim = PimSystem(PimConfig(n_cores=CORES))
    ds = pim.put(X, y)
    coefs = []
    for lr in lrs:
        est = make_estimator("linreg", version=VERSION, lr=lr,
                             n_iters=N_ITERS, system=pim).fit(ds)
        coefs.append(est.coef_)
    return coefs


def _sweep(X, y, lrs, fused: bool):
    system = PimSystem(PimConfig(n_cores=CORES))
    sched = PimScheduler(system, rank_size=CORES if fused else
                         CORES // len(lrs) or 1)
    handles = sched.sweep("linreg", (X, y), {"lr": lrs}, version=VERSION,
                          n_iters=N_ITERS,
                          n_cores=CORES if fused else None, fused=fused)
    sched.drain()
    bad = [h for h in handles if h.state.value != "done"]
    if bad:
        raise RuntimeError(f"sweep jobs did not finish: {bad}")
    return [h.result.attributes["coef_"] for h in handles], sched


def run():
    X, y, _ = make_linear_dataset(N_SAMPLES, N_FEATURES, seed=0)
    k = len(LRS)

    # warmup: exercise every path once at full K (each timed branch
    # still pays its own jit compile — fresh systems/slices on both
    # sides — but process-level jax warmup is amortized out)
    _serial(X, y, LRS[:1])
    _sweep(X, y, LRS, fused=True)
    _sweep(X, y, LRS, fused=False)

    t0 = time.perf_counter()
    ref = _serial(X, y, LRS)
    t_serial = time.perf_counter() - t0

    t0 = time.perf_counter()
    gang, gang_sched = _sweep(X, y, LRS, fused=False)
    t_gang = time.perf_counter() - t0

    t0 = time.perf_counter()
    fused, fused_sched = _sweep(X, y, LRS, fused=True)
    t_fused = time.perf_counter() - t0

    exact_fused = all(np.array_equal(a, b) for a, b in zip(ref, fused))
    exact_gang = all(np.array_equal(a, b) for a, b in zip(ref, gang))
    # what one job / the serial baseline costs on the modeled machine
    model = HierarchicalCostModel.for_cores(CORES)
    modeled_job_s = model.job_seconds("lin", VERSION, N_SAMPLES,
                                      N_FEATURES, N_ITERS,
                                      n_cores=CORES, n_threads=16)
    result = {
        "k_jobs": k,
        "n_iters": N_ITERS,
        "version": VERSION,
        "serial_makespan_s": t_serial,
        "gang_makespan_s": t_gang,
        "fused_makespan_s": t_fused,
        "serial_jobs_per_s": k / t_serial,
        "gang_jobs_per_s": k / t_gang,
        "fused_jobs_per_s": k / t_fused,
        "fused_speedup_over_serial": t_serial / t_fused,
        "fused_matches_serial_bitwise": exact_fused,
        "gang_matches_serial_bitwise": exact_gang,
        "modeled_job_dpu_s": modeled_job_s,
        "modeled_serial_dpu_s": k * modeled_job_s,
        # modeled-vs-measured drift (DESIGN.md §13.5): per-job wall /
        # cost-model ratios straight out of PimScheduler.stats(), plus
        # the gang scheduler's per-chunk ratio histogram — the PR 7
        # calibration recorded as a continuously monitored series
        "gang_drift": gang_sched.stats()["drift"],
        "fused_drift": fused_sched.stats()["drift"],
        "drift_ratio_histogram": gang_sched.metrics.to_dict().get(
            "sched.drift_ratio"),
    }
    write_json(OUT_PATH, result)

    return [
        row(f"sched.serial.K{k}", t_serial * 1e6 / k,
            f"makespan={t_serial:.3f}s"),
        row(f"sched.gang.K{k}", t_gang * 1e6 / k,
            f"makespan={t_gang:.3f}s;exact={exact_gang}"),
        row(f"sched.fused.K{k}", t_fused * 1e6 / k,
            f"makespan={t_fused:.3f}s;exact={exact_fused};"
            f"speedup={t_serial / t_fused:.2f}x"),
    ]


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
