"""Shared benchmark utilities.

Every JSON a bench writes into ``benchmarks/out/`` goes through
:func:`write_json`, which stamps the run-metadata envelope (git sha,
UTC timestamp, jax version, host platform — repro.obs.runmeta) so the
recorded perf trajectory stays attributable across PRs.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.obs.runmeta import run_meta, write_json  # noqa: F401 — the
# shared writer every bench uses (re-exported so benches import one
# module for timing and persistence alike)


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
              **kw) -> float:
    """Median wall-time of fn(*args) in seconds (block_until_ready aware)."""
    import jax
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            or isinstance(out, (tuple, list, dict)) else None
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, us_per_call: float, derived: str = "") -> tuple:
    return (name, us_per_call, derived)


def print_rows(rows) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
