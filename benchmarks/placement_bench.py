"""Placement benchmark: contention-aware vs first-fit bank allocation.

A modeled multi-tenant experiment over the hierarchical cost model
(DESIGN.md §12.4).  A mixed manifest of K-Means and GD jobs shares one
modeled 1024-core PIM machine (16 ranks of 64 DPUs, 2 ranks per memory
channel); jobs are admitted FIFO, each lease is placed by the policy
under test, and a job's modeled duration comes from
``HierarchicalCostModel.job_seconds`` with the channel-contention
divisor observed at placement time — tenants sharing a memory channel
split its host-link bandwidth, so where a lease lands changes how long
its transfer legs take.  (Durations are priced once, at admission — a
static approximation both policies share.)

The manifest leaves the machine ~25% headroom: placement only matters
when the allocator has a choice, and a queue deep enough to pin the
machine at 100% occupancy gives every policy the identical single
hole.  First-fit packs leases left-to-right, stacking tenants onto the
same channels; contention-aware placement spreads them across quiet
channels first.  The benchmark records both makespans (the JSON the CI
check reads asserts contention <= first_fit) plus per-policy placement
traces.  Pure cost-model arithmetic — no JAX, runs in milliseconds.

  PYTHONPATH=src python -m benchmarks.placement_bench
  make placement-bench
"""
from __future__ import annotations

import heapq
import os
from collections import deque

from benchmarks.common import row, write_json
from repro.sched import BankAllocator
from repro.systems.topology import HierarchicalCostModel, PimTopology

MACHINE_CORES = 1024
DPUS_PER_RANK = 64
RANKS_PER_CHANNEL = 2

#: the mixed manifest: leg-heavy K-Means tenants (k centroids broadcast
#: + per-cluster sums gathered every iteration) interleaved with
#: kernel-heavy GD fits — the mix the paper's multi-tenant rank pool
#: would see.  12 of the machine's 16 ranks are demanded, so the
#: allocator always has placement freedom.
JOBS = [
    {"name": f"kme-{i}", "workload": "kme", "version": "int16",
     "n": 16_384, "f": 16, "iters": 100, "cores": 64, "k": 16}
    for i in range(5)
] + [
    {"name": f"lin-{i}", "workload": "lin", "version": "int32",
     "n": 65_536, "f": 16, "iters": 60, "cores": 64, "k": 16}
    for i in range(3)
] + [
    {"name": f"log-{i}", "workload": "log", "version": "int32_lut_wram",
     "n": 32_768, "f": 16, "iters": 80, "cores": 128, "k": 16}
    for i in range(2)
]

OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "placement_bench.json")


def simulate(placement: str) -> dict:
    """Event-driven makespan of the manifest under one placement policy.

    FIFO admission (no backfill — both policies queue identically, so
    the makespan difference is placement and nothing else); durations
    are priced at the contention observed when the lease is granted.
    """
    topo = PimTopology.for_cores(MACHINE_CORES, dpus_per_rank=DPUS_PER_RANK,
                                 ranks_per_channel=RANKS_PER_CHANNEL)
    alloc = BankAllocator(MACHINE_CORES, topology=topo, placement=placement)
    model = HierarchicalCostModel(topo)
    pending = deque(JOBS)
    running: list = []          # (end_time, start_core, lease, name)
    now = 0.0
    trace = []
    while pending or running:
        while pending:
            job = pending[0]
            lease = alloc.allocate(job["cores"])
            if lease is None:
                break
            pending.popleft()
            live = [(ls.start, ls.n_cores) for ls in alloc.leases
                    if ls.start != lease.start]
            sharers = model.contention_sharers(lease.start, lease.n_cores,
                                               live)
            dur = model.job_seconds(
                job["workload"], job["version"], job["n"], job["f"],
                job["iters"], n_cores=lease.n_cores, n_threads=16,
                k=job["k"], start=lease.start, sharers=sharers)
            trace.append({"job": job["name"], "t_admit": now,
                          "start": lease.start, "cores": lease.n_cores,
                          "channels": list(lease.channels),
                          "sharers": sharers, "modeled_s": dur})
            heapq.heappush(running, (now + dur, lease.start, lease,
                                     job["name"]))
        end, _, lease, _name = heapq.heappop(running)
        now = end
        alloc.release(lease)
    return {"placement": placement, "makespan_s": now,
            "mean_sharers": sum(t["sharers"] for t in trace) / len(trace),
            "trace": trace}


def run():
    first_fit = simulate("first_fit")
    contention = simulate("contention")
    speedup = first_fit["makespan_s"] / contention["makespan_s"]
    result = {
        "machine_cores": MACHINE_CORES,
        "dpus_per_rank": DPUS_PER_RANK,
        "ranks_per_channel": RANKS_PER_CHANNEL,
        "n_jobs": len(JOBS),
        "first_fit": first_fit,
        "contention": contention,
        "contention_speedup_over_first_fit": speedup,
        "contention_beats_first_fit": (contention["makespan_s"]
                                       <= first_fit["makespan_s"]),
    }
    write_json(OUT_PATH, result)
    return [
        row("placement.first_fit.makespan_s", first_fit["makespan_s"],
            f"mean_sharers={first_fit['mean_sharers']:.2f}"),
        row("placement.contention.makespan_s", contention["makespan_s"],
            f"mean_sharers={contention['mean_sharers']:.2f}"),
        row("placement.contention_speedup", speedup,
            f"beats_first_fit={result['contention_beats_first_fit']}"),
    ]


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
