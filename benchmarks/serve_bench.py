"""Serving throughput (slot engine, reduced LM, CPU-indicative)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.api import Model
from repro.serve.engine import Request, ServeEngine
from .common import row


def run():
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=4, max_seq=64)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, 8)
                    .astype(np.int32), max_new_tokens=12)
            for _ in range(6)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in done)
    return [row("serve_engine_tok_per_s", total / dt,
                f"requests={len(done)};slots=4;cpu_indicative")]
