"""Sustained-load benchmark for the async training service (§14.2).

Drives the serving :class:`~repro.sched.scheduler.PimScheduler` the way
a tenant population would: jobs arrive on a Poisson process (seeded —
the arrival trace is reproducible) while the background drain loop runs,
so submission, admission, chunk draining, and completion all overlap.
Measures what a service operator would watch:

  ``jobs_per_second``     completed jobs over the measurement window;
  ``queue_latency``       submission -> first admission, p50/p99;
  ``completion_latency``  submission -> terminal state, p50/p99;
  ``slo``                 deadline misses plus cost-model admission
                          rejections under a tight ``max_modeled_seconds``
                          (the §14.3 knobs exercised under load).

Results land in ``benchmarks/out/service_bench.json`` through the shared
run-metadata envelope.

  PYTHONPATH=src python -m benchmarks.service_bench
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row, write_json
from repro.api import PimConfig, PimSystem
from repro.data.synthetic import make_linear_dataset
from repro.sched import JobState, PimScheduler

N_JOBS = 24
ARRIVAL_RATE = 40.0          # jobs/s offered load (Poisson)
N_SAMPLES, N_FEATURES = 512, 8
JOB_CORES, MACHINE_CORES = 4, 16
N_ITERS, FUSE = 60, 10
SEED = 0
OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "service_bench.json")


def _percentiles(xs):
    if not xs:
        return {"count": 0, "p50": None, "p99": None}
    xs = sorted(xs)

    def pct(q):
        import math
        return xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))]

    return {"count": len(xs), "mean": sum(xs) / len(xs),
            "p50": pct(0.50), "p99": pct(0.99), "max": xs[-1]}


def run():
    rng = np.random.RandomState(SEED)
    X, y, _ = make_linear_dataset(N_SAMPLES, N_FEATURES, seed=SEED)

    system = PimSystem(PimConfig(n_cores=MACHINE_CORES))
    sched = PimScheduler(system, rank_size=JOB_CORES, policy="deadline")

    # warmup: compile the fused step program once so the measured window
    # times scheduling, not XLA compilation
    warm = sched.submit("linreg", (X, y), version="int32", name="warmup",
                        n_cores=JOB_CORES, n_iters=N_ITERS,
                        fuse_steps=FUSE)
    sched.drain()
    assert warm.state is JobState.DONE

    gaps = rng.exponential(1.0 / ARRIVAL_RATE, size=N_JOBS)
    sched.serve(poll_interval=0.005)
    t0 = time.perf_counter()
    handles = []
    for i, gap in enumerate(gaps):
        time.sleep(float(gap))
        handles.append(sched.submit(
            "linreg", (X, y), version="int32", name=f"tenant{i}",
            n_cores=JOB_CORES, deadline_seconds=5.0,
            n_iters=N_ITERS, fuse_steps=FUSE))
    assert sched.wait(handles, timeout=120.0), "drain timed out"
    wall = time.perf_counter() - t0
    sched.shutdown(wait=True)

    done = [h for h in handles if h.state is JobState.DONE]
    assert len(done) == N_JOBS, \
        f"lost jobs: {[h.state for h in handles if h.state is not JobState.DONE]}"
    queue_lat = _percentiles([h.queue_latency for h in done])
    completion_lat = _percentiles([h.completion_latency for h in done])

    # SLO admission under the same load model: a bound the cost model
    # prices every job above must reject everything, queue nothing
    slo_sched = PimScheduler(system, rank_size=JOB_CORES,
                             max_modeled_seconds=1e-9)
    rejected = [slo_sched.submit("linreg", (X, y), version="int32",
                                 n_cores=JOB_CORES, n_iters=N_ITERS)
                for _ in range(4)]
    assert all(h.state is JobState.FAILED for h in rejected)
    assert slo_sched.idle

    results = {
        "n_jobs": N_JOBS,
        "offered_jobs_per_second": ARRIVAL_RATE,
        "machine_cores": MACHINE_CORES,
        "job_cores": JOB_CORES,
        "wall_seconds": wall,
        "jobs_per_second": len(done) / wall,
        "queue_latency": queue_lat,
        "completion_latency": completion_lat,
        "slo": {
            "deadline_misses": sum(1 for h in done if h.deadline_missed),
            "admission_rejections": len(rejected),
        },
        "scheduler_metrics": sched.metrics.to_dict(),
    }
    write_json(OUT_PATH, results)
    return [
        row("service.sustained_load", 1e6 / results["jobs_per_second"],
            f"jobs/s={results['jobs_per_second']:.2f};"
            f"q_p50={queue_lat['p50'] * 1e3:.1f}ms;"
            f"q_p99={queue_lat['p99'] * 1e3:.1f}ms;"
            f"c_p99={completion_lat['p99'] * 1e3:.1f}ms"),
    ]


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
