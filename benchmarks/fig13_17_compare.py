"""Paper Fig. 13-17 + Tables 5-7: PIM vs CPU vs GPU comparison.

Every row is produced through the backend-portable ``System`` API
(DESIGN.md §10): the SAME ``Workload`` objects fit on

  * a ``PimSystem`` (paper-version numerics; step time from the
    calibrated ``HierarchicalCostModel`` — per-DPU kernel plus
    rank-serialized transfer legs — at the paper's best core count),
  * a ``HostSystem`` (the processor-centric fp32 baseline, measured
    wall-clock in this container — the deleted per-trainer
    ``train_cpu_baseline`` loops became this target), and
  * a ``ModeledGpuSystem`` (A100 roofline priced from the measured
    FLOPs/bytes of the compiled programs — replacing the previously
    echoed paper GPU constants; the paper's reported ratios remain as
    reference columns).

``repro.launch.compare`` is the interactive face of the same
comparison; this module keeps the benchmark harness's figure-keyed CSV
rows.

Dataset note: SUSY/Higgs/Criteo downloads are unavailable offline; sizes
are matched with synthetic data of identical (samples x attributes) shape
(SUSY 5M x 18, Skin 245k x 3, Higgs 11M x 28 truncated to fit RAM/time
budgets — scaling factors documented per row).
"""
from __future__ import annotations

import time

from repro.api import HierarchicalCostModel, get_workload, make_system
from repro.core.metrics import (accuracy, adjusted_rand_index,
                                training_error_rate)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)
from repro.launch.roofline import a100
from .common import row

PAPER = {
    "lin_gpu_over_pim": 4.1,      # §5.4.1 (GPU 4.1x faster than LIN-BUI)
    "log_pim_over_cpu": 3.9,      # LOG-BUI-LUT vs CPU
    "dtr_pim_over_cpu": 27.0,     # Higgs
    "dtr_pim_over_gpu": 1.34,
    "kme_pim_over_cpu": 2.8,
    "kme_pim_over_gpu": 3.2,
}


def _host_fit_seconds(workload: str, X, y, **params) -> float:
    """Steady-state wall seconds of one fp32 fit on the HostSystem
    baseline (warm fit first: compile + view materialization)."""
    wl = get_workload(workload)
    host = make_system("host")
    ds = host.put(X, y)
    spec = wl.spec("fp32", **params)
    wl.fit(ds, spec)
    t0 = time.perf_counter()
    wl.fit(ds, spec)
    return time.perf_counter() - t0


def _gpu_iter_seconds(workload: str, X, y, iters: int,
                      row_scale: float = 1.0, **params) -> float:
    """Per-iteration A100 roofline seconds of the fp32 fit, with the
    FLOP/byte terms scaled to the full (un-subsampled) dataset size —
    per-launch overhead does not scale with rows, the math does."""
    wl = get_workload(workload)
    gpu = make_system("gpu-model")
    ds = gpu.put(X, y)
    spec = wl.spec("fp32", **params)
    wl.fit(ds, spec)
    snap = gpu.gpu.snapshot()
    wl.fit(ds, spec)
    d = gpu.gpu.delta(snap)
    launches = max(d.launches, 1)
    rl = a100()
    return rl.kernel_seconds(d.flops / launches * row_scale,
                             d.hbm_bytes / launches * row_scale) \
        * launches / max(iters, 1)


def _pim_step_seconds(workload: str, version: str, n: int, f: int,
                      cores: int, k: int = 16) -> float:
    """One modeled PIM training pass at paper scale: per-DPU kernel +
    the rank-serialized broadcast/gather legs (UPMEM ranks are 64 DPUs
    regardless of the allocation size, so the tree is built with
    dpus_per_rank=64 rather than the divisor heuristic)."""
    m = HierarchicalCostModel.for_cores(cores, dpus_per_rank=64)
    return m.step_seconds(workload, version, n, f, n_cores=cores,
                          n_threads=16, k=k)


def run():
    rows = []
    # ---- LIN on a SUSY-shaped dataset (5M x 18 -> 500k x 18 subsample;
    # times scale linearly in n, factor noted) --------------------------------
    scale = 10
    X, y, _ = make_linear_dataset(5_000_000 // scale, 18, seed=0)
    iters = 10
    cpu_lin = _host_fit_seconds("linreg", X, y, n_iters=iters) \
        / iters * scale
    pim_lin = _pim_step_seconds("lin", "bui", 5_000_000, 18, 2524)
    gpu_lin = _gpu_iter_seconds("linreg", X, y, iters, row_scale=scale,
                                n_iters=iters)
    rows.append(row("fig13_lin_cpu_measured_ms_per_iter", cpu_lin * 1e3,
                    f"subsample_x{scale};host_system_fp32"))
    rows.append(row("fig13_lin_bui_pim_model_ms_per_iter", pim_lin * 1e3,
                    f"paper_gpu_over_pim={PAPER['lin_gpu_over_pim']}"))
    rows.append(row("fig13_lin_gpu_roofline_ms_per_iter", gpu_lin * 1e3,
                    f"modeled_gpu_over_pim={pim_lin / gpu_lin:.2f};"
                    f"paper={PAPER['lin_gpu_over_pim']}"))
    rows.append(row("fig13_lin_pim_over_cpu_speedup", cpu_lin / pim_lin,
                    "paper~1.13_for_fp32_higher_for_bui"))

    # ---- LOG on a Skin-shaped dataset (245k x 3) ---------------------------
    Xs, ys, _ = make_linear_dataset(245_057, 3, seed=1)
    cpu_log = _host_fit_seconds("logreg", Xs, ys, n_iters=iters) / iters
    pim_log = _pim_step_seconds("log", "bui_lut", 245_057, 3, 256)
    gpu_log = _gpu_iter_seconds("logreg", Xs, ys, iters, n_iters=iters)
    rows.append(row("fig14_log_cpu_measured_ms_per_iter", cpu_log * 1e3,
                    "host_system_fp32_exact_sigmoid"))
    rows.append(row("fig14_log_bui_lut_pim_model_ms_per_iter",
                    pim_log * 1e3, ""))
    rows.append(row("fig14_log_gpu_roofline_ms_per_iter", gpu_log * 1e3,
                    f"modeled_gpu_over_pim={pim_log / gpu_log:.2f}"))
    rows.append(row("fig14_log_pim_over_cpu_speedup", cpu_log / pim_log,
                    f"paper={PAPER['log_pim_over_cpu']}"))

    # ---- DTR on a Higgs-shaped dataset (11M x 28 -> 550k x 28) -------------
    scale = 20
    Xh, yh = make_classification(11_000_000 // scale, 28, seed=2)
    dtree_wl = get_workload("dtree")
    pim = make_system("pim", n_cores=16)
    t0 = time.perf_counter()
    tree_fit = dtree_wl.fit(pim.put(Xh, yh),
                            dtree_wl.spec("fp32", max_depth=10))
    pim_impl_dtr = time.perf_counter() - t0
    n_nodes = tree_fit.attributes["n_nodes_"]
    host = make_system("host")
    t0 = time.perf_counter()
    tcpu = dtree_wl.fit(host.put(Xh, yh),
                        dtree_wl.spec("fp32", max_depth=10))
    cpu_dtr = (time.perf_counter() - t0) * scale
    pim_dtr = _pim_step_seconds("dtr", "fp32", 11_000_000, 28, 1024) \
        * 2 * n_nodes  # split-evaluate passes across the tree build
    rows.append(row("fig15a_dtr_cpu_measured_s", cpu_dtr,
                    f"subsample_x{scale};host_system"))
    rows.append(row("fig15a_dtr_pim_model_s", pim_dtr,
                    f"paper_speedup={PAPER['dtr_pim_over_cpu']}x_cpu_"
                    f"{PAPER['dtr_pim_over_gpu']}x_gpu"))
    rows.append(row("tab6_dtr_train_accuracy_pim",
                    accuracy(dtree_wl.predict(tree_fit, Xh), yh),
                    f"cpu={accuracy(dtree_wl.predict(tcpu, Xh), yh):.4f};"
                    "paper=0.65635_vs_0.65581"))

    # ---- KME on a Higgs-shaped dataset -------------------------------------
    Xk, _, _ = make_blobs(11_000_000 // scale, 28, centers=16, seed=3)
    kme_wl = get_workload("kmeans")
    t0 = time.perf_counter()
    rk = kme_wl.fit(pim.put(Xk),
                    kme_wl.spec("int16", n_clusters=16, seed=0,
                                max_iter=40))
    pim_impl_kme = time.perf_counter() - t0
    t0 = time.perf_counter()
    rc = kme_wl.fit(make_system("host").put(Xk),
                    kme_wl.spec("fp32", n_clusters=16, seed=0,
                                max_iter=40))
    cpu_kme = (time.perf_counter() - t0) * scale
    pim_kme = _pim_step_seconds("kme", "int16", 11_000_000, 28, 2524) \
        * rk.attributes["n_iter_"]
    rows.append(row("fig15b_kme_cpu_measured_s", cpu_kme,
                    f"subsample_x{scale};host_system_fp32"))
    rows.append(row("fig15b_kme_pim_model_s", pim_kme,
                    f"paper_speedup={PAPER['kme_pim_over_cpu']}x_cpu_"
                    f"{PAPER['kme_pim_over_gpu']}x_gpu"))
    rows.append(row("tab7_kme_ari_pim_vs_cpu",
                    adjusted_rand_index(rk.attributes["labels_"],
                                        rc.attributes["labels_"]),
                    "paper=0.999985"))

    # ---- Table 5: error rates on the real-shaped datasets ------------------
    lin_wl = get_workload("linreg")
    r = lin_wl.fit(make_system("pim", n_cores=16).put(X, y),
                   lin_wl.spec("int32", n_iters=60))
    rows.append(row("tab5_lin_int32_err_pct",
                    training_error_rate(lin_wl.predict(r, X), y),
                    "paper=18.68_on_SUSY(real_data)"))
    return rows
