"""Paper Fig. 13-17 + Tables 5-7: PIM vs CPU vs GPU comparison.

Columns per workload:
  cpu_measured   our numpy/JAX CPU baseline wall time (this container)
  pim_model      calibrated DPU cost model at the paper's best core count
  paper_speedup  the paper's reported PIM-over-CPU speedup
  model_speedup  pim_model vs a cpu_model scaled to the paper's Xeon 4215
                 (we cannot measure their exact CPU; the ratio column is
                 the reproduction target, reported side by side)

GPU numbers cannot be measured in this container; the paper's reported
ratios are echoed in the derived field for reference.

Dataset note: SUSY/Higgs/Criteo downloads are unavailable offline; sizes
are matched with synthetic data of identical (samples x attributes) shape
(SUSY 5M x 18, Skin 245k x 3, Higgs 11M x 28 truncated to fit RAM/time
budgets — scaling factors documented per row).
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import DpuCostModel, PimConfig, PimSystem
from repro.core import dtree, kmeans, linreg, logreg
from repro.core.metrics import (accuracy, adjusted_rand_index,
                                training_error_rate)
from repro.data.synthetic import (make_blobs, make_classification,
                                  make_linear_dataset)
from .common import row

PAPER = {
    "lin_gpu_over_pim": 4.1,      # §5.4.1 (GPU 4.1x faster than LIN-BUI)
    "log_pim_over_cpu": 3.9,      # LOG-BUI-LUT vs CPU
    "dtr_pim_over_cpu": 27.0,     # Higgs
    "dtr_pim_over_gpu": 1.34,
    "kme_pim_over_cpu": 2.8,
    "kme_pim_over_gpu": 3.2,
}


def run():
    rows = []
    m = DpuCostModel()
    # ---- LIN on a SUSY-shaped dataset (5M x 18 -> 500k x 18 subsample;
    # times scale linearly in n, factor noted) --------------------------------
    scale = 10
    X, y, _ = make_linear_dataset(5_000_000 // scale, 18, seed=0)
    iters = 10
    t0 = time.perf_counter()
    linreg.train_cpu_baseline(X, y, n_iters=iters)
    cpu_lin = (time.perf_counter() - t0) / iters * scale
    pim_lin = m.workload_seconds("lin", "bui", 5_000_000, 18, 2524, 16)
    rows.append(row("fig13_lin_cpu_measured_ms_per_iter", cpu_lin * 1e3,
                    f"subsample_x{scale}"))
    rows.append(row("fig13_lin_bui_pim_model_ms_per_iter", pim_lin * 1e3,
                    f"paper_gpu_over_pim={PAPER['lin_gpu_over_pim']}"))
    rows.append(row("fig13_lin_pim_over_cpu_speedup", cpu_lin / pim_lin,
                    "paper~1.13_for_fp32_higher_for_bui"))

    # ---- LOG on a Skin-shaped dataset (245k x 3) ---------------------------
    Xs, ys, _ = make_linear_dataset(245_057, 3, seed=1)
    t0 = time.perf_counter()
    logreg.train_cpu_baseline(Xs, ys, n_iters=iters)
    cpu_log = (time.perf_counter() - t0) / iters
    pim_log = m.workload_seconds("log", "bui_lut", 245_057, 3, 256, 16)
    rows.append(row("fig14_log_cpu_measured_ms_per_iter", cpu_log * 1e3, ""))
    rows.append(row("fig14_log_bui_lut_pim_model_ms_per_iter",
                    pim_log * 1e3, ""))
    rows.append(row("fig14_log_pim_over_cpu_speedup", cpu_log / pim_log,
                    f"paper={PAPER['log_pim_over_cpu']}"))

    # ---- DTR on a Higgs-shaped dataset (11M x 28 -> 550k x 28) -------------
    scale = 20
    Xh, yh = make_classification(11_000_000 // scale, 28, seed=2)
    pim = PimSystem(PimConfig(n_cores=16))
    t0 = time.perf_counter()
    tree = dtree.fit(pim.put(Xh, yh), dtree.TreeConfig(max_depth=10))
    pim_impl_dtr = time.perf_counter() - t0
    t0 = time.perf_counter()
    tcpu = dtree.train_cpu_baseline(Xh, yh, dtree.TreeConfig(max_depth=10))
    cpu_dtr = (time.perf_counter() - t0) * scale
    pim_dtr = m.workload_seconds("dtr", "fp32", 11_000_000, 28, 1024, 16) \
        * 2 * tree.n_nodes  # split-evaluate passes across the tree build
    rows.append(row("fig15a_dtr_cpu_measured_s", cpu_dtr,
                    f"subsample_x{scale}"))
    rows.append(row("fig15a_dtr_pim_model_s", pim_dtr,
                    f"paper_speedup={PAPER['dtr_pim_over_cpu']}x_cpu_"
                    f"{PAPER['dtr_pim_over_gpu']}x_gpu"))
    rows.append(row("tab6_dtr_train_accuracy_pim",
                    accuracy(tree.predict(Xh), yh),
                    f"cpu={accuracy(tcpu.predict(Xh), yh):.4f};"
                    "paper=0.65635_vs_0.65581"))

    # ---- KME on a Higgs-shaped dataset -------------------------------------
    Xk, _, _ = make_blobs(11_000_000 // scale, 28, centers=16, seed=3)
    cfg = kmeans.KMeansConfig(k=16, seed=0, max_iters=40)
    t0 = time.perf_counter()
    rk = kmeans.fit(pim.put(Xk), cfg)
    pim_impl_kme = time.perf_counter() - t0
    t0 = time.perf_counter()
    rc = kmeans.train_cpu_baseline(Xk, cfg)
    cpu_kme = (time.perf_counter() - t0) * scale
    pim_kme = m.workload_seconds("kme", "int16", 11_000_000, 28, 2524,
                                 16) * rk.n_iters
    rows.append(row("fig15b_kme_cpu_measured_s", cpu_kme,
                    f"subsample_x{scale}"))
    rows.append(row("fig15b_kme_pim_model_s", pim_kme,
                    f"paper_speedup={PAPER['kme_pim_over_cpu']}x_cpu_"
                    f"{PAPER['kme_pim_over_gpu']}x_gpu"))
    rows.append(row("tab7_kme_ari_pim_vs_cpu",
                    adjusted_rand_index(rk.labels, rc.labels),
                    "paper=0.999985"))

    # ---- Table 5: error rates on the real-shaped datasets ------------------
    r = linreg.fit(PimSystem(PimConfig(n_cores=16)).put(X, y),
                   linreg.GdConfig(version="int32", n_iters=60))
    rows.append(row("tab5_lin_int32_err_pct",
                    training_error_rate(r.predict(X), y),
                    "paper=18.68_on_SUSY(real_data)"))
    return rows
