"""Paper Fig. 11 (weak scaling, 1-64 PIM cores) and Fig. 12 (strong
scaling, 256-2048 cores).

Weak scaling additionally runs our real JAX PIM implementation (vmap
backend) at each core count and reports the measured comm fraction from
the PimSystem byte counters against the paper's <7% claim.  Strong
scaling at 256-2048 cores uses the hierarchical cost model (per-DPU
kernel + rank-serialized transfer legs, DESIGN.md §12) at the paper's
own hardware scale and reports the step-time speedup vs 256 cores —
the serialized legs are what lands the 2048-core point inside the
paper's measured 6.37x-7.98x band instead of the flat model's 8.0x.
"""
from __future__ import annotations

import time

from repro.api import HierarchicalCostModel, PimConfig, PimSystem
from repro.core import linreg
from repro.data.synthetic import make_linear_dataset
from .common import row

WEAK_CORES = (1, 4, 16, 64)
STRONG_CORES = (256, 512, 1024, 2048)
PER_CORE = 2048  # samples per core (paper Table 3, LIN/LOG weak scaling)


def run():
    rows = []
    iters = 30

    # -- weak scaling: measured on the real implementation ------------------
    for cores in WEAK_CORES:
        X, y, _ = make_linear_dataset(cores * PER_CORE, 16, seed=0)
        pim = PimSystem(PimConfig(n_cores=cores))
        ds = pim.put(X, y)
        t0 = time.perf_counter()
        linreg.fit(ds, linreg.GdConfig(version="int32", n_iters=iters))
        dt = (time.perf_counter() - t0) / iters
        comm_bytes = pim.stats.cpu_to_pim + pim.stats.pim_to_cpu
        rows.append(row(f"fig11_lin_int32_weak_c{cores}_ms", dt * 1e3,
                        f"comm_bytes_per_iter={comm_bytes // iters}"))

    # comm fraction: the hierarchical model's own rank-serialized legs
    # over its per-DPU kernel term (no more ad-hoc aggregate-link math)
    for cores in WEAK_CORES:
        m = HierarchicalCostModel.for_cores(cores)
        kern = m.workload_seconds("lin", "int32", cores * PER_CORE, 16,
                                  cores, 16)
        step = m.step_seconds("lin", "int32", cores * PER_CORE, 16,
                              n_cores=cores, n_threads=16)
        frac = (step - kern) / step
        rows.append(row(f"fig11_comm_fraction_c{cores}", frac * 100,
                        "paper=<7pct"))

    # -- strong scaling: hierarchical model at paper scale -------------------
    base = {}
    for w, v, n in (("lin", "int32", 6_291_456),
                    ("log", "int32_lut_wram", 6_291_456),
                    ("dtr", "fp32", 153_600_000),
                    ("kme", "int16", 25_600_000)):
        for cores in STRONG_CORES:
            m = HierarchicalCostModel.for_cores(cores)
            t = m.step_seconds(w, v, n, 16, n_cores=cores, n_threads=16)
            if cores == 256:
                base[w] = t
            rows.append(row(f"fig12_{w}_strong_c{cores}_model_ms", t * 1e3,
                            f"speedup_vs_256={base[w] / t:.2f}"
                            + (";paper=6.37-7.98x_at_2048"
                               if cores == 2048 else "")))
    return rows
