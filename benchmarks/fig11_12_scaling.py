"""Paper Fig. 11 (weak scaling, 1-64 PIM cores) and Fig. 12 (strong
scaling, 256-2048 cores).

Weak scaling additionally runs our real JAX PIM implementation (vmap
backend) at each core count and reports the measured comm fraction from
the PimSystem byte counters against the paper's <7% claim.  Strong
scaling at 256-2048 cores uses the calibrated DPU cost model (the paper's
own hardware regime) and reports the kernel-time speedup vs 256 cores
(paper: 6.37x-7.98x at 2048).
"""
from __future__ import annotations

import time

from repro.api import DpuCostModel, PimConfig, PimSystem
from repro.core import linreg
from repro.data.synthetic import make_linear_dataset
from .common import row

WEAK_CORES = (1, 4, 16, 64)
STRONG_CORES = (256, 512, 1024, 2048)
PER_CORE = 2048  # samples per core (paper Table 3, LIN/LOG weak scaling)


def run():
    rows = []
    iters = 30

    # -- weak scaling: measured on the real implementation ------------------
    for cores in WEAK_CORES:
        X, y, _ = make_linear_dataset(cores * PER_CORE, 16, seed=0)
        pim = PimSystem(PimConfig(n_cores=cores))
        ds = pim.put(X, y)
        t0 = time.perf_counter()
        linreg.fit(ds, linreg.GdConfig(version="int32", n_iters=iters))
        dt = (time.perf_counter() - t0) / iters
        comm_bytes = pim.stats.cpu_to_pim + pim.stats.pim_to_cpu
        rows.append(row(f"fig11_lin_int32_weak_c{cores}_ms", dt * 1e3,
                        f"comm_bytes_per_iter={comm_bytes // iters}"))

    # comm fraction from the DPU cost model + modeled transfer time
    m = DpuCostModel()
    for cores in WEAK_CORES:
        kern = m.workload_seconds("lin", "int32", cores * PER_CORE, 16,
                                  cores, 16) * iters
        # per-iteration: broadcast w (17 f32) + partials (17 f32/core),
        # over a ~20 GB/s host<->DIMM aggregate link
        comm = iters * (17 * 4 * cores * 2) / 20e9
        frac = comm / (kern + comm)
        rows.append(row(f"fig11_comm_fraction_c{cores}", frac * 100,
                        "paper=<7pct"))

    # -- strong scaling: DPU cost model at paper scale -----------------------
    base = {}
    for w, v, n in (("lin", "int32", 6_291_456),
                    ("log", "int32_lut_wram", 6_291_456),
                    ("dtr", "fp32", 153_600_000),
                    ("kme", "int16", 25_600_000)):
        for cores in STRONG_CORES:
            t = m.workload_seconds(w, v, n, 16, cores, 16)
            if cores == 256:
                base[w] = t
            rows.append(row(f"fig12_{w}_strong_c{cores}_model_ms", t * 1e3,
                            f"speedup_vs_256={base[w] / t:.2f}"
                            + (";paper=6.37-7.98x_at_2048"
                               if cores == 2048 else "")))
    return rows
