"""Beyond-paper: the paper's techniques inside the LM stack.

Measures reduced-config LM train-step wall time and loss parity for:
  baseline            bf16/f32 dense
  +quantize_dense     int8 weights (LIN-HYB analogue)
  +lut_activations    LUT SiLU (LOG-LUT analogue)
(the CPU wall-clock is indicative; the dry-run roofline carries the
TPU-relevant numbers — this bench verifies functional parity + cost of
the quantization path end to end).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.tokens import MarkovCorpus
from repro.models.api import Model
from repro.optim.adam import AdamW
from repro.train.loop import make_train_step
from .common import row


def _train(cfg, steps=8, batch=8, seq=64):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    corpus = MarkovCorpus(cfg.vocab_size, seed=0)
    batch_d = jax.tree_util.tree_map(jnp.asarray, corpus.batch(batch, seq))
    # warmup/compile
    params, opt_state, m = step(params, opt_state, batch_d)
    t0 = time.perf_counter()
    losses = []
    for _ in range(steps):
        batch_d = jax.tree_util.tree_map(jnp.asarray,
                                         corpus.batch(batch, seq))
        params, opt_state, m = step(params, opt_state, batch_d)
        losses.append(float(m["loss"]))
    dt = (time.perf_counter() - t0) / steps
    return dt, losses


def run():
    rows = []
    base = get_config("granite-3-8b").reduced()
    variants = {
        "baseline": base,
        "quant_dense": dataclasses.replace(base, quantize_dense=True),
        "lut_act": dataclasses.replace(base, lut_activations=True),
        "quant+lut": dataclasses.replace(base, quantize_dense=True,
                                         lut_activations=True),
    }
    ref_loss = None
    for name, cfg in variants.items():
        dt, losses = _train(cfg)
        if ref_loss is None:
            ref_loss = losses[-1]
        rows.append(row(f"lm_ablation_{name}_step_us", dt * 1e6,
                        f"final_loss={losses[-1]:.3f};"
                        f"delta_vs_base={losses[-1] - ref_loss:+.3f}"))
    return rows
