"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus section markers).
  PYTHONPATH=src python -m benchmarks.run [--only fig06]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    "benchmarks.fig06_07_quality",      # paper Fig. 6/7 (quality)
    "benchmarks.fig08_10_kernel_time",  # paper Fig. 8/9/10 (1-core perf)
    "benchmarks.fig11_12_scaling",      # paper Fig. 11/12 (weak/strong)
    "benchmarks.fig13_17_compare",      # paper Fig. 13-17, Tab. 5-7
    "benchmarks.kernels_bench",         # Pallas kernels (interpret)
    "benchmarks.dispatch_bench",        # backend dispatch parity/time
    "benchmarks.sched_bench",           # job scheduler: fused vs serial
    "benchmarks.step_fusion_bench",     # fused k-step scans vs per-step
    "benchmarks.lm_ablation",           # beyond-paper LM ablations
    "benchmarks.serve_bench",           # serving throughput
    "benchmarks.service_bench",         # async service under load
    "benchmarks.roofline_summary",      # dry-run roofline terms (§Perf)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t_all = time.time()
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        mod = importlib.import_module(mod_name)
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001 — keep the harness going
            print(f"{mod_name},-1,ERROR:{type(e).__name__}:{e}")
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.4f},{derived}")
        print(f"# {mod_name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    print(f"# total {time.time() - t_all:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
