"""End-to-end behaviour tests for the whole system."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, all_cells, shape_for, supports
from repro.data.tokens import MarkovCorpus
from repro.models.api import Model, input_specs
from repro.optim.adam import AdamW
from repro.train.loop import make_train_step


def test_lm_learns_markov_structure():
    """A small LM must push loss clearly below the uniform bound toward the
    corpus entropy floor (end-to-end train correctness)."""
    cfg = get_config("granite-3-8b").reduced(vocab_size=128)
    model = Model(cfg)
    corpus = MarkovCorpus(cfg.vocab_size, seed=0)
    opt = AdamW(lr=3e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(60):
        batch = jax.tree_util.tree_map(jnp.asarray, corpus.batch(16, 32))
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    uniform = np.log(cfg.vocab_size)
    assert losses[-1] < uniform - 0.5, (losses[0], losses[-1], uniform)


def test_serving_engine_end_to_end():
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=2, max_seq=48)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, 8)
                    .astype(np.int32), max_new_tokens=6) for _ in range(5)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.output) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.output)


def test_serving_greedy_deterministic():
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    outs = []
    for _ in range(2):
        engine = ServeEngine(model, params, n_slots=1, max_seq=32)
        r = engine.run([Request(
            prompt=np.arange(8, dtype=np.int32), max_new_tokens=8)])[0]
        outs.append(tuple(r.output))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Cell/shape matrix sanity.
# ---------------------------------------------------------------------------

def test_cell_matrix_is_complete():
    cells = all_cells()
    assert len(cells) == 40  # 10 archs x 4 shapes
    skips = [(a, s) for a, s, ok, _ in cells if not ok]
    # only long_500k skips, only for non-ssm/hybrid archs
    assert all(s == "long_500k" for _, s in skips)
    assert len(skips) == 8


def test_input_specs_no_allocation():
    """input_specs must return ShapeDtypeStructs (dry-run contract)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname in SHAPES:
            ok, _ = supports(cfg, sname)
            if not ok:
                continue
            spec = input_specs(cfg, shape_for(cfg, sname))
            for leaf in jax.tree_util.tree_leaves(spec):
                assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, sname)


def test_train_microbatches_divide_batches():
    from repro.configs.shapes import TRAIN_MICROBATCHES
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        s = shape_for(cfg, "train_4k")
        assert s.global_batch % s.microbatches == 0, arch


# ---------------------------------------------------------------------------
# Dry-run machinery (unit level; the full sweep runs via the launcher).
# ---------------------------------------------------------------------------

def test_dryrun_results_exist_and_green():
    """The committed sweep results must cover all 80 cells with no errors
    (regenerate with `python -m repro.launch.dryrun`)."""
    import json
    import os
    path = "experiments/dryrun_results.json"
    if not os.path.exists(path):
        pytest.skip("dry-run results not generated yet")
    with open(path) as f:
        results = json.load(f)
    # production cells have 3-part keys; --mesh-shape experiments append
    # a 4th part and live alongside
    prod = {k: v for k, v in results.items() if len(k.split("|")) == 3}
    assert len(prod) == 80
    statuses = {k: v["status"] for k, v in prod.items()}
    errors = [k for k, s in statuses.items() if s == "error"]
    assert not errors, errors
    assert sum(1 for s in statuses.values() if s == "skipped") == 16


def test_production_mesh_shapes():
    """make_production_mesh matches the assignment spec (no device-state
    dependency beyond host platform)."""
    import repro.launch.mesh as mesh_mod
    import inspect
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
