"""Mini-batch SGD variant (paper §2 mentions GD *and* SGD) + data loader."""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import linreg
from repro.core.metrics import training_error_rate
from repro.core.pim import PimConfig, PimSystem
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import make_linear_dataset
from repro.data.tokens import MarkovCorpus, UniformTokens


def test_sgd_converges_like_gd():
    X, y, _ = make_linear_dataset(8192, 16, seed=0)
    pim = PimSystem(PimConfig(n_cores=16))
    gd = linreg.train(X, y, pim, linreg.GdConfig(version="int32",
                                                 n_iters=400))
    sgd = linreg.train(X, y, pim, linreg.GdConfig(
        version="int32", n_iters=400, minibatch=128, lr=0.05))
    e_gd = training_error_rate(gd.predict(X), y)
    e_sgd = training_error_rate(sgd.predict(X), y)
    assert e_sgd < e_gd + 2.0, (e_gd, e_sgd)


def test_sgd_uses_minibatch_counters():
    """SGD must move fewer PIM->CPU bytes per iteration than full GD? No —
    partials are same size; what shrinks is the per-iteration *compute*.
    Assert instead the deterministic seed reproduces the same model."""
    X, y, _ = make_linear_dataset(2048, 8, seed=1)
    pim = PimSystem(PimConfig(n_cores=8))
    cfg = linreg.GdConfig(version="fp32", n_iters=50, minibatch=64, seed=7)
    r1 = linreg.train(X, y, pim, cfg)
    r2 = linreg.train(X, y, pim, cfg)
    np.testing.assert_array_equal(r1.w, r2.w)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_prefetch_loader_delivers_batches():
    corpus = UniformTokens(128, seed=0)
    loader = PrefetchLoader(lambda: corpus.batch(4, 16), prefetch=2)
    try:
        seen = [next(loader) for _ in range(5)]
        for b in seen:
            assert b["tokens"].shape == (4, 16)
            assert int(jnp.max(b["tokens"])) < 128
    finally:
        loader.close()


def test_prefetch_loader_overlaps_host_work():
    """The loader must hide a slow host source behind consumption."""
    def slow_source():
        time.sleep(0.05)
        return {"x": np.zeros(4, np.float32)}

    loader = PrefetchLoader(slow_source, prefetch=2)
    try:
        next(loader)          # warm
        time.sleep(0.12)      # let the worker stage ahead
        t0 = time.perf_counter()
        next(loader)
        dt = time.perf_counter() - t0
        assert dt < 0.04, dt  # served from the prefetch queue
    finally:
        loader.close()


def test_markov_corpus_entropy_bound_sane():
    c = MarkovCorpus(256, seed=0)
    h = c.entropy_bound()
    assert 0.0 < h < np.log(256)
    batch = c.batch(3, 20)
    assert batch["tokens"].shape == (3, 20)
    # targets are tokens shifted by one
    full = c.sample(1, 10)
    assert full.shape == (1, 11)
